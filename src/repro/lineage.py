"""Integration facade for logging framework ops into DSLog.

``repro.core.oplib`` promises this module as the surface the training
framework uses to record pipeline/model operations: one import gives the
catalog (:class:`DSLog`), the query types, the lineage DAG and planner, the
op registry with its per-op lineage adapters, and the capture helpers —
without reaching into individual ``repro.core`` submodules.

    from repro import lineage as L

    log = L.DSLog(root="/tmp/lineage")
    spec = L.get_op("matmul")            # adapter from the op registry
    log.register_operation(...)
    L.QueryBox, log.prov_query("loss", "corpus", cells)  # graph-form query

The data pipeline (``repro.data.pipeline.TokenPipeline``) accepts a
``dslog=`` instance and logs through this same API; see
``examples/lineage_debugging.py`` for the end-to-end flow.
"""

from repro.core import (  # noqa: F401
    AffinityShardPolicy,
    ArrayDef,
    CommitPipeline,
    CompressedTable,
    CycleError,
    DSLog,
    LeaseHeldError,
    ExchangeStep,
    HashShardPolicy,
    IntervalIndex,
    LineageEntry,
    LineageGraph,
    LineageRelation,
    QueryBox,
    QueryPlan,
    QueryPlanner,
    ReusePredictor,
    ShardedDSLog,
    ShardedLineageGraph,
    ShardedQueryPlan,
    ShardedQueryPlanner,
    ShardPolicy,
    compress,
    compress_both,
    merge_boxes,
    theta_join,
    theta_join_batch,
    theta_join_inverse,
    theta_join_inverse_batch,
)
from repro.core import capture  # noqa: F401
from repro.core.oplib import OPS, OpSpec, get_op, op_names  # noqa: F401

__all__ = [
    "AffinityShardPolicy",
    "ArrayDef",
    "CommitPipeline",
    "CompressedTable",
    "CycleError",
    "DSLog",
    "ExchangeStep",
    "LeaseHeldError",
    "HashShardPolicy",
    "IntervalIndex",
    "LineageEntry",
    "LineageGraph",
    "LineageRelation",
    "OPS",
    "OpSpec",
    "QueryBox",
    "QueryPlan",
    "QueryPlanner",
    "ReusePredictor",
    "ShardPolicy",
    "ShardedDSLog",
    "ShardedLineageGraph",
    "ShardedQueryPlan",
    "ShardedQueryPlanner",
    "capture",
    "compress",
    "compress_both",
    "get_op",
    "merge_boxes",
    "op_names",
    "theta_join",
    "theta_join_batch",
    "theta_join_inverse",
    "theta_join_inverse_batch",
]
