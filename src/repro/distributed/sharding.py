"""Logical-axis → mesh-axis sharding rules (DP/FSDP/TP/EP/SP).

Parameters carry logical specs like ``("fsdp", "tp")`` (see
``repro.models.layers``); this module resolves them against a mesh:

* ``fsdp`` → the ``data`` axis (ZeRO-3 parameter sharding within a pod)
* ``tp``   → the ``model`` axis (tensor parallelism)
* batch    → ``("pod", "data")`` when the mesh has a pod axis (pure DP
  across pods — the slow inter-pod links carry only gradient reductions)

Rules are data, not code, so §Perf iterations can swap them per-arch
(e.g. moving ``fsdp`` to ``("pod", "data")`` for the 314B config).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "param_sharding",
    "batch_sharding",
    "cache_sharding",
    "logical_to_spec",
    "set_activation_mesh",
    "hint",
]


class AxisRules(dict):
    """logical axis name -> mesh axis (str | tuple | None)."""


def default_rules(mesh: Mesh) -> AxisRules:
    has_pod = "pod" in mesh.axis_names
    return AxisRules(
        fsdp="data",
        tp="model",
        dp=("pod", "data") if has_pod else ("data",),
        sp="data",  # sequence sharding for long-context caches
    )


DEFAULT_RULES = default_rules


def logical_to_spec(logical: tuple, rules: AxisRules) -> PS:
    axes = []
    for ax in logical:
        axes.append(rules.get(ax) if ax is not None else None)
    return PS(*axes)


# --------------------------------------------------------------------------- #
# Activation sharding hints
# --------------------------------------------------------------------------- #
# GSPMD's propagation through head-reshapes and scan carries loses the batch/
# head sharding badly enough to blow temp memory by orders of magnitude (see
# EXPERIMENTS.md §Dry-run).  Model code therefore calls ``hint(x, kind)`` at
# the handful of layout decision points; the launcher activates a mesh here.
# Outside an activated mesh the hints are no-ops, so unit tests and the CPU
# trainer run unchanged.

_ACT: dict | None = None


def set_activation_mesh(
    mesh: Mesh | None,
    rules: AxisRules | None = None,
    policy: dict | None = None,
):
    """Enable (or with ``None`` disable) activation sharding hints.

    ``policy`` tunes the strategy per tensor kind (the §Perf hillclimbing
    knobs):
      attn_heads: "auto" (TP when divisible, else sequence-parallel) |
                  "tp_uneven" (TP with GSPMD padding for 14/25/40-head
                  configs) | "seq" | "batch_only"
    """
    global _ACT
    if mesh is None:
        _ACT = None
        return
    rules = rules or default_rules(mesh)
    _ACT = {
        "mesh": mesh,
        "dp": rules["dp"],
        "model_size": mesh.shape["model"],
        "policy": dict(policy or {}),
    }


def hint(x, kind: str):
    """Apply an activation sharding constraint (no-op without a mesh).

    kinds:
      hidden   [B, S, D]        -> (dp, None, None)
      heads    [B, S, H, hd]    -> heads on model when divisible, else
                                   sequence-parallel (dp, model, None, None)
      ffn      [B, S, F]        -> (dp, None, model)
      logits   [B, S, V]        -> (dp, None, model)
      experts  [E, B, C, D]     -> (None, dp, None, None)
      bhst     [B, H, S, T]     -> scores: H on model when divisible
    """
    if _ACT is None:
        return x
    dp, ms = _ACT["dp"], _ACT["model_size"]
    mesh = _ACT["mesh"]
    policy = _ACT.get("policy", {})
    heads_mode = policy.get("attn_heads", "auto")
    b_ok = x.shape[0] > 1
    dpx = dp if b_ok else None
    if kind == "hidden":
        spec = PS(dpx, *([None] * (x.ndim - 1)))
    elif kind == "heads":
        tp_ok = x.shape[2] % ms == 0 or (
            heads_mode == "tp_uneven" and x.shape[2] >= ms
        )
        seq_ok = x.shape[1] % ms == 0 and x.shape[1] > 1
        if heads_mode == "batch_only":
            spec = PS(dpx, None, None, None)
        elif heads_mode == "seq" and seq_ok:
            spec = PS(dpx, "model", None, None)
        elif tp_ok:
            spec = PS(dpx, None, "model", None)
        elif seq_ok:
            spec = PS(dpx, "model", None, None)
        else:
            spec = PS(dpx, None, None, None)
    elif kind == "bhst":
        tp_ok = x.shape[1] % ms == 0 or (
            heads_mode == "tp_uneven" and x.shape[1] >= ms
        )
        seq_ok = x.shape[2] % ms == 0 and x.shape[2] > 1
        if heads_mode == "batch_only":
            spec = PS(dpx, None, None, None)
        elif heads_mode == "seq" and seq_ok:
            spec = PS(dpx, None, "model", None)
        elif tp_ok:
            spec = PS(dpx, "model", None, None)
        elif seq_ok:
            spec = PS(dpx, None, "model", None)
        else:
            spec = PS(dpx, None, None, None)
    elif kind in ("ffn", "logits"):
        spec = PS(dpx, None, "model" if x.shape[-1] % ms == 0 else None)
    elif kind == "experts":
        spec = PS(None, dp if x.shape[1] > 1 else None, None, None)
    else:
        raise ValueError(kind)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_sharding(
    mesh: Mesh, spec_tree, rules: AxisRules | None = None, shapes_tree=None
):
    """Tree of NamedSharding from a tree of logical spec tuples.

    With ``shapes_tree`` (parallel tree of arrays/ShapeDtypeStructs), mesh
    axes are dropped from dimensions they do not divide — e.g. a 50280-row
    vocab table cannot split 16 ways, so its ``tp`` axis is demoted to
    replication rather than failing at lower time (exact configs from the
    assignment keep their odd vocab sizes).
    """
    rules = rules or default_rules(mesh)
    is_spec = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x
    )

    def axes_size(ax) -> int:
        if ax is None:
            return 1
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n

    def resolve(t, shape=None):
        spec = list(logical_to_spec(t, rules))
        if shape is not None:
            dims = shape.shape if hasattr(shape, "shape") else shape
            for i, ax in enumerate(spec):
                if ax is not None and dims[i] % axes_size(ax) != 0:
                    spec[i] = None
        return NamedSharding(mesh, PS(*spec))

    if shapes_tree is None:
        return jax.tree.map(resolve, spec_tree, is_leaf=is_spec)
    return jax.tree.map(resolve, spec_tree, shapes_tree, is_leaf=is_spec)


def batch_sharding(mesh: Mesh, batch_like, rules: AxisRules | None = None):
    """Shard every batch leaf on its leading (batch) dim over the DP axes."""
    rules = rules or default_rules(mesh)
    dp = rules["dp"]

    def spec_for(x):
        nd = x.ndim if hasattr(x, "ndim") else len(x.shape)
        return NamedSharding(mesh, PS(dp, *([None] * (nd - 1))))

    return jax.tree.map(spec_for, batch_like)


def cache_sharding(
    mesh: Mesh,
    cache_like,
    n_kv_heads: int,
    batch: int,
    rules: AxisRules | None = None,
):
    """Decode-cache shardings.

    KV tensors are [L, B, T, Kv, hd]:
      * B over DP axes when it divides;
      * Kv over ``model`` when it divides, else T over ``model``
        (sequence-parallel cache — the long_500k path);
      * when B == 1 (long-context), T additionally over the DP axes.
    SSM states are [L, B, H, N, P]: B over DP, H over model when divisible.
    """
    rules = rules or default_rules(mesh)
    model_size = mesh.shape["model"]
    dp_axes = rules["dp"]
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]

    def spec_for_path(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = x.ndim if hasattr(x, "ndim") else len(x.shape)
        if name in ("k", "v"):
            b_ax = dp_axes if batch % dp_size == 0 and batch > 1 else None
            if n_kv_heads % model_size == 0:
                spec = PS(None, b_ax, None, "model", None)
            elif batch == 1:
                spec = PS(None, None, (*dp_axes, "model"), None, None)
            else:
                spec = PS(None, b_ax, "model", None, None)
            return NamedSharding(mesh, spec)
        if name == "ssm" and nd == 5:
            b_ax = dp_axes if batch % dp_size == 0 and batch > 1 else None
            h_ax = "model" if x.shape[2] % model_size == 0 else None
            return NamedSharding(mesh, PS(None, b_ax, h_ax, None, None))
        if name == "conv" and nd == 4:
            b_ax = dp_axes if batch % dp_size == 0 and batch > 1 else None
            c_ax = "model" if x.shape[3] % model_size == 0 else None
            return NamedSharding(mesh, PS(None, b_ax, None, c_ax))
        return NamedSharding(mesh, PS(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(spec_for_path, cache_like)
