from .collectives import (  # noqa: F401
    flash_decode_combine,
    local_partial_attention,
    pipeline_stage_step,
)
from .elastic import StepWatchdog, reshard_tree  # noqa: F401
from .sharding import (  # noqa: F401
    AxisRules,
    batch_sharding,
    cache_sharding,
    default_rules,
    logical_to_spec,
    param_sharding,
)
