"""Explicit collective patterns used by §Perf optimizations.

* :func:`flash_decode_combine` — distributed partial-softmax combine: each
  shard attends over its slice of a sequence-sharded KV cache and the
  (m, l, o) triples are merged with max/sum reductions — flash-decoding
  mapped onto mesh collectives.  This replaces the XLA-chosen
  gather-then-softmax schedule for ``long_500k`` (collective-bound baseline).
* :func:`pipeline_stage_step` — GPipe-style microbatch rotation over a mesh
  axis with ``ppermute`` (optional PP across the ``pod`` axis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["local_partial_attention", "flash_decode_combine", "pipeline_stage_step"]


def local_partial_attention(q, k_shard, v_shard, valid):
    """Per-shard partial attention.

    q: [B, H, 1, hd]; k_shard/v_shard: [B, H, T_local, hd];
    valid: [B, T_local] bool.  Returns (m, l, o) partials.
    """
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhtd->bhqt", q, k_shard).astype(jnp.float32) * scale
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = s.max(axis=-1)  # [B,H,1]
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bhqt,bhtd->bhqd", p.astype(q.dtype), v_shard)
    return m, l, o


def flash_decode_combine(m, l, o, axis_name: str):
    """Merge per-shard (m, l, o) softmax partials over ``axis_name``."""
    m_g = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * corr, axis_name)
    o_g = jax.lax.psum(o * corr[..., None].astype(o.dtype), axis_name)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None].astype(o_g.dtype)


def pipeline_stage_step(fn, x, axis_name: str):
    """One GPipe rotation: apply this stage's ``fn`` then shift activations
    to the next stage along ``axis_name`` (ring ppermute)."""
    y = fn(x)
    n = jax.lax.axis_size(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    return jax.lax.ppermute(y, axis_name, perm)
