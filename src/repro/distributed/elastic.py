"""Elastic scaling + straggler mitigation hooks.

Elasticity: checkpoints are saved unsharded (gathered), so scaling in/out is
"restore onto the new mesh" — :func:`reshard_tree` places a host tree onto
any mesh via the same logical rules.  The data pipeline is a pure function
of the step counter, so a re-sharded restart replays the identical global
batch stream (``tests/test_elastic.py`` proves bitwise-identical batches
across data-parallel widths).

Straggler mitigation: a real multi-host deployment cannot observe peers'
progress from inside jit — :class:`StepWatchdog` wraps the host-side loop:
it tracks a robust (median + MAD) step-time envelope and fires a callback
when the current step exceeds the deadline, which the launcher maps to
"checkpoint-and-evict" (see ``launch/train.py --straggler-policy``).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import jax

from .sharding import AxisRules, param_sharding

__all__ = ["reshard_tree", "StepWatchdog"]


def reshard_tree(host_tree, spec_tree, mesh, rules: AxisRules | None = None):
    """Place a host (numpy) tree onto ``mesh`` under logical specs."""
    sh = param_sharding(mesh, spec_tree, rules)
    return jax.tree.map(jax.device_put, host_tree, sh)


@dataclass
class StepWatchdog:
    """Deadline-based straggler detector for the host training loop."""

    factor: float = 3.0  # deadline = median + factor * MAD (+ floor)
    floor_s: float = 1.0
    history: list = field(default_factory=list)
    max_history: int = 64
    fired: int = 0

    def observe(self, dt: float) -> None:
        self.history.append(dt)
        if len(self.history) > self.max_history:
            self.history.pop(0)

    def deadline(self) -> float:
        if len(self.history) < 3:
            return float("inf")
        h = sorted(self.history)
        med = h[len(h) // 2]
        mad = sorted(abs(x - med) for x in h)[len(h) // 2]
        return med + self.factor * max(mad, 1e-3) + self.floor_s

    def guard(self, step_fn, *args, on_straggler=None, **kw):
        """Run one step; if it exceeds the deadline, invoke the callback
        (which in production checkpoints + re-meshes without the slow host)."""
        deadline = self.deadline()
        done = threading.Event()
        result: list = []

        def runner():
            result.append(step_fn(*args, **kw))
            done.set()

        t0 = time.monotonic()
        th = threading.Thread(target=runner, daemon=True)
        th.start()
        fired_here = False
        while not done.wait(timeout=0.05):
            if time.monotonic() - t0 > deadline and not fired_here:
                fired_here = True
                self.fired += 1
                if on_straggler is not None:
                    on_straggler(time.monotonic() - t0, deadline)
        th.join()
        self.observe(time.monotonic() - t0)
        return result[0]
