from .adamw import (  # noqa: F401
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_schedule,
    global_norm,
)
from .compress import (  # noqa: F401
    compressed_psum,
    ef_roundtrip,
    ef_state_init,
    int8_compress,
    int8_decompress,
    topk_compress,
    topk_decompress,
)
