"""Gradient compression for slow inter-pod links (beyond-paper).

Two standard schemes with **error feedback** (the residual of the lossy
round-trip is added back into the next step, which is what keeps convergence
unchanged in practice):

* int8 quantization with per-tensor scale (≈4x over fp32 wire format);
* magnitude top-k sparsification (k as a fraction).

Intended use: a ``shard_map``-level DP all-reduce over the ``pod`` axis
compresses before ``psum`` and decompresses after; ``compressed_psum`` shows
the pattern.  Pure-pjit training lets XLA pick the collectives, so this path
is opt-in (``--grad-compress``) for deployments where the pod interconnect
is the bottleneck (§Perf discusses when that trade wins).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "ef_state_init",
    "int8_compress",
    "int8_decompress",
    "topk_compress",
    "topk_decompress",
    "ef_roundtrip",
    "compressed_psum",
]


def ef_state_init(grads):
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def int8_compress(x):
    x = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def topk_compress(x, frac: float):
    x = x.astype(jnp.float32)
    flat = x.reshape(-1)
    k = max(1, int(flat.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    return kept, idx, x.shape


def topk_decompress(kept, idx, shape):
    flat = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), jnp.float32)
    flat = flat.at[idx].set(kept)
    return flat.reshape(shape)


def ef_roundtrip(g, err, scheme: str = "int8", frac: float = 0.01):
    """One error-feedback compression round-trip for a single tensor.

    Returns (decompressed value to feed the optimizer/all-reduce,
    new error residual).
    """
    corrected = g.astype(jnp.float32) + err
    if scheme == "int8":
        q, s = int8_compress(corrected)
        approx = int8_decompress(q, s)
    elif scheme == "topk":
        kept, idx, shape = topk_compress(corrected, frac)
        approx = topk_decompress(kept, idx, shape)
    else:
        raise ValueError(scheme)
    return approx, corrected - approx


def compressed_psum(grads, err_state, axis_name: str, scheme="int8", frac=0.01):
    """Error-feedback compressed all-reduce (use inside shard_map).

    Each shard compresses (grad + residual), the *compressed representation*
    is what crosses the wire (psum of the dequantized int8 values — on real
    interconnects the int8 payload is 4x smaller; XLA models this as the
    reduced tensor), and the residual stays local.
    """
    def one(g, e):
        approx, new_e = ef_roundtrip(g, e, scheme, frac)
        return jax.lax.psum(approx, axis_name), new_e

    flat_g, td = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(td, [o[0] for o in out]),
        jax.tree.unflatten(td, [o[1] for o in out]),
    )
