"""AdamW with global-norm clipping and schedules — pure-pytree, pjit-friendly.

State layout mirrors the parameter tree (so the same sharding specs apply:
ZeRO — optimizer state is sharded exactly like its parameter).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm", "cosine_schedule"]


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | linear | constant


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def cosine_schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        decay = 1.0 - frac
    else:
        frac = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = cosine_schedule(step.astype(jnp.float32), cfg)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gn, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
