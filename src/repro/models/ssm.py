"""Mamba-2 (SSD — state-space duality) block, chunked-scan training form and
O(1)-state decode form  [arXiv:2405.21060].

The chunked SSD algorithm decomposes the sequence into chunks of length Q:
the intra-chunk term is a small attention-like quadratic contraction, and
chunk-to-chunk information flows through an ``[H, N, P]`` state carried by a
``lax.scan`` — this is the TPU-friendly formulation (dense MXU einsums per
chunk, one sequential scan over S/Q steps instead of S).

Decode maintains ``(conv_state [B, d_conv-1, CH], ssm_state [B, H, N, P])``
per layer and costs O(1) per token — this is what makes ``long_500k``
tractable for the ssm/hybrid architectures.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from .layers import P, dense, dense_init, rmsnorm, rmsnorm_init

__all__ = ["ssm_init", "ssm_apply", "ssm_decode", "ssm_state_shapes"]


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    n_groups = 1
    conv_ch = d_inner + 2 * n_groups * s.d_state
    return d_inner, n_heads, n_groups, conv_ch


def ssm_init(key, cfg, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, n_groups, conv_ch = _dims(cfg)
    d_in_proj = 2 * d_inner + 2 * n_groups * s.d_state + n_heads
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], d, d_in_proj, ("fsdp", "tp"), dtype=dtype),
        "conv_w": P(
            (jax.random.normal(ks[1], (s.d_conv, conv_ch), jnp.float32) * 0.2).astype(
                dtype
            ),
            (None, "tp"),
        ),
        "conv_b": P(jnp.zeros((conv_ch,), dtype), ("tp",)),
        "A_log": P(
            jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32), (None,)
        ),
        "D": P(jnp.ones((n_heads,), jnp.float32), (None,)),
        "dt_bias": P(jnp.zeros((n_heads,), jnp.float32), (None,)),
        "norm": rmsnorm_init(d_inner, dtype),
        "out_proj": dense_init(
            ks[2], d_inner, d, ("tp", "fsdp"), dtype=dtype, scale=d_inner**-0.5
        ),
    }


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    d_inner, n_heads, n_groups, conv_ch = _dims(cfg)
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_ch], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal 1-D conv: xBC [B,S,CH], w [K,CH]."""
    k = w.shape[0]
    x_pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(k):  # K is 4 — static unroll beats conv for depthwise
        out = out + x_pad[:, i : i + xBC.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def ssm_apply(p, x, cfg, unroll: int = 1):
    """x: [B, S, D] → [B, S, D] (training / prefill)."""
    s_cfg = cfg.ssm
    b, seq, d = x.shape
    d_inner, n_heads, n_groups, conv_ch = _dims(cfg)
    hd, n = s_cfg.head_dim, s_cfg.d_state
    q = min(s_cfg.chunk, seq)
    assert seq % q == 0, "sequence must be divisible by SSD chunk"
    nc = seq // q

    z, xBC, dt = _split_proj(cfg, dense(p["in_proj"], x))
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xh, B_ssm, C_ssm = jnp.split(xBC, [d_inner, d_inner + n_groups * n], axis=-1)
    xh = xh.reshape(b, seq, n_heads, hd)
    B_ssm = B_ssm.reshape(b, seq, n_groups, n)
    C_ssm = C_ssm.reshape(b, seq, n_groups, n)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    a = -jnp.exp(p["A_log"])  # [H] negative
    da = dt * a  # [B,S,H] log-decay per step
    xdt = xh.astype(jnp.float32) * dt[..., None]  # [B,S,H,P]

    # chunk views (scan axis first)
    def chunked(t, extra_dims):
        return t.reshape((b, nc, q) + extra_dims).swapaxes(0, 1)

    da_c = chunked(da, (n_heads,))  # [nc,B,q,H]
    xdt_c = chunked(xdt, (n_heads, hd))
    b_c = chunked(B_ssm.astype(jnp.float32), (n_groups, n))[..., 0, :]
    c_c = chunked(C_ssm.astype(jnp.float32), (n_groups, n))[..., 0, :]
    mask = jnp.tril(jnp.ones((q, q), bool))

    def scan_step(state, inp):
        """Whole SSD chunk inside the scan body: the [q, q, H] decay matrix
        is live for only one chunk at a time (peak-memory bound)."""
        da_k, xdt_k, b_k, c_k = inp  # [B,q,H], [B,q,H,P], [B,q,N], [B,q,N]
        csum = jnp.cumsum(da_k, axis=1)  # [B,q,H]
        li = csum[:, :, None, :] - csum[:, None, :, :]  # [B,q,q,H]
        # mask BEFORE exp: li > 0 for the (masked) j > i entries can
        # overflow, and where(mask, inf, 0) still NaNs the backward pass
        li = jnp.where(mask[None, :, :, None], li, -jnp.inf)
        L = jnp.exp(li)
        scores = jnp.einsum("bin,bjn->bij", c_k, b_k)  # [B,q,q]
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", scores, L, xdt_k)
        in_decay = jnp.exp(csum)  # decay from chunk start to step i
        y_inter = jnp.einsum("bin,bih,bhnp->bihp", c_k, in_decay, state)
        decay_to_end = jnp.exp(csum[:, -1:, :] - csum)  # [B,q,H]
        s_chunk = jnp.einsum("bjn,bjh,bjhp->bhnp", b_k, decay_to_end, xdt_k)
        new_state = state * jnp.exp(csum[:, -1, :])[:, :, None, None] + s_chunk
        return new_state, y_intra + y_inter

    # accounting safety valve: fully unrolling hundreds of chunks explodes
    # compile time while the scan body is <1% of SSM FLOPs (projections
    # dominate) — cap the unroll and accept the tiny undercount.
    if unroll is True and nc > 64:
        unroll = 1
    init = jnp.zeros((b, n_heads, n, hd), jnp.float32)
    _, y_c = jax.lax.scan(scan_step, init, (da_c, xdt_c, b_c, c_c), unroll=unroll)
    y = y_c.swapaxes(0, 1).reshape(b, seq, n_heads, hd)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, seq, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return hint(dense(p["out_proj"], y), "hidden")


def ssm_state_shapes(cfg, batch):
    s = cfg.ssm
    d_inner, n_heads, n_groups, conv_ch = _dims(cfg)
    return (
        (batch, s.d_conv - 1, conv_ch),  # conv state
        (batch, n_heads, s.d_state, s.head_dim),  # ssm state
    )


def ssm_decode(p, x, cfg, conv_state, ssm_state):
    """One-token decode.  x: [B, 1, D] → (y, conv_state, ssm_state)."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    d_inner, n_heads, n_groups, conv_ch = _dims(cfg)
    hd, n = s_cfg.head_dim, s_cfg.d_state

    z, xBC, dt = _split_proj(cfg, dense(p["in_proj"], x))
    xBC = xBC[:, 0]  # [B,CH]
    window = jnp.concatenate([conv_state, xBC[:, None, :]], axis=1)  # [B,K,CH]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    xBC = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    new_conv_state = window[:, 1:]

    xh, B_ssm, C_ssm = jnp.split(xBC, [d_inner, d_inner + n_groups * n], axis=-1)
    xh = xh.reshape(b, n_heads, hd).astype(jnp.float32)
    B_ssm = B_ssm.reshape(b, n)[:, None, :].astype(jnp.float32)  # G=1 → [B,1,N]
    C_ssm = C_ssm.reshape(b, n)[:, None, :].astype(jnp.float32)

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt1 * a)  # [B,H]
    xdt = xh * dt1[..., None]  # [B,H,P]
    new_state = ssm_state * decay[:, :, None, None] + jnp.einsum(
        "bgn,bhp->bhnp", B_ssm, xdt
    )
    y = jnp.einsum("bgn,bhnp->bhp", C_ssm, new_state)  # [B,H,P]
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return hint(dense(p["out_proj"], y), "hidden"), new_conv_state, new_state
