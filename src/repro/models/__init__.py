from .model import (  # noqa: F401
    decode_step,
    forward,
    init_caches,
    init_model,
    lm_loss,
    prefill,
)
