"""Parameter plumbing + basic layers (norms, MLP, RoPE, embeddings).

No flax/haiku — parameters are plain pytrees of :class:`P` leaves carrying
``(value, partition-spec)`` so sharding is declared where the parameter is
created.  ``split_params`` separates the value tree from the logical-spec
tree; ``repro.distributed.sharding`` maps logical axes to mesh axes.

Logical axes:
  ``fsdp``  — parameter dimension sharded ZeRO-3 style over the data axis
  ``tp``    — tensor-parallel dimension over the model axis
  ``None``  — replicated
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "P",
    "split_params",
    "dense_init",
    "dense",
    "rmsnorm_init",
    "rmsnorm",
    "layernorm_init",
    "layernorm",
    "mlp_init",
    "mlp",
    "embed_init",
    "rope_freqs",
    "apply_rope",
]


class P(NamedTuple):
    value: Any
    spec: tuple  # logical partition per dim, e.g. ("fsdp", "tp")


def split_params(tree):
    """(values, logical_specs) from a tree of :class:`P` leaves."""
    is_p = lambda x: isinstance(x, P)
    vals = jax.tree.map(lambda p: p.value, tree, is_leaf=is_p)
    specs = jax.tree.map(lambda p: p.spec, tree, is_leaf=is_p)
    return vals, specs


def _init_matrix(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense_init(key, d_in, d_out, spec, bias=False, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else d_in**-0.5
    p = {"w": P(_init_matrix(key, (d_in, d_out), scale, dtype), spec)}
    if bias:
        p["b"] = P(jnp.zeros((d_out,), dtype), (spec[-1],))
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d, dtype=jnp.float32):
    return {"g": P(jnp.ones((d,), dtype), (None,))}


def rmsnorm(p, x, eps=1e-6):
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * p["g"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d, dtype=jnp.float32):
    return {
        "g": P(jnp.ones((d,), dtype), (None,)),
        "b": P(jnp.zeros((d,), dtype), (None,)),
    }


def layernorm(p, x, eps=1e-6):
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean((h - mu) ** 2, axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    return (h * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(
        x.dtype
    )


def mlp_init(key, d_model, d_ff, act="swiglu", dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "up": dense_init(ks[0], d_model, d_ff, ("fsdp", "tp"), dtype=dtype),
        "down": dense_init(
            ks[1], d_ff, d_model, ("tp", "fsdp"), dtype=dtype, scale=d_ff**-0.5
        ),
    }
    if act == "swiglu":
        p["gate"] = dense_init(ks[2], d_model, d_ff, ("fsdp", "tp"), dtype=dtype)
    return p


def mlp(p, x, act="swiglu"):
    up = dense(p["up"], x)
    if act == "swiglu":
        h = jax.nn.silu(dense(p["gate"], x)) * up
    else:
        h = jax.nn.gelu(up)
    return dense(p["down"], h)


def embed_init(key, vocab, d, dtype=jnp.float32):
    # N(0, 1/sqrt(d)) keeps tied-head logits O(1) at init
    return {
        "table": P(
            _init_matrix(key, (vocab, d), d**-0.5, dtype), ("tp", "fsdp")
        )
    }


# ----------------------------- RoPE ---------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, hd]; positions: [B, S] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, hd/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
