"""Full model assembly: embeddings / modality frontends, layer stack, head,
loss, and the train / prefill / decode step functions the launcher jits.

Batch conventions (see ``repro.launch.dryrun`` input_specs):

* decoder LMs:   ``{"tokens": [B, S] int32}``; labels are tokens shifted.
* VLM:           ``+ {"patch_embeds": [B, Np, D]}`` (frontend stub) —
                 patches are prepended to the text embeddings.
* audio encoder: ``{"frames": [B, T, F] , "labels": [B, T] int32}``
                 (conv feature-extractor stub; encoder-only, CE per frame).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distributed.sharding import hint
from .blocks import init_caches, layer_windows, stack_apply, stack_decode, stack_init
from .layers import P, dense, dense_init, embed_init, rmsnorm, rmsnorm_init, split_params

__all__ = [
    "init_model",
    "model_specs",
    "forward",
    "lm_loss",
    "prefill",
    "decode_step",
    "init_caches",
]


def init_model(key, cfg: ArchConfig, dtype=jnp.float32):
    """Returns (param value tree, logical spec tree)."""
    ks = jax.random.split(key, 5)
    tree = {}
    specs = {}
    if cfg.frontend == "frames":
        proj = dense_init(ks[0], cfg.frontend_dim, cfg.d_model, ("fsdp", "tp"), True, dtype)
        v, s = split_params(proj)
        tree["frontend_proj"], specs["frontend_proj"] = v, s
    emb = embed_init(ks[1], cfg.vocab_padded, cfg.d_model, dtype)
    v, s = split_params(emb)
    tree["embed"], specs["embed"] = v, s
    stack_vals, stack_specs = stack_init(ks[2], cfg, dtype)
    tree["layers"], specs["layers"] = stack_vals, stack_specs
    fn = rmsnorm_init(cfg.d_model, dtype)
    v, s = split_params(fn)
    tree["final_norm"], specs["final_norm"] = v, s
    if not cfg.tie_embeddings:
        head = dense_init(
            ks[3], cfg.d_model, cfg.vocab_padded, ("fsdp", "tp"), False, dtype,
            scale=cfg.d_model**-0.5,
        )
        v, s = split_params(head)
        tree["head"], specs["head"] = v, s
    return tree, specs


def _embed_tokens(params, tokens, cfg):
    emb = params["embed"]["table"]
    x = jnp.take(emb, tokens, axis=0)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x


def _head(params, h, cfg):
    logits = (
        h @ params["embed"]["table"].T
        if cfg.tie_embeddings
        else dense(params["head"], h)
    )
    if cfg.vocab_padded != cfg.vocab:  # mask padding ids
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


def forward(params, batch, cfg: ArchConfig, *, mode="auto", chunk=512, unroll=1, layer_unroll=1):
    """Full-sequence forward.  Returns (logits [B, S, V], aux_loss)."""
    if cfg.frontend == "frames":
        x = dense(params["frontend_proj"], batch["frames"])
    else:
        x = _embed_tokens(params, batch["tokens"], cfg)
        if cfg.frontend == "patch":
            pe = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([pe, x], axis=1)
    x = hint(x, "hidden")
    h, aux = stack_apply(params["layers"], x, cfg, mode=mode, chunk=chunk,
                         unroll=unroll, layer_unroll=layer_unroll)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = hint(_head(params, h, cfg), "logits")
    return logits, aux


def lm_loss(params, batch, cfg: ArchConfig, *, mode="auto", chunk=512,
            unroll=1, layer_unroll=1, aux_weight=0.01):
    """Cross-entropy loss (next-token for decoders, per-frame for encoders)."""
    logits, aux = forward(params, batch, cfg, mode=mode, chunk=chunk,
                          unroll=unroll, layer_unroll=layer_unroll)
    logits = logits.astype(jnp.float32)
    if cfg.encoder_only:
        labels = batch["labels"]
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
        loss = nll.mean()
    else:
        tokens = batch["tokens"]
        if cfg.frontend == "patch":
            # logits for text positions start after the patch prefix
            np_ = batch["patch_embeds"].shape[1]
            logits = logits[:, np_:, :]
        lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        loss = nll.mean()
    return loss + aux_weight * aux, (loss, aux)


# --------------------------------------------------------------------------- #
# Serving
# --------------------------------------------------------------------------- #
def prefill(params, batch, cfg: ArchConfig, max_len: int, *, mode="auto", chunk=512, unroll=1, layer_unroll=1):
    """Run the prompt through the stack; returns (last-token logits, caches).

    For the dry-run we lower prefill as a pure forward (logits only) —
    cache construction is exercised by ``decode_step`` which owns the cache
    layout; a fused prefill+cache write is a §Perf follow-up.
    """
    logits, _ = forward(params, batch, cfg, mode=mode, chunk=chunk,
                        unroll=unroll, layer_unroll=layer_unroll)
    return logits[:, -1:, :]


def decode_step(params, token, caches, cur_len, cfg: ArchConfig, layer_unroll=1):
    """One decode step.

    token: [B, 1] int32; caches: stacked per-layer dict; cur_len: int32
    scalar (same position for all layers).  Returns (logits [B, 1, V],
    new caches).
    """
    if "len" in caches:
        caches = {**caches, "len": jnp.full((cfg.n_layers,), cur_len, jnp.int32)}
    x = _embed_tokens(params, token, cfg)
    h, new_caches = stack_decode(params["layers"], x, cfg, caches, layer_unroll)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _head(params, h, cfg)
    return logits, new_caches
