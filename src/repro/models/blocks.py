"""Per-layer blocks (dense / MoE / SSM / hybrid) + the scan-over-layers stack.

All layers of one architecture share parameter shapes, so the whole stack is
a single ``lax.scan`` over weights stacked on a leading layer axis — this
keeps HLO size and compile time flat in depth (80-layer qwen-110b compiles
as fast as 2 layers) and is what makes the 512-device dry-run tractable.
Per-layer attention kind (gemma3's 5 local : 1 global) rides along as a
scanned int32 window array rather than Python branching.

Remat: ``cfg.remat`` ∈ {nothing, dots, full} wraps the scan body with
``jax.checkpoint`` so the big configs fit v5e HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distributed.sharding import hint
from .attention import attn_init, attention_block, decode_attention_block
from .layers import mlp, mlp_init, rmsnorm, rmsnorm_init, split_params
from .moe import moe_apply, moe_init
from .ssm import ssm_apply, ssm_decode, ssm_init, ssm_state_shapes

__all__ = [
    "layer_init",
    "stack_init",
    "stack_apply",
    "stack_decode",
    "layer_windows",
    "init_caches",
]

GLOBAL_WINDOW = jnp.int32(1 << 30)  # "no window" sentinel


def layer_init(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 8)
    p = {}
    if cfg.family == "ssm":
        p["norm1"] = rmsnorm_init(cfg.d_model, dtype)
        p["ssm"] = ssm_init(ks[0], cfg, dtype)
        if cfg.d_ff:
            p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)
        return p
    p["norm1"] = rmsnorm_init(cfg.d_model, dtype)
    p["attn"] = attn_init(ks[0], cfg, dtype)
    if cfg.family == "hybrid":
        p["ssm"] = ssm_init(ks[1], cfg, dtype)
        p["branch_norm_attn"] = rmsnorm_init(cfg.d_model, dtype)
        p["branch_norm_ssm"] = rmsnorm_init(cfg.d_model, dtype)
    p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
    if cfg.moe is not None:
        p["moe"] = moe_init(ks[2], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)
    return p


def layer_apply(p, x, cfg: ArchConfig, window, *, mode="auto", chunk=512, unroll=1):
    """One block, full sequence.  Returns (y, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        x = x + ssm_apply(p["ssm"], rmsnorm(p["norm1"], x, cfg.norm_eps), cfg, unroll)
        if cfg.d_ff:
            x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps), cfg.mlp_act)
        return x, aux
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    a = attention_block(p["attn"], h, cfg, window=window, mode=mode, chunk=chunk, unroll=unroll)
    if cfg.family == "hybrid":
        s = ssm_apply(p["ssm"], h, cfg, unroll)
        a = 0.5 * (
            rmsnorm(p["branch_norm_attn"], a, cfg.norm_eps)
            + rmsnorm(p["branch_norm_ssm"], s, cfg.norm_eps)
        )
    x = x + a
    h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = moe_apply(p["moe"], h2, cfg)
    else:
        y = mlp(p["mlp"], h2, cfg.mlp_act)
    return x + y, aux


def layer_decode(p, x, cfg: ArchConfig, window, cache):
    """One block, one token.  cache is this layer's slice."""
    aux_cache = {}
    if cfg.family == "ssm":
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, conv_s, ssm_s = ssm_decode(p["ssm"], h, cfg, cache["conv"], cache["ssm"])
        x = x + y
        if cfg.d_ff:
            x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps), cfg.mlp_act)
        return x, {"conv": conv_s, "ssm": ssm_s}
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    a, ck, cv = decode_attention_block(
        p["attn"], h, cfg, cache["k"], cache["v"], cache["len"], window=window
    )
    new_cache = {"k": ck, "v": cv, "len": cache["len"] + 1}
    if cfg.family == "hybrid":
        y, conv_s, ssm_s = ssm_decode(p["ssm"], h, cfg, cache["conv"], cache["ssm"])
        a = 0.5 * (
            rmsnorm(p["branch_norm_attn"], a, cfg.norm_eps)
            + rmsnorm(p["branch_norm_ssm"], y, cfg.norm_eps)
        )
        new_cache["conv"] = conv_s
        new_cache["ssm"] = ssm_s
    x = x + a
    h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
    if cfg.moe is not None:
        y2, _ = moe_apply(p["moe"], h2, cfg)
    else:
        y2 = mlp(p["mlp"], h2, cfg.mlp_act)
    return x + y2, new_cache


# --------------------------------------------------------------------------- #
# Stack (scan over layers)
# --------------------------------------------------------------------------- #
def layer_windows(cfg: ArchConfig) -> jnp.ndarray:
    """Per-layer effective attention window (int32; huge sentinel = global)."""
    wins = []
    for kind in cfg.layer_kinds():
        if kind == "local":
            wins.append(cfg.window)
        else:
            wins.append(1 << 30)
    return jnp.asarray(wins, jnp.int32)


def stack_init(key, cfg: ArchConfig, dtype):
    """Stacked layer params: (values pytree with leading L axis, spec tree)."""
    keys = jax.random.split(key, cfg.n_layers)
    _, specs = split_params(layer_init(keys[0], cfg, dtype))
    specs = jax.tree.map(
        lambda t: (None,) + tuple(t), specs, is_leaf=lambda x: isinstance(x, tuple)
    )
    vals = jax.vmap(
        lambda k: split_params(layer_init(k, cfg, dtype))[0]
    )(keys)
    return vals, specs


def _remat_wrap(fn, remat: str):
    if remat == "nothing":
        return fn
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


def stack_apply(stacked_vals, x, cfg: ArchConfig, *, mode="auto", chunk=512,
                unroll=1, layer_unroll=1):
    """Run all layers; returns (hidden, total_aux_loss).

    ``layer_unroll=True`` fully unrolls the layer scan (and ``unroll`` the
    inner chunk scans) — the dry-run cost-accounting variant, since XLA
    cost analysis counts a while-loop body once regardless of trip count.
    """
    windows = layer_windows(cfg)

    def body(carry, layer):
        h, aux = carry
        lp, win = layer
        h, a = layer_apply(lp, h, cfg, win, mode=mode, chunk=chunk, unroll=unroll)
        return (hint(h, "hidden"), aux + a), None

    body = _remat_wrap(body, cfg.remat)
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stacked_vals, windows),
        unroll=layer_unroll,
    )
    return x, aux


def stack_decode(stacked_vals, x, cfg: ArchConfig, caches, layer_unroll=1):
    """One-token decode through all layers; caches have leading L axis."""
    windows = layer_windows(cfg)

    def body(h, layer):
        lp, win, cache = layer
        h, new_cache = layer_decode(lp, h, cfg, win, cache)
        return h, new_cache

    x, new_caches = jax.lax.scan(
        body, x, (stacked_vals, windows, caches), unroll=layer_unroll
    )
    return x, new_caches


def init_caches(cfg: ArchConfig, batch: int, max_len: int, dtype):
    """Decode caches with leading layer axis."""
    L = cfg.n_layers
    cache = {}
    if cfg.family != "ssm":
        cache["k"] = jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype)
        cache["v"] = jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype)
        cache["len"] = jnp.zeros((L,), jnp.int32)
    if cfg.family in ("ssm", "hybrid"):
        conv_shape, ssm_shape = ssm_state_shapes(cfg, batch)
        cache["conv"] = jnp.zeros((L,) + conv_shape, dtype)
        cache["ssm"] = jnp.zeros((L,) + ssm_shape, jnp.float32)
    return cache
