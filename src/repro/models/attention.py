"""GQA attention: naive-dot and chunked online-softmax ("flash at XLA
level"), sliding-window masking, KV-cache decode, optional QKV bias.

Layout: heads stay FLAT ([B, S, H, hd]; KV repeated to H for GQA) — the
grouped [B, S, Kv, G, hd] reshape defeats GSPMD head-sharding propagation.
``hint(...)`` calls pin the distribution strategy per shape:

* heads divisible by |model|  → tensor-parallel attention over heads;
* otherwise                   → sequence-parallel attention (q sharded on S,
  KV replicated) — the context-parallel fallback for 14/25/40-head configs
  on a 16-wide model axis.

The chunked path scans KV blocks carrying the running (max, sum, acc)
triple — the FlashAttention recurrence at XLA level, so peak score memory is
``[B, H, S_q, chunk]`` instead of ``[B, H, S_q, S_kv]`` for 32k prefill.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from .layers import apply_rope, dense, dense_init

__all__ = ["attn_init", "attention_block", "decode_attention_block"]

NEG_INF = -1e30


def attn_init(key, cfg, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "q": dense_init(ks[0], d, cfg.n_heads * hd, ("fsdp", "tp"), cfg.qkv_bias, dtype),
        "k": dense_init(ks[1], d, cfg.n_kv_heads * hd, ("fsdp", "tp"), cfg.qkv_bias, dtype),
        "v": dense_init(ks[2], d, cfg.n_kv_heads * hd, ("fsdp", "tp"), cfg.qkv_bias, dtype),
        "o": dense_init(ks[3], cfg.n_heads * hd, d, ("tp", "fsdp"), False, dtype),
    }


def _split_heads(x, n_heads, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, hd)


def _repeat_kv(x, n_heads):
    g = n_heads // x.shape[2]
    return jnp.repeat(x, g, axis=2) if g > 1 else x


def _mask_bias(q_pos, k_pos, causal, window):
    """[S_q, S_kv] additive bias.  ``window`` is a (possibly traced) int32
    scalar; global attention uses a huge sentinel so one code path serves
    gemma3-style mixed local/global stacks under lax.scan."""
    m = jnp.zeros((q_pos.shape[0], k_pos.shape[0]), jnp.float32)
    if causal:
        m = jnp.where(k_pos[None, :] > q_pos[:, None], NEG_INF, m)
    m = jnp.where(q_pos[:, None] - k_pos[None, :] >= window, NEG_INF, m)
    return m


def _dot_attention(q, k, v, bias):
    """q:[B,Sq,H,hd] k/v:[B,Skv,H,hd] bias:[Sq,Skv] → [B,Sq,H,hd]."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bthd->bhqt", q, k) * scale
    scores = hint(scores.astype(jnp.float32) + bias[None, None], "bhst")
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqt,bthd->bqhd", w, v)


def _chunked_attention(q, k, v, q_pos, k_pos, causal, window, chunk, unroll=1):
    """Online-softmax over KV chunks (flash recurrence via lax.scan)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    n_chunks = skv // chunk
    scale = hd**-0.5
    k_c = k.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    kp_c = k_pos.reshape(n_chunks, chunk)

    def step(carry, inp):
        m, l, acc = carry  # [B,H,Sq], [B,H,Sq], [B,Sq,H,hd]
        kc, vc, kpc = inp
        s = jnp.einsum("bqhd,bthd->bhqt", q, kc) * scale
        s = s.astype(jnp.float32) + _mask_bias(q_pos, kpc, causal, window)[None, None]
        s = hint(s, "bhst")
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc = acc * alpha.transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhqt,bthd->bqhd", p.astype(q.dtype), vc
        ).astype(jnp.float32)
        acc = hint(acc, "heads")
        return (m_new, l_new, acc), None

    if unroll is True and n_chunks > 64:  # accounting compile-time valve
        unroll = 1
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = hint(jnp.zeros((b, sq, h, hd), jnp.float32), "heads")
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (k_c, v_c, kp_c), unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def _causal_blocked_attention(q, k, v, q_pos, k_pos, causal, window, chunk,
                              unroll=1):
    """Triangular q-block schedule: query chunk ``qi`` attends only KV
    chunks ``<= qi`` (static python loop → static slice bounds), halving
    causal-attention FLOPs vs masking a full S x S sweep (§Perf)."""
    b, s, h, hd = q.shape
    assert s % chunk == 0, "causal_blocked needs seq divisible by chunk"
    nq = s // chunk
    outs = []
    for qi in range(nq):
        lo, hi = qi * chunk, (qi + 1) * chunk
        outs.append(
            _chunked_attention(
                q[:, lo:hi], k[:, :hi], v[:, :hi],
                q_pos[lo:hi], k_pos[:hi], causal, window, chunk, unroll,
            )
        )
    return jnp.concatenate(outs, axis=1)


def attention_block(
    p,
    x,
    cfg,
    *,
    window=None,
    positions=None,
    mode: str = "auto",
    chunk: int = 512,
    unroll: int = 1,
    return_kv: bool = False,
):
    """Full-sequence attention (training / prefill).

    x: [B, S, D].  ``window``: int32 scalar sliding-window size (huge
    sentinel ⇒ global attention); may be a traced per-layer value.  Returns
    [B, S, D] (and pre-repeat K/V when ``return_kv``).
    """
    b, s, d = x.shape
    hd = cfg.hd
    if window is None:
        window = jnp.int32(1 << 30)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q = _split_heads(dense(p["q"], x), cfg.n_heads, hd)
    k = _split_heads(dense(p["k"], x), cfg.n_kv_heads, hd)
    v = _split_heads(dense(p["v"], x), cfg.n_kv_heads, hd)
    q = hint(apply_rope(q, positions, cfg.rope_theta), "heads")
    k = apply_rope(k, positions, cfg.rope_theta)
    kv_keep = (k, v)
    k = hint(_repeat_kv(k, cfg.n_heads), "heads")
    v = hint(_repeat_kv(v, cfg.n_heads), "heads")

    causal = not cfg.encoder_only
    pos1 = jnp.arange(s, dtype=jnp.int32)
    if mode == "auto":
        mode = "dot" if s <= 2048 else "chunked"
    if mode == "dot":
        bias = _mask_bias(pos1, pos1, causal, window)
        out = _dot_attention(q, k, v, bias)
    elif mode == "causal_blocked" and causal and s % chunk == 0:
        out = _causal_blocked_attention(
            q, k, v, pos1, pos1, causal, window, chunk, unroll
        )
    else:
        pad = (-s) % chunk
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kp = jnp.concatenate([pos1, jnp.full((pad,), jnp.int32(-(10**9)))])
        else:
            kp = pos1
        out = _chunked_attention(q, k, v, pos1, kp, causal, window, chunk, unroll)
    out = hint(out.reshape(b, s, cfg.n_heads * hd), "ffn")
    y = hint(dense(p["o"], out), "hidden")
    if return_kv:
        return y, kv_keep
    return y


def decode_attention_block(p, x, cfg, cache_k, cache_v, cur_len, *, window=None):
    """Single-token decode against a fixed-size KV cache.

    x: [B, 1, D]; cache_k/v: [B, T, Kv, hd]; ``cur_len``: int32 scalar —
    tokens [0, cur_len) are valid, the new token is written at ``cur_len``.
    Returns (y [B,1,D], new_cache_k, new_cache_v).
    """
    b, _, d = x.shape
    hd = cfg.hd
    t = cache_k.shape[1]
    if window is None:
        window = jnp.int32(1 << 30)
    pos = jnp.full((b, 1), cur_len, jnp.int32)
    q = _split_heads(dense(p["q"], x), cfg.n_heads, hd)
    k = _split_heads(dense(p["k"], x), cfg.n_kv_heads, hd)
    v = _split_heads(dense(p["v"], x), cfg.n_kv_heads, hd)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, cur_len, 0, 0)
    )
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, cur_len, 0, 0)
    )
    g = cfg.n_heads // cfg.n_kv_heads
    kpos = jnp.arange(t, dtype=jnp.int32)
    valid = (kpos <= cur_len) & (kpos > cur_len - window)
    scale = hd**-0.5
    # grouped einsum against the *unrepeated* cache (decode is memory-bound:
    # never materialize a repeated 32k-long cache)
    qg = q.reshape(b, 1, cfg.n_kv_heads, g, hd)
    scores = jnp.einsum("bqkgd,btkd->bkgqt", qg, cache_k.astype(qg.dtype)) * scale
    scores = scores.astype(jnp.float32) + jnp.where(valid, 0.0, NEG_INF)[
        None, None, None, None, :
    ]
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgqt,btkd->bqkgd", w, cache_v.astype(x.dtype))
    y = dense(p["o"], out.reshape(b, 1, cfg.n_heads * hd))
    return hint(y, "hidden"), cache_k, cache_v
