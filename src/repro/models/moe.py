"""Mixture-of-Experts layer: GShard-style capacity dispatch, top-k routing,
shared experts (Qwen-MoE), load-balance aux loss.

Expert parallelism maps onto the mesh through the einsum operands: expert
weights are ``[E, D, F]`` with ``D → fsdp`` and ``F → tp``; the dispatch
one-hot keeps tokens grouped by their batch row so the dispatch einsums
shard over the data axis without resharding ("G" below = batch rows).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from .layers import P, dense, dense_init, mlp, mlp_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    f = m.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 6)
    scale_in, scale_out = d**-0.5, f**-0.5

    def expert_mat(k, shape, scale, spec):
        return P(
            (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype), spec
        )

    p = {
        "router": dense_init(ks[0], d, m.n_experts, (None, None), dtype=dtype),
        "gate_w": expert_mat(ks[1], (m.n_experts, d, f), scale_in, (None, "fsdp", "tp")),
        "up_w": expert_mat(ks[2], (m.n_experts, d, f), scale_in, (None, "fsdp", "tp")),
        "down_w": expert_mat(ks[3], (m.n_experts, f, d), scale_out, (None, "tp", "fsdp")),
    }
    if m.n_shared:
        # shared experts are dense MLPs applied to every token; fuse them
        # into one wide MLP (mathematically identical, one less einsum)
        p["shared"] = mlp_init(ks[4], d, m.n_shared * f, "swiglu", dtype)
        p["shared_gate"] = dense_init(ks[5], d, 1, (None, None), dtype=dtype)
    return p


def moe_apply(p, x, cfg):
    """x: [B, S, D] → (y, aux_loss).  Dispatch per ``cfg.moe.dispatch``:

    * ``einsum`` — GShard one-hot dispatch/combine einsums (baseline;
      simple, but the dispatch matmuls cost O(S·E·cap·D) FLOPs — for
      60-expert qwen2-moe they rival the expert FFNs themselves).
    * ``sorted`` — sort token-choices by expert, gather the first ``cap``
      per expert, scatter-add weighted outputs back: O(S·k log(S·k))
      integer work + pure data movement, no dispatch FLOPs (§Perf).
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    cap = max(1, int(s * k / e * m.capacity_factor))

    logits = dense(p["router"], x).astype(jnp.float32)  # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # [B,S,k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )

    # load-balance aux loss (Switch/GShard form)
    me = probs.mean(axis=(0, 1))  # [E]
    one_hot_top1 = jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    if getattr(m, "dispatch", "einsum") == "sorted":
        y = _sorted_dispatch(p, x, cfg, gate_vals, gate_idx, cap)
        if m.n_shared:
            y = y + mlp(p["shared"], x, "swiglu")
        return y, aux

    # position of each (token, choice) within its expert's capacity buffer
    dispatch = jnp.zeros((b, s, e, cap), dtype=x.dtype)
    combine = jnp.zeros((b, s, e, cap), dtype=jnp.float32)
    for choice in range(k):  # static unroll over top-k choices
        oh = jax.nn.one_hot(gate_idx[..., choice], e, dtype=jnp.float32)  # [B,S,E]
        pos = (jnp.cumsum(oh, axis=1) - oh) + combine_positions_base(combine)
        keep = (pos < cap) & (oh > 0)
        pos_c = jnp.clip(pos, 0, cap - 1).astype(jnp.int32)
        sel = jax.nn.one_hot(pos_c, cap, dtype=jnp.float32) * keep[..., None]
        contrib = oh[..., None] * sel  # [B,S,E,cap]
        dispatch = dispatch + contrib.astype(x.dtype)
        combine = combine + contrib * gate_vals[..., choice, None, None]

    xe = hint(jnp.einsum("bsec,bsd->ebcd", dispatch, x), "experts")  # [E,B,cap,D]
    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xe, p["gate_w"])) * jnp.einsum(
        "ebcd,edf->ebcf", xe, p["up_w"]
    )
    ye = jnp.einsum("ebcf,efd->ebcd", h, p["down_w"])  # [E,B,cap,D]
    y = hint(jnp.einsum("bsec,ebcd->bsd", combine.astype(x.dtype), ye), "hidden")

    if m.n_shared:
        y = y + mlp(p["shared"], x, "swiglu")
    return y, aux


def combine_positions_base(combine):
    """Occupied slots per expert so far across earlier top-k choices."""
    # combine > 0 marks taken (token, slot) cells; count per (B, E)
    taken = (combine > 0).astype(jnp.float32).sum(axis=(1, 3))  # [B, E]
    return taken[:, None, :]


def _expert_ffn(p, xe):
    """xe: [E, B, cap, D] → [E, B, cap, D] (SwiGLU expert MLPs)."""
    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xe, p["gate_w"])) * jnp.einsum(
        "ebcd,edf->ebcf", xe, p["up_w"]
    )
    return jnp.einsum("ebcf,efd->ebcd", h, p["down_w"])


def _sorted_dispatch(p, x, cfg, gate_vals, gate_idx, cap):
    """Gather/scatter MoE dispatch (sort tokens by expert, no one-hots)."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    sk = s * k
    eid = gate_idx.reshape(b, sk)  # expert of each (token, choice)
    tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None, :], (b, sk)
    )
    gate = gate_vals.reshape(b, sk)
    order = jnp.argsort(eid, axis=1, stable=True)
    eid_s = jnp.take_along_axis(eid, order, axis=1)
    tok_s = jnp.take_along_axis(tok, order, axis=1)
    gate_s = jnp.take_along_axis(gate, order, axis=1)
    # rank within expert = position - first position of that expert
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e)))(eid_s)
    first = jnp.take_along_axis(starts, eid_s, axis=1)  # [B, sk]
    rank = jnp.arange(sk, dtype=jnp.int32)[None, :] - first.astype(jnp.int32)
    keep = rank < cap
    slot = jnp.where(keep, eid_s * cap + rank, e * cap)  # overflow -> spill row

    bidx = jnp.arange(b)[:, None]
    gathered = jnp.take_along_axis(x, tok_s[..., None], axis=1)  # [B, sk, D]
    xe = jnp.zeros((b, e * cap + 1, d), x.dtype).at[bidx, slot].set(gathered)
    xe = xe[:, : e * cap].reshape(b, e, cap, d).transpose(1, 0, 2, 3)
    ye = _expert_ffn(p, xe)  # [E, B, cap, D]
    ye_flat = ye.transpose(1, 0, 2, 3).reshape(b, e * cap, d)
    ye_flat = jnp.concatenate(
        [ye_flat, jnp.zeros((b, 1, d), ye_flat.dtype)], axis=1
    )
    contrib = jnp.take_along_axis(ye_flat, slot[..., None], axis=1)
    w = jnp.where(keep, gate_s, 0.0).astype(x.dtype)[..., None]
    y = jnp.zeros_like(x).at[bidx, tok_s].add(contrib * w)
    return y
