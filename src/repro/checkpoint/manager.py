"""Fault-tolerant checkpointing: atomic, versioned, compressed, with
cross-mesh (elastic) restore.

Layout::

    <root>/step_00000420/manifest.json     # tree structure + dtypes/shapes + codec
    <root>/step_00000420/arrays.bin.zst    # concatenated raw buffers (or .zlib)
    <root>/LATEST                          # atomic pointer file

Writes go to ``<dir>.tmp`` then ``os.replace`` — a crash mid-save can never
corrupt the pointer or a previous checkpoint.  ``restore`` takes an optional
``(mesh, spec_tree)`` so a checkpoint written on one mesh restores onto a
differently-shaped mesh (elastic scaling): arrays are saved unsharded
(gathered), and resharding happens at ``device_put`` time.

The compression codec is pluggable: ``zstandard`` when installed (fast,
better ratio), stdlib ``zlib`` otherwise.  The codec used at save time is
recorded in the manifest, so checkpoints round-trip across environments with
and without ``zstandard`` — restore only fails if a ``zstd`` checkpoint is
opened where ``zstandard`` is genuinely missing.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover - depends on environment
    zstd = None

__all__ = ["CheckpointManager"]

_CODEC_EXT = {"zstd": "zst", "zlib": "zlib"}


def _default_codec() -> str:
    return "zstd" if zstd is not None else "zlib"


def _compress_stream(codec: str, f, chunks) -> None:
    if codec == "zstd":
        with zstd.ZstdCompressor(level=3).stream_writer(f) as w:
            for c in chunks:
                w.write(c)
    elif codec == "zlib":
        co = zlib.compressobj(6)
        for c in chunks:
            f.write(co.compress(c))
        f.write(co.flush())
    else:
        raise ValueError(f"unknown checkpoint codec {codec!r}")


def _decompress_bytes(codec: str, f) -> bytes:
    if codec == "zstd":
        if zstd is None:
            raise RuntimeError(
                "checkpoint was written with zstd but zstandard is not installed"
            )
        return zstd.ZstdDecompressor().stream_reader(f).read()
    if codec == "zlib":
        return zlib.decompress(f.read())
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, async_save: bool = False):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree, extra: dict | None = None) -> str:
        """Save a pytree of arrays (gathers to host first)."""
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {})
            )
            self._thread.start()
            return self._dir(step)
        self._write(step, host, extra or {})
        return self._dir(step)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def _write(self, step: int, host: dict, extra: dict) -> None:
        final = self._dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        codec = _default_codec()
        fn = f"arrays.bin.{_CODEC_EXT[codec]}"
        manifest = {"step": step, "extra": extra, "codec": codec, "file": fn,
                    "arrays": []}
        for k, a in host.items():
            manifest["arrays"].append(
                {"path": k, "dtype": str(a.dtype), "shape": list(a.shape)}
            )
        with open(os.path.join(tmp, fn), "wb") as f:
            _compress_stream(
                codec,
                f,
                (np.ascontiguousarray(a).tobytes() for a in host.values()),
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        # atomic LATEST pointer
        ptr_tmp = os.path.join(self.root, "LATEST.tmp")
        with open(ptr_tmp, "w") as f:
            f.write(os.path.basename(final))
        os.replace(ptr_tmp, os.path.join(self.root, "LATEST"))
        self._gc()

    def _gc(self) -> None:
        steps = sorted(
            d for d in os.listdir(self.root) if d.startswith("step_") and not d.endswith(".tmp")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, d), ignore_errors=True)

    # ------------------------------------------------------------------ #
    def latest_step(self) -> int | None:
        ptr = os.path.join(self.root, "LATEST")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            name = f.read().strip()
        if not os.path.exists(os.path.join(self.root, name)):
            return None
        return int(name.split("_")[1])

    def restore(self, step: int | None = None, shardings=None):
        """Load (tree, extra).  ``shardings``: optional flat-matching pytree of
        ``jax.sharding.Sharding`` for elastic placement on a new mesh."""
        if step is None:
            step = self.latest_step()
            if step is None:
                return None, None
        d = self._dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        # pre-codec checkpoints have no codec/file fields and are always zstd
        codec = manifest.get("codec", "zstd")
        fn = manifest.get("file", "arrays.bin.zst")
        with open(os.path.join(d, fn), "rb") as f:
            raw = _decompress_bytes(codec, f)
        flat = {}
        off = 0
        for rec in manifest["arrays"]:
            dt = np.dtype(rec["dtype"])
            n = int(np.prod(rec["shape"])) if rec["shape"] else 1
            nbytes = n * dt.itemsize
            a = np.frombuffer(raw, dt, count=n, offset=off).reshape(rec["shape"])
            off += nbytes
            flat[rec["path"]] = a
        tree = _unflatten(flat)
        if shardings is not None:
            flat_sh = _flatten(shardings)
            tree = _unflatten(
                {
                    k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
                    for k, v in _flatten(tree).items()
                }
            )
        return tree, manifest["extra"]
