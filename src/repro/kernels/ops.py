"""Jit'd public wrappers around the Pallas kernels.

These handle packing/padding from the natural numpy layouts used by
``repro.core`` into the 128-lane int32 tiles the kernels expect, and select
``interpret=True`` automatically when no TPU is attached (this container) so
the kernel bodies are validated on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .range_join import LANES, range_join_mask
from .run_boundary import run_boundaries_packed

__all__ = ["run_boundaries", "range_join_pairs", "default_interpret"]


def default_interpret() -> bool:
    return jax.devices()[0].platform != "tpu"


def _pad_rows(a: np.ndarray, mult: int, fill: int) -> np.ndarray:
    n = a.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return a
    return np.concatenate(
        [a, np.full((pad,) + a.shape[1:], fill, a.dtype)], axis=0
    )


def run_boundaries(
    group_cols: list[np.ndarray],
    lo: np.ndarray,
    hi: np.ndarray,
    block_rows: int = 1024,
    interpret: bool | None = None,
) -> np.ndarray:
    """Boundary flags for sorted rows; drop-in for the numpy hot pass.

    ``group_cols`` are the equality columns, ``lo``/``hi`` the merge-column
    interval.  Values must fit int32 (array indices always do).
    """
    if interpret is None:
        interpret = default_interpret()
    n = lo.shape[0]
    n_keys = len(group_cols)
    assert n_keys + 2 <= LANES, "too many group columns for one tile"
    packed = np.zeros((n, LANES), np.int32)
    for c, col in enumerate(group_cols):
        packed[:, c] = col.astype(np.int32)
    packed[:, n_keys] = lo.astype(np.int32)
    packed[:, n_keys + 1] = hi.astype(np.int32)
    # pad rows with a copy of the last row → padded flags are 0 (no runs)
    padded = _pad_rows(packed, block_rows, 0)
    if padded.shape[0] != n and n > 0:
        padded[n:] = padded[n - 1]
    flags = run_boundaries_packed(
        jnp.asarray(padded),
        n_keys=n_keys,
        block_rows=block_rows,
        interpret=interpret,
    )
    return np.asarray(flags[:n]).astype(bool)


def range_join_pairs(
    q_lo: np.ndarray,
    q_hi: np.ndarray,
    r_lo: np.ndarray,
    r_hi: np.ndarray,
    block_q: int = 256,
    block_r: int = 256,
    interpret: bool | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """All (query row, table row) index pairs whose boxes overlap.

    Kernel-accelerated replacement for the broadcasting pass inside
    ``repro.core.query.theta_join``.
    """
    if interpret is None:
        interpret = default_interpret()
    nq, l = q_lo.shape
    nr = r_lo.shape[0]
    if nq == 0 or nr == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    assert 2 * l <= LANES

    def pack(lo, hi):
        n = lo.shape[0]
        p = np.zeros((n, LANES), np.int32)
        p[:, :l] = lo.astype(np.int32)
        p[:, l : 2 * l] = hi.astype(np.int32)
        return p

    qp = _pad_rows(pack(q_lo, q_hi), block_q, 0)
    rp = _pad_rows(pack(r_lo, r_hi), block_r, 0)
    # make padded rows empty boxes: lo=1, hi=0 (overlap nothing)
    if qp.shape[0] > nq:
        qp[nq:, :l] = 1
        qp[nq:, l : 2 * l] = 0
    if rp.shape[0] > nr:
        rp[nr:, :l] = 1
        rp[nr:, l : 2 * l] = 0
    mask = range_join_mask(
        jnp.asarray(qp),
        jnp.asarray(rp),
        n_attrs=l,
        block_q=block_q,
        block_r=block_r,
        interpret=interpret,
    )
    qi, ri = np.nonzero(np.asarray(mask[:nq, :nr]))
    return qi.astype(np.int64), ri.astype(np.int64)
