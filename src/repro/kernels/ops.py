"""Jit'd public wrappers around the Pallas kernels.

These handle packing/padding from the natural numpy layouts used by
``repro.core`` into the 128-lane int32 tiles the kernels expect, and select
``interpret=True`` automatically when no TPU is attached (this container) so
the kernel bodies are validated on CPU.

The packers are int32: coordinates outside the int32 range cannot ride the
kernel path (they would silently wrap — the bug this module now refuses).
``fits_int32`` is the gate callers use to route oversized joins to the
numpy dense path; handing out-of-range values to a packer raises.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .range_join import (
    LANES,
    check_lane_capacity,
    range_join_mask,
    range_join_tile_masks,
)
from .run_boundary import run_boundaries_packed

__all__ = [
    "run_boundaries",
    "range_join_pairs",
    "segmented_range_join_pairs",
    "default_interpret",
    "fits_int32",
]

_I32 = np.iinfo(np.int32)


def default_interpret() -> bool:
    return jax.devices()[0].platform != "tpu"


def fits_int32(*arrays: np.ndarray) -> bool:
    """Whether every value survives an int32 pack without wrapping."""
    for a in arrays:
        if a.size and (a.min() < _I32.min or a.max() > _I32.max):
            return False
    return True


def _require_int32(*arrays: np.ndarray) -> None:
    if not fits_int32(*arrays):
        raise ValueError(
            "coordinates outside the int32 range cannot be packed for the "
            "kernel path (they would wrap); route this join to the numpy "
            "dense path (fits_int32 gates this)"
        )


def run_boundaries(
    group_cols: list[np.ndarray],
    lo: np.ndarray,
    hi: np.ndarray,
    block_rows: int = 1024,
    interpret: bool | None = None,
) -> np.ndarray:
    """Boundary flags for sorted rows; drop-in for the numpy hot pass.

    ``group_cols`` are the equality columns, ``lo``/``hi`` the merge-column
    interval.  Values must fit int32 (array indices always do).
    """
    if interpret is None:
        interpret = default_interpret()
    n = lo.shape[0]
    n_keys = len(group_cols)
    assert n_keys + 2 <= LANES, "too many group columns for one tile"
    _require_int32(*group_cols, lo, hi)
    packed = np.zeros((n, LANES), np.int32)
    for c, col in enumerate(group_cols):
        packed[:, c] = col.astype(np.int32)
    packed[:, n_keys] = lo.astype(np.int32)
    packed[:, n_keys + 1] = hi.astype(np.int32)
    # the kernel pads rows to the block grid internally (copies of the last
    # row never start a run) and slices the flags back to n
    flags = run_boundaries_packed(
        jnp.asarray(packed),
        n_keys=n_keys,
        block_rows=block_rows,
        interpret=interpret,
    )
    return np.asarray(flags).astype(bool)


def _pack_boxes(lo: np.ndarray, hi: np.ndarray, n_attrs: int) -> np.ndarray:
    """Pack ``[N, l]`` lo/hi into the kernel's ``[N, 128]`` int32 layout.

    Lanes ``[0, n_attrs)`` hold lo columns, ``[n_attrs, 2*n_attrs)`` hi
    columns; attributes beyond ``lo.shape[1]`` (width padding in segmented
    packs) are left ``lo = hi = 0`` on *both* operands, which always
    overlaps and so never filters a pair.
    """
    n, l = lo.shape
    _require_int32(lo, hi)  # last line of defense at the cast site
    p = np.zeros((n, LANES), np.int32)
    p[:, :l] = lo.astype(np.int32)
    p[:, n_attrs : n_attrs + l] = hi.astype(np.int32)
    return p


def range_join_pairs(
    q_lo: np.ndarray,
    q_hi: np.ndarray,
    r_lo: np.ndarray,
    r_hi: np.ndarray,
    block_q: int = 256,
    block_r: int = 256,
    interpret: bool | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """All (query row, table row) index pairs whose boxes overlap.

    Kernel-accelerated replacement for the broadcasting pass inside
    ``repro.core.query.theta_join``.  Raises for joins the kernel cannot
    express faithfully (lane capacity, int32 overflow) — the caller's
    routing (``repro.core.query._kernel_pairs``) checks the same gates and
    falls back to numpy before ever reaching this point.
    """
    if interpret is None:
        interpret = default_interpret()
    nq, l = q_lo.shape
    nr = r_lo.shape[0]
    if nq == 0 or nr == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    check_lane_capacity(l)
    _require_int32(q_lo, q_hi, r_lo, r_hi)
    mask = range_join_mask(
        jnp.asarray(_pack_boxes(q_lo, q_hi, l)),
        jnp.asarray(_pack_boxes(r_lo, r_hi, l)),
        n_attrs=l,
        block_q=block_q,
        block_r=block_r,
        interpret=interpret,
    )
    qi, ri = np.nonzero(np.asarray(mask))
    return qi.astype(np.int64), ri.astype(np.int64)


def _pad_packed_rows(p: np.ndarray, mult: int, n_attrs: int) -> np.ndarray:
    """Pad packed rows to a multiple of ``mult`` with empty boxes.

    The numpy twin of ``range_join._pad_empty``: padded rows carry
    ``lo = 1, hi = 0`` on every attribute lane, so they never overlap a
    padded row; real rows with coordinates spanning ``[≤0, ≥1]`` *can* still
    graze one, which is why tile extraction bounds-checks pairs against the
    segment's real row counts.
    """
    n = p.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return p
    row = np.zeros(LANES, np.int32)
    row[:n_attrs] = 1
    return np.concatenate([p, np.tile(row, (pad, 1))], axis=0)


def _blockdiag_pairs(
    segments: "list[tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]",
    n_attrs: int,
    block_q: int,
    block_r: int,
    interpret: bool,
) -> tuple[list[tuple[np.ndarray, np.ndarray]], int, int]:
    """Per-segment pairs via the tile-scheduled (block-diagonal) kernel.

    Each segment is packed and padded to block multiples *independently*
    (tiles never straddle segments, so no segment-id lane is spent), the
    diagonal tile schedule is enumerated on the host, and pair extraction
    runs on the ``[T, block_q, block_r]`` tile stack — host transfer scales
    with the diagonal, not the cross product.  Returns the per-segment
    pair lists plus (padded rows, tiles visited).
    """
    n_segs = len(segments)
    q_parts = [
        _pad_packed_rows(_pack_boxes(s[0], s[1], n_attrs), block_q, n_attrs)
        for s in segments
    ]
    r_parts = [
        _pad_packed_rows(_pack_boxes(s[2], s[3], n_attrs), block_r, n_attrs)
        for s in segments
    ]
    nqb = np.array([p.shape[0] // block_q for p in q_parts], np.int64)
    nrb = np.array([p.shape[0] // block_r for p in r_parts], np.int64)
    q_blk_off = np.concatenate([[0], np.cumsum(nqb)])
    r_blk_off = np.concatenate([[0], np.cumsum(nrb)])
    tile_start = np.concatenate([[0], np.cumsum(nqb * nrb)])
    n_tiles = int(tile_start[-1])
    empty = (np.zeros(0, np.int64), np.zeros(0, np.int64))
    if n_tiles == 0:
        return [empty for _ in segments], 0, 0
    # the diagonal schedule: segment-major, q-block outer / r-block inner
    tile_q = np.concatenate(
        [q_blk_off[s] + np.repeat(np.arange(nqb[s]), nrb[s]) for s in range(n_segs)]
    )
    tile_r = np.concatenate(
        [r_blk_off[s] + np.tile(np.arange(nrb[s]), int(nqb[s])) for s in range(n_segs)]
    )
    masks = range_join_tile_masks(
        jnp.asarray(np.concatenate(q_parts, axis=0)),
        jnp.asarray(np.concatenate(r_parts, axis=0)),
        # dslint: ignore[int32-cast] block indices, bounded by row count/block
        jnp.asarray(tile_q.astype(np.int32)),
        # dslint: ignore[int32-cast] block indices, bounded by row count/block
        jnp.asarray(tile_r.astype(np.int32)),
        n_attrs=n_attrs,
        block_q=block_q,
        block_r=block_r,
        interpret=interpret,
    )
    flat = np.flatnonzero(np.asarray(masks))
    t, rem = np.divmod(flat, block_q * block_r)
    lq, lr = np.divmod(rem, block_r)
    qi_pad = tile_q[t] * block_q + lq  # global padded-row coordinates
    ri_pad = tile_r[t] * block_r + lr
    # tiles are segment-grouped and flatnonzero is tile-major, so one cut
    # per segment recovers the per-join slices
    cuts = np.searchsorted(t, tile_start[1:-1])
    out = []
    for s, (qs, rs) in enumerate(
        zip(np.split(qi_pad, cuts), np.split(ri_pad, cuts))
    ):
        qi = qs - q_blk_off[s] * block_q
        ri = rs - r_blk_off[s] * block_r
        keep = (qi < segments[s][0].shape[0]) & (ri < segments[s][2].shape[0])
        if not keep.all():
            qi, ri = qi[keep], ri[keep]
        if nrb[s] > 1:
            # tiles run r-block inner, so segments spanning several r blocks
            # need a row-major resort to match the dense oracle's pair order
            order = np.lexsort((ri, qi))
            qi, ri = qi[order], ri[order]
        out.append(
            (qi.astype(np.int64, copy=False), ri.astype(np.int64, copy=False))
        )
    rows_padded = int(
        sum(p.shape[0] for p in q_parts) + sum(p.shape[0] for p in r_parts)
    )
    return out, rows_padded, n_tiles


def segmented_range_join_pairs(
    segments: "list[tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]",
    block_q: int = 256,
    block_r: int = 256,
    interpret: bool | None = None,
    layout: str = "auto",
) -> tuple[list[tuple[np.ndarray, np.ndarray]], dict]:
    """Many independent range joins in **one** kernel launch.

    ``segments`` is a list of ``(q_lo, q_hi, r_lo, r_hi)`` joins; attribute
    widths are padded to the widest segment (spare attributes carry
    ``lo = hi = 0`` on both sides, never filtering).  Two launch layouts:

    * ``"dense"`` — one masked ``[NQ, 128] × [NR, 128]`` cross-product
      launch; with more than one segment, a spare-lane attribute holds the
      *segment id* with ``lo = hi = segment`` so rows only match within
      their own join (a single segment skips the lane).  The correctness
      oracle, and the cheaper plan for single-segment or tiny frontiers
      where per-segment padding would cost more than the cross product.
    * ``"blockdiag"`` — the tile-scheduled kernel
      (:func:`repro.kernels.range_join.range_join_tile_masks`): only the
      ~K diagonal tiles of a K-segment frontier are visited, and the host
      reads back the tile stack instead of the full cross-product mask.

    ``layout="auto"`` charges both schedules in tiles and picks the
    cheaper.  Returns the per-segment ``(qi, ri)`` pair lists (row-major
    order, bit-identical between layouts and to a per-segment dense
    evaluation) plus occupancy info for ``io_stats``: ``tiles_visited`` is
    the executed schedule, ``tiles_skipped`` the cross-product tiles the
    block-diagonal schedule avoided.
    """
    if interpret is None:
        interpret = default_interpret()
    geometry = (block_q, block_r)
    if not segments:
        return [], {
            "rows": 0, "rows_padded": 0, "launches": 0, "layout": "dense",
            "geometry": geometry, "tiles_visited": 0, "tiles_skipped": 0,
        }
    if layout not in ("auto", "dense", "blockdiag"):
        raise ValueError(f"unknown launch layout {layout!r}")
    l_max = max(s[0].shape[1] for s in segments)
    for q_lo, q_hi, r_lo, r_hi in segments:
        _require_int32(q_lo, q_hi, r_lo, r_hi)
    nq_tot = sum(s[0].shape[0] for s in segments)
    nr_tot = sum(s[2].shape[0] for s in segments)
    rows = int(nq_tot + nr_tot)
    # tile bills for both schedules over the same segments: the masked
    # cross product pays the full grid, the diagonal pays per-segment
    # ceil-padded blocks — auto takes the cheaper, and the difference is
    # what io_stats reports as skipped
    cross_tiles = -(-nq_tot // block_q) * -(-nr_tot // block_r)
    diag_tiles = sum(
        -(-s[0].shape[0] // block_q) * -(-s[2].shape[0] // block_r)
        for s in segments
    )
    if layout == "auto":
        layout = (
            "blockdiag"
            if len(segments) > 1 and diag_tiles < cross_tiles
            else "dense"
        )
    if layout == "blockdiag":
        check_lane_capacity(l_max)  # no segment lane: tiles never cross segments
        out, rows_padded, visited = _blockdiag_pairs(
            segments, l_max, block_q, block_r, interpret
        )
        return out, {
            "rows": rows,
            "rows_padded": rows_padded,
            "launches": 1,
            "layout": "blockdiag",
            "geometry": geometry,
            "tiles_visited": visited,
            "tiles_skipped": max(0, int(cross_tiles - visited)),
        }

    # masked dense cross-product launch
    segmented = len(segments) > 1
    n_attrs = l_max + (1 if segmented else 0)  # + segment-id lane pair
    check_lane_capacity(l_max, segmented=segmented)

    def pack_side(arrs: list[tuple[np.ndarray, np.ndarray]]) -> np.ndarray:
        parts = []
        for seg, (lo, hi) in enumerate(arrs):
            p = _pack_boxes(lo, hi, n_attrs)
            if segmented:
                p[:, l_max] = seg  # segment id: lo = hi = seg
                p[:, n_attrs + l_max] = seg
            parts.append(p)
        return np.concatenate(parts, axis=0)

    qp = pack_side([(s[0], s[1]) for s in segments])
    rp = pack_side([(s[2], s[3]) for s in segments])
    q_off = np.cumsum([0] + [s[0].shape[0] for s in segments])
    r_off = np.cumsum([0] + [s[2].shape[0] for s in segments])
    mask = range_join_mask(
        jnp.asarray(qp),
        jnp.asarray(rp),
        n_attrs=n_attrs,
        block_q=block_q,
        block_r=block_r,
        interpret=interpret,
    )
    qi, ri = np.nonzero(np.asarray(mask))
    # pairs are qi-major and the segment lane confines ri to the segment's
    # own column range, so one cut per segment recovers the per-join lists
    cuts = np.searchsorted(qi, q_off[1:-1])
    out = []
    for seg, (qs, rs) in enumerate(
        zip(np.split(qi, cuts), np.split(ri, cuts))
    ):
        out.append(
            (
                (qs - q_off[seg]).astype(np.int64),
                (rs - r_off[seg]).astype(np.int64),
            )
        )
    rows_padded = int(
        -(-qp.shape[0] // block_q) * block_q + -(-rp.shape[0] // block_r) * block_r
    )
    return out, {
        "rows": rows,
        "rows_padded": rows_padded,
        "launches": 1,
        "layout": "dense",
        "geometry": geometry,
        "tiles_visited": int(cross_tiles),
        "tiles_skipped": 0,
    }
