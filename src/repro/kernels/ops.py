"""Jit'd public wrappers around the Pallas kernels.

These handle packing/padding from the natural numpy layouts used by
``repro.core`` into the 128-lane int32 tiles the kernels expect, and select
``interpret=True`` automatically when no TPU is attached (this container) so
the kernel bodies are validated on CPU.

The packers are int32: coordinates outside the int32 range cannot ride the
kernel path (they would silently wrap — the bug this module now refuses).
``fits_int32`` is the gate callers use to route oversized joins to the
numpy dense path; handing out-of-range values to a packer raises.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .range_join import LANES, check_lane_capacity, range_join_mask
from .run_boundary import run_boundaries_packed

__all__ = [
    "run_boundaries",
    "range_join_pairs",
    "segmented_range_join_pairs",
    "default_interpret",
    "fits_int32",
]

_I32 = np.iinfo(np.int32)


def default_interpret() -> bool:
    return jax.devices()[0].platform != "tpu"


def fits_int32(*arrays: np.ndarray) -> bool:
    """Whether every value survives an int32 pack without wrapping."""
    for a in arrays:
        if a.size and (a.min() < _I32.min or a.max() > _I32.max):
            return False
    return True


def _require_int32(*arrays: np.ndarray) -> None:
    if not fits_int32(*arrays):
        raise ValueError(
            "coordinates outside the int32 range cannot be packed for the "
            "kernel path (they would wrap); route this join to the numpy "
            "dense path (fits_int32 gates this)"
        )


def _pad_rows(a: np.ndarray, mult: int, fill: int) -> np.ndarray:
    n = a.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return a
    return np.concatenate(
        [a, np.full((pad,) + a.shape[1:], fill, a.dtype)], axis=0
    )


def run_boundaries(
    group_cols: list[np.ndarray],
    lo: np.ndarray,
    hi: np.ndarray,
    block_rows: int = 1024,
    interpret: bool | None = None,
) -> np.ndarray:
    """Boundary flags for sorted rows; drop-in for the numpy hot pass.

    ``group_cols`` are the equality columns, ``lo``/``hi`` the merge-column
    interval.  Values must fit int32 (array indices always do).
    """
    if interpret is None:
        interpret = default_interpret()
    n = lo.shape[0]
    n_keys = len(group_cols)
    assert n_keys + 2 <= LANES, "too many group columns for one tile"
    _require_int32(*group_cols, lo, hi)
    packed = np.zeros((n, LANES), np.int32)
    for c, col in enumerate(group_cols):
        packed[:, c] = col.astype(np.int32)
    packed[:, n_keys] = lo.astype(np.int32)
    packed[:, n_keys + 1] = hi.astype(np.int32)
    # pad rows with a copy of the last row → padded flags are 0 (no runs)
    padded = _pad_rows(packed, block_rows, 0)
    if padded.shape[0] != n and n > 0:
        padded[n:] = padded[n - 1]
    flags = run_boundaries_packed(
        jnp.asarray(padded),
        n_keys=n_keys,
        block_rows=block_rows,
        interpret=interpret,
    )
    return np.asarray(flags[:n]).astype(bool)


def _pack_boxes(lo: np.ndarray, hi: np.ndarray, n_attrs: int) -> np.ndarray:
    """Pack ``[N, l]`` lo/hi into the kernel's ``[N, 128]`` int32 layout.

    Lanes ``[0, n_attrs)`` hold lo columns, ``[n_attrs, 2*n_attrs)`` hi
    columns; attributes beyond ``lo.shape[1]`` (width padding in segmented
    packs) are left ``lo = hi = 0`` on *both* operands, which always
    overlaps and so never filters a pair.
    """
    n, l = lo.shape
    _require_int32(lo, hi)  # last line of defense at the cast site
    p = np.zeros((n, LANES), np.int32)
    p[:, :l] = lo.astype(np.int32)
    p[:, n_attrs : n_attrs + l] = hi.astype(np.int32)
    return p


def range_join_pairs(
    q_lo: np.ndarray,
    q_hi: np.ndarray,
    r_lo: np.ndarray,
    r_hi: np.ndarray,
    block_q: int = 256,
    block_r: int = 256,
    interpret: bool | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """All (query row, table row) index pairs whose boxes overlap.

    Kernel-accelerated replacement for the broadcasting pass inside
    ``repro.core.query.theta_join``.  Raises for joins the kernel cannot
    express faithfully (lane capacity, int32 overflow) — the caller's
    routing (``repro.core.query._kernel_pairs``) checks the same gates and
    falls back to numpy before ever reaching this point.
    """
    if interpret is None:
        interpret = default_interpret()
    nq, l = q_lo.shape
    nr = r_lo.shape[0]
    if nq == 0 or nr == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    check_lane_capacity(l)
    _require_int32(q_lo, q_hi, r_lo, r_hi)
    mask = range_join_mask(
        jnp.asarray(_pack_boxes(q_lo, q_hi, l)),
        jnp.asarray(_pack_boxes(r_lo, r_hi, l)),
        n_attrs=l,
        block_q=block_q,
        block_r=block_r,
        interpret=interpret,
    )
    qi, ri = np.nonzero(np.asarray(mask))
    return qi.astype(np.int64), ri.astype(np.int64)


def segmented_range_join_pairs(
    segments: "list[tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]",
    block_q: int = 256,
    block_r: int = 256,
    interpret: bool | None = None,
) -> tuple[list[tuple[np.ndarray, np.ndarray]], dict]:
    """Many independent range joins in **one** kernel launch.

    ``segments`` is a list of ``(q_lo, q_hi, r_lo, r_hi)`` joins.  All
    segments are packed into a single ``[NQ, 128] × [NR, 128]`` invocation:
    attribute widths are padded to the widest segment (spare attributes
    carry ``lo = hi = 0`` on both sides, never filtering), and one extra
    spare-lane attribute holds the *segment id* with ``lo = hi = segment``
    so rows only match within their own join.  Returns the per-segment
    ``(qi, ri)`` pair lists (row-major order, identical to a per-segment
    dense evaluation) plus occupancy info for ``io_stats``.
    """
    if interpret is None:
        interpret = default_interpret()
    if not segments:
        return [], {"rows": 0, "rows_padded": 0, "launches": 0}
    l_max = max(s[0].shape[1] for s in segments)
    n_attrs = l_max + 1  # + segment-id lane pair
    check_lane_capacity(l_max, segmented=True)
    for q_lo, q_hi, r_lo, r_hi in segments:
        _require_int32(q_lo, q_hi, r_lo, r_hi)

    def pack_side(arrs: list[tuple[np.ndarray, np.ndarray]]) -> np.ndarray:
        rows = []
        for seg, (lo, hi) in enumerate(arrs):
            p = _pack_boxes(lo, hi, n_attrs)
            p[:, l_max] = seg  # segment id: lo = hi = seg
            p[:, n_attrs + l_max] = seg
            rows.append(p)
        return np.concatenate(rows, axis=0)

    qp = pack_side([(s[0], s[1]) for s in segments])
    rp = pack_side([(s[2], s[3]) for s in segments])
    q_off = np.cumsum([0] + [s[0].shape[0] for s in segments])
    r_off = np.cumsum([0] + [s[2].shape[0] for s in segments])
    mask = range_join_mask(
        jnp.asarray(qp),
        jnp.asarray(rp),
        n_attrs=n_attrs,
        block_q=block_q,
        block_r=block_r,
        interpret=interpret,
    )
    qi, ri = np.nonzero(np.asarray(mask))
    # pairs are qi-major and the segment lane confines ri to the segment's
    # own column range, so one cut per segment recovers the per-join lists
    cuts = np.searchsorted(qi, q_off[1:-1])
    out = []
    for seg, (qs, rs) in enumerate(
        zip(np.split(qi, cuts), np.split(ri, cuts))
    ):
        out.append(
            (
                (qs - q_off[seg]).astype(np.int64),
                (rs - r_off[seg]).astype(np.int64),
            )
        )
    rows = int(qp.shape[0] + rp.shape[0])
    rows_padded = int(
        -(-qp.shape[0] // block_q) * block_q + -(-rp.shape[0] // block_r) * block_r
    )
    return out, {"rows": rows, "rows_padded": rows_padded, "launches": 1}
