"""Launch-geometry autotuner for the batched dense-join engines.

The segmented kernels take a ``(block_q, block_r)`` launch geometry and the
blocked-numpy twin a mask-block budget (cells per row block); the best
values depend on the backend (TPU Pallas vs interpret vs numpy) and on the
*shape* of the frontier being joined (many tiny segments want small tiles,
few big segments want big ones).  :class:`GeometryTuner` measures candidate
geometries the first time a (backend, frontier-shape bucket) combination is
seen — Triton-style: each candidate runs the real workload once after a
warmup, the winner's result is kept so the measuring dispatch does the real
work — and caches the winner in a small table that the catalog persists as
an ``autotune.json`` sidecar next to the manifest.

Deliberately **jax-free**: backends are opaque strings, workloads run
through caller-supplied runners, so ``repro.core`` imports this without
touching the kernel stack.  Entries are keyed by backend, which is what
invalidates the cache when a store moves machines — a table tuned under
``interpret`` simply never answers a ``tpu`` lookup.
"""

from __future__ import annotations

import math
import threading
import time
from typing import Callable, Iterable, Sequence

__all__ = [
    "GeometryTuner",
    "shape_bucket",
    "DEFAULT_GEOMETRY",
    "CANDIDATE_GEOMETRIES",
    "DEFAULT_TWIN_CELLS",
    "CANDIDATE_TWIN_CELLS",
]

# kernel-launch geometry: (block_q, block_r) tile shapes.  Second-minor dim
# multiples of 8 and lane dim multiples of 128 keep every candidate legal
# for TPU tiling.
DEFAULT_GEOMETRY = (256, 256)
CANDIDATE_GEOMETRIES = (
    (64, 128),
    (128, 128),
    (128, 256),
    (256, 128),
    (256, 256),
    (512, 256),
)

# numpy-twin geometry: mask cells evaluated per row block (the twin's only
# launch knob — trades scratch-buffer locality against ufunc call overhead)
DEFAULT_TWIN_CELLS = (4_194_304,)
CANDIDATE_TWIN_CELLS = ((1_048_576,), (4_194_304,), (16_777_216,))

_TABLE_VERSION = 1


def _log2_bucket(n: int) -> int:
    """Coarse pow-2 bucket of a count (0 stays 0)."""
    return 0 if n <= 0 else int(math.log2(n)) + 1


def shape_bucket(shapes: "Sequence[tuple[int, int, int]]") -> str:
    """Bucket key for a frontier's segment shapes.

    ``shapes`` is ``[(n_query_rows, n_table_rows, n_attrs), ...]``.  Buckets
    are deliberately coarse — pow-2 segment count, pow-2 *median* row counts,
    exact max width — so a handful of tuning runs covers a workload's whole
    steady state without ever re-measuring near-identical frontiers.
    """
    if not shapes:
        return "empty"
    k = _log2_bucket(len(shapes))
    med_q = _log2_bucket(int(sorted(s[0] for s in shapes)[len(shapes) // 2]))
    med_r = _log2_bucket(int(sorted(s[1] for s in shapes)[len(shapes) // 2]))
    width = max(s[2] for s in shapes)
    return f"k{k}q{med_q}r{med_r}w{width}"


class GeometryTuner:
    """Per-(backend, shape-bucket) launch-geometry table with measurement.

    ``pick`` is the one-stop API: cached winner when known, otherwise (if a
    ``runner`` is supplied) measure every candidate on the real workload and
    cache the winner.  Geometries are opaque int tuples — ``(block_q,
    block_r)`` for the kernels, ``(block_cells,)`` for the numpy twin — so
    one table serves both engines.
    """

    def __init__(self) -> None:
        # parallel query workers race pick/lookup/to_manifest on one
        # tuner; the lock (rank 75, a leaf) guards only the table —
        # candidate measurement runs outside it, because runners execute
        # real workloads that take stats locks and fire metrics
        try:
            from repro.core import _locks

            self._lock = _locks.new_lock("autotune._lock")
        except ImportError:  # standalone use outside the repo tree
            self._lock = threading.Lock()
        self._table: dict[str, dict] = {}
        self.dirty = False

    # ------------------------------------------------------------------ #
    @staticmethod
    def _key(backend: str, bucket: str) -> str:
        return f"{backend}|{bucket}"

    def __len__(self) -> int:
        with self._lock:
            return len(self._table)

    def lookup(self, backend: str, bucket: str) -> "tuple[int, ...] | None":
        """The cached winning geometry, or None when this (backend, bucket)
        has never been measured — including after a backend change: entries
        are keyed by backend, so a table tuned elsewhere never answers."""
        with self._lock:
            rec = self._table.get(self._key(backend, bucket))
        if rec is None or rec.get("backend") != backend:
            return None
        try:
            return tuple(int(x) for x in rec["geometry"])
        except (KeyError, TypeError, ValueError):
            return None

    def pick(
        self,
        backend: str,
        bucket: str,
        runner: "Callable[[tuple[int, ...]], object] | None" = None,
        candidates: "Iterable[tuple[int, ...]]" = CANDIDATE_GEOMETRIES,
        default: "tuple[int, ...]" = DEFAULT_GEOMETRY,
        warmup: bool = True,
    ) -> "tuple[tuple[int, ...], object | None]":
        """Winning geometry for (backend, bucket), measuring on a miss.

        Returns ``(geometry, result)``: ``result`` is the winner's workload
        output when this call measured (so the tuning dispatch does the real
        work — no wasted evaluation), else ``None`` (cache hit, or no
        ``runner`` to measure with → ``default``).  ``warmup=True`` runs
        each candidate once untimed first so trace/compile cost never picks
        the winner (pointless for pure-numpy runners — pass ``False``).
        """
        cached = self.lookup(backend, bucket)
        if cached is not None:
            return cached, None
        if runner is None:
            return tuple(default), None
        best: "tuple[int, ...] | None" = None
        best_s = math.inf
        best_result: object = None
        measured: dict[str, float] = {}
        for geom in candidates:
            geom = tuple(int(x) for x in geom)
            if warmup:
                runner(geom)
            t0 = time.perf_counter()
            result = runner(geom)
            dt = time.perf_counter() - t0
            measured["x".join(str(x) for x in geom)] = round(dt * 1e6, 1)
            if dt < best_s:
                best, best_s, best_result = geom, dt, result
        assert best is not None, "no candidate geometries supplied"
        # concurrent measurers of the same key race benignly: last writer
        # wins and both winners came from real measurements
        with self._lock:
            self._table[self._key(backend, bucket)] = {
                "backend": backend,
                "bucket": bucket,
                "geometry": list(best),
                "us": round(best_s * 1e6, 1),
                "measured": measured,
            }
            self.dirty = True
        return best, best_result

    # ------------------------------------------------------------------ #
    # persistence (catalog sidecar)
    # ------------------------------------------------------------------ #
    def to_manifest(self) -> dict:
        with self._lock:
            return {"version": _TABLE_VERSION, "entries": dict(self._table)}

    def load_manifest(self, chunk: "dict | None") -> None:
        """Restore a persisted table, dropping anything malformed.

        Tolerant by design (the sidecar may be torn or from a future
        version): a bad chunk loads as a cold table, and entries whose
        recorded backend disagrees with their key are discarded — they
        could only mislead a lookup.
        """
        self._table.clear()
        self.dirty = False
        if not isinstance(chunk, dict):
            return
        entries = chunk.get("entries")
        if not isinstance(entries, dict):
            return
        for key, rec in entries.items():
            if not isinstance(rec, dict) or not isinstance(key, str):
                continue
            backend = rec.get("backend")
            if not isinstance(backend, str) or not key.startswith(backend + "|"):
                continue
            geom = rec.get("geometry")
            if not (
                isinstance(geom, list)
                and geom
                and all(isinstance(x, int) and x > 0 for x in geom)
            ):
                continue
            self._table[key] = rec
