"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` contract).

Every kernel in this package must match these references exactly
(integer outputs — ``assert_allclose`` with zero tolerance) over shape and
dtype sweeps; see ``tests/test_kernels.py``.
"""

from __future__ import annotations

import jax.numpy as jnp

LANES = 128


def run_boundaries_ref(packed: jnp.ndarray, n_keys: int) -> jnp.ndarray:
    """Reference for ``run_boundary.run_boundaries_packed``."""
    keys = packed[:, :n_keys]
    lo = packed[:, n_keys]
    hi = packed[:, n_keys + 1]
    key_change = jnp.any(keys[1:] != keys[:-1], axis=1)
    not_adjacent = lo[1:] > hi[:-1] + 1
    flags = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), key_change | not_adjacent]
    )
    return flags.astype(jnp.int32)  # dslint: ignore[int32-cast] bool flags


def range_join_mask_ref(
    q_packed: jnp.ndarray, r_packed: jnp.ndarray, n_attrs: int
) -> jnp.ndarray:
    """Reference for ``range_join.range_join_mask``."""
    q_lo = q_packed[:, :n_attrs]
    q_hi = q_packed[:, n_attrs : 2 * n_attrs]
    r_lo = r_packed[:, :n_attrs]
    r_hi = r_packed[:, n_attrs : 2 * n_attrs]
    ok = jnp.all(
        (q_lo[:, None, :] <= r_hi[None, :, :])
        & (r_lo[None, :, :] <= q_hi[:, None, :]),
        axis=-1,
    )
    return ok.astype(jnp.int32)  # dslint: ignore[int32-cast] bool mask
