"""Pallas TPU kernel: fused multi-column run-boundary detection.

This is the O(N) hot pass inside every ProvRC range-encoding step (paper
§IV.A): given rows *already sorted* by their group key, emit ``1`` where a
new run starts — i.e. where any group-key column changes, or the merge
column stops being contiguous (``lo[t] > hi[t-1] + 1``).

TPU adaptation (vs. the paper's scalar Python scan): the scan has no loop
dependence once the previous row is available, so we tile rows into VMEM
blocks of ``(block_rows, 128)`` int32 and compare each block against itself
shifted by one row.  The single cross-tile dependency (the last row of the
previous tile) is precomputed as a tiny ``[num_tiles, 128]`` side input —
an O(N / block_rows) strided gather done once by XLA, so the kernel reads
every element of the sorted table exactly once from HBM.  The column axis is
padded to the 128-lane width; group-key columns and the two merge-interval
columns travel in the same tile so the whole boundary predicate fuses into
one VMEM pass (numpy needs C+2 separate comparison sweeps).

Layout:  ``packed[:, :n_keys]`` = group-key columns,
         ``packed[:, n_keys]`` = merge ``lo``, ``packed[:, n_keys+1]`` =
         merge ``hi``; remaining lanes are zero padding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _kernel(packed_ref, prev_ref, out_ref, *, n_keys: int):
    """One row-tile: boundary flags for rows [i*T, (i+1)*T)."""
    block = packed_ref[...]  # [T, LANES] int32
    prev_tail = prev_ref[...]  # [1, LANES]  last row of previous tile
    # previous-row view: shift block down by one, filling row 0 from the tail
    prev_rows = jnp.concatenate([prev_tail, block[:-1, :]], axis=0)

    key_mask = (jax.lax.iota(jnp.int32, LANES) < n_keys)[None, :]
    diff = (block != prev_rows) & key_mask
    key_change = jnp.any(diff, axis=1)

    lo = block[:, n_keys]
    prev_hi = prev_rows[:, n_keys + 1]
    not_adjacent = lo > prev_hi + 1

    # dslint: ignore[int32-cast] bool flags
    out_ref[...] = (key_change | not_adjacent).astype(jnp.int32)[:, None]


@functools.partial(jax.jit, static_argnames=("n_keys", "block_rows", "interpret"))
def run_boundaries_packed(
    packed: jax.Array,
    *,
    n_keys: int,
    block_rows: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """Boundary flags for a packed ``[N, 128]`` int32 sorted table.

    Any row count: rows are padded internally to the block grid with copies
    of the last row (identical rows never start a run, so padded flags are
    0) and the returned flags are sliced back to ``N``.  Row 0 is always a
    boundary — tile 0's previous-row sentinel differs from every real row.
    """
    n, lanes = packed.shape
    assert lanes == LANES, f"pack columns to {LANES} lanes"
    if n == 0:
        return jnp.zeros((0,), jnp.int32)
    pad = (-n) % block_rows
    if pad:
        packed = jnp.concatenate(
            [packed, jnp.tile(packed[-1:], (pad, 1))], axis=0
        )
    num_tiles = (n + pad) // block_rows

    # Last row of the previous tile for each tile; tile 0 gets a sentinel
    # row that can never equal a real row (forces a boundary at row 0).
    tails = packed[block_rows - 1 :: block_rows][:-1]
    sentinel = jnp.full((1, LANES), jnp.iinfo(jnp.int32).min, jnp.int32)
    prev = jnp.concatenate([sentinel, tails], axis=0)  # [num_tiles, LANES]

    flags = pl.pallas_call(
        functools.partial(_kernel, n_keys=n_keys),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, 1), jnp.int32),
        interpret=interpret,
    )(packed, prev)
    return flags[:n, 0]
