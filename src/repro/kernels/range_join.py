"""Pallas TPU kernel: blocked multi-attribute interval-overlap join.

The range join of the paper's θ-join (§V.B.1): for query boxes ``Q`` and
compressed-table key boxes ``R``, emit the boolean matrix
``mask[q, r] = ∧_j  [q.lo_j, q.hi_j] ∩ [r.lo_j, r.hi_j] ≠ ∅``.

TPU adaptation: this is an all-pairs predicate with the same data-movement
shape as an attention-score block — we tile ``Q`` rows × ``R`` rows into
VMEM blocks and evaluate the conjunction over attributes entirely in
registers, so each (q, r) tile pair is materialized once in VMEM and never
round-trips through HBM.  The attribute axis (≤ a few) is carried in the
lane dimension of each operand tile.

Inputs are packed ``[N, 2*l]`` int32 (lo columns then hi columns), padded to
128 lanes; the mask output block is ``(block_q, block_r)`` int32.  Row
counts need **not** be multiples of the block sizes: the kernel pads both
operands internally with *empty* boxes (``lo = 1, hi = 0`` — they overlap
nothing) and slices the padding back off the mask, so callers hand in
natural row counts.

Batched (multi-join) invocations come in two launch layouts (see
``repro.kernels.ops.segmented_range_join_pairs``):

* **masked dense** — all segments packed into one ``[NQ, 128] × [NR, 128]``
  cross-product launch with a *segment id* in a spare lane as one more
  interval attribute (``lo = hi = segment``): two rows overlap on that
  attribute iff they belong to the same segment, so the masks stay
  separable.  Simple and the correctness oracle, but a K-segment frontier
  evaluates K² tile blocks for K blocks of useful work.
* **block-diagonal** (:func:`range_join_tile_masks`) — a scalar-prefetch
  grid over an explicit per-tile ``(q_block, r_block)`` schedule.  The host
  enumerates only the tiles on the segment diagonal; the kernel's
  ``BlockSpec`` index maps read the prefetched tile offsets, so off-diagonal
  tiles are never visited and the output (``[T, block_q, block_r]``) scales
  with the diagonal, not the cross product.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def check_lane_capacity(n_attrs: int, segmented: bool = False) -> None:
    """Raise when ``n_attrs`` interval attributes cannot fit one tile.

    Each attribute needs a lo and a hi lane; a segmented (batched) launch
    additionally spends one attribute on the segment id.  Beyond this the
    dense route must run on the numpy path — callers that want the silent
    fallback check before packing, so reaching the kernel over-capacity is
    a hard error, not a degradation.
    """
    total = n_attrs + (1 if segmented else 0)
    if 2 * total > LANES:
        raise ValueError(
            f"range_join_mask lane capacity exceeded: {n_attrs} attributes"
            f"{' + 1 segment lane' if segmented else ''} need {2 * total} "
            f"lanes but one tile has {LANES}; route this join to the numpy "
            f"dense path instead"
        )


def _kernel(q_ref, r_ref, out_ref, *, n_attrs: int):
    q = q_ref[...]  # [TQ, LANES]
    r = r_ref[...]  # [TR, LANES]
    ok = jnp.ones((q.shape[0], r.shape[0]), dtype=jnp.bool_)
    for j in range(n_attrs):  # static unroll over attributes
        q_lo = q[:, j][:, None]
        q_hi = q[:, n_attrs + j][:, None]
        r_lo = r[:, j][None, :]
        r_hi = r[:, n_attrs + j][None, :]
        ok &= (q_lo <= r_hi) & (r_lo <= q_hi)
    out_ref[...] = ok.astype(jnp.int32)  # dslint: ignore[int32-cast] bool mask


def _pad_empty(packed: jax.Array, n: int, mult: int, n_attrs: int) -> jax.Array:
    """Pad rows to a multiple of ``mult`` with empty boxes (lo=1, hi=0)."""
    pad = (-n) % mult
    if pad == 0:
        return packed
    lane = jnp.arange(LANES)
    # dslint: ignore[int32-cast] constant 0/1 row, hi lanes stay 0
    row = jnp.where(lane < n_attrs, 1, 0).astype(jnp.int32)
    return jnp.concatenate([packed, jnp.tile(row, (pad, 1))], axis=0)


@functools.partial(
    jax.jit, static_argnames=("n_attrs", "block_q", "block_r", "interpret")
)
def range_join_mask(
    q_packed: jax.Array,
    r_packed: jax.Array,
    *,
    n_attrs: int,
    block_q: int = 256,
    block_r: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Overlap mask for padded ``[NQ, 128]`` × ``[NR, 128]`` int32 boxes.

    Arbitrary row counts: operands are padded internally to the block grid
    with empty boxes and the returned mask is sliced back to ``[NQ, NR]``.
    """
    check_lane_capacity(n_attrs)
    nq, lanes = q_packed.shape
    nr, lanes_r = r_packed.shape
    if lanes != LANES or lanes_r != LANES:
        raise ValueError(f"operands must be packed to {LANES} lanes")
    qp = _pad_empty(q_packed, nq, block_q, n_attrs)
    rp = _pad_empty(r_packed, nr, block_r, n_attrs)
    grid = (qp.shape[0] // block_q, rp.shape[0] // block_r)
    mask = pl.pallas_call(
        functools.partial(_kernel, n_attrs=n_attrs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, LANES), lambda i, j: (i, 0)),
            pl.BlockSpec((block_r, LANES), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_r), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], rp.shape[0]), jnp.int32),
        interpret=interpret,
    )(qp, rp)
    return mask[:nq, :nr]


def _tile_kernel(tq_ref, tr_ref, q_ref, r_ref, out_ref, *, n_attrs: int):
    """One scheduled tile: the overlap conjunction for its (q, r) blocks.

    ``tq_ref``/``tr_ref`` are the prefetched tile schedules — consumed by
    the BlockSpec index maps, not the body, which sees exactly the operand
    blocks the schedule selected.
    """
    q = q_ref[...]  # [block_q, LANES]
    r = r_ref[...]  # [block_r, LANES]
    ok = jnp.ones((q.shape[0], r.shape[0]), dtype=jnp.bool_)
    for j in range(n_attrs):  # static unroll over attributes
        q_lo = q[:, j][:, None]
        q_hi = q[:, n_attrs + j][:, None]
        r_lo = r[:, j][None, :]
        r_hi = r[:, n_attrs + j][None, :]
        ok &= (q_lo <= r_hi) & (r_lo <= q_hi)
    out_ref[0] = ok.astype(jnp.int32)  # dslint: ignore[int32-cast] bool mask


@functools.partial(
    jax.jit, static_argnames=("n_attrs", "block_q", "block_r", "interpret")
)
def range_join_tile_masks(
    q_packed: jax.Array,
    r_packed: jax.Array,
    tile_q: jax.Array,
    tile_r: jax.Array,
    *,
    n_attrs: int,
    block_q: int = 256,
    block_r: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Overlap masks for an explicit tile schedule (block-diagonal launch).

    ``tile_q``/``tile_r`` are int32 ``[T]`` *block indices* into the packed
    operands (rows must already be multiples of the block sizes — the
    segmented packer pads each segment independently, which is what keeps a
    tile from straddling two segments).  Tile ``t`` evaluates q rows
    ``[tile_q[t]*block_q, ...)`` against r rows ``[tile_r[t]*block_r, ...)``
    and lands in ``out[t]``; tiles not in the schedule are never computed,
    so a K-segment frontier costs its diagonal (~K tiles), not the K² cross
    product.  The schedule rides scalar prefetch: it is available to the
    ``BlockSpec`` index maps before the body runs, so this is one launch,
    not T.
    """
    check_lane_capacity(n_attrs)
    nq, lanes = q_packed.shape
    nr, lanes_r = r_packed.shape
    if lanes != LANES or lanes_r != LANES:
        raise ValueError(f"operands must be packed to {LANES} lanes")
    if nq % block_q or nr % block_r:
        raise ValueError(
            "tile-scheduled operands must be pre-padded to block multiples "
            f"(got {nq} q rows / {nr} r rows for {block_q}x{block_r} tiles)"
        )
    n_tiles = tile_q.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((block_q, LANES), lambda t, tq, tr: (tq[t], 0)),
            pl.BlockSpec((block_r, LANES), lambda t, tq, tr: (tr[t], 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, block_r), lambda t, tq, tr: (t, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_tile_kernel, n_attrs=n_attrs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles, block_q, block_r), jnp.int32),
        interpret=interpret,
    )(tile_q, tile_r, q_packed, r_packed)
