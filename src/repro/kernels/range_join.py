"""Pallas TPU kernel: blocked multi-attribute interval-overlap join.

The range join of the paper's θ-join (§V.B.1): for query boxes ``Q`` and
compressed-table key boxes ``R``, emit the boolean matrix
``mask[q, r] = ∧_j  [q.lo_j, q.hi_j] ∩ [r.lo_j, r.hi_j] ≠ ∅``.

TPU adaptation: this is an all-pairs predicate with the same data-movement
shape as an attention-score block — we tile ``Q`` rows × ``R`` rows into
VMEM blocks and evaluate the conjunction over attributes entirely in
registers, so each (q, r) tile pair is materialized once in VMEM and never
round-trips through HBM.  The attribute axis (≤ a few) is carried in the
lane dimension of each operand tile.

Inputs are packed ``[N, 2*l]`` int32 (lo columns then hi columns), padded to
128 lanes; the mask output block is ``(block_q, block_r)`` int32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _kernel(q_ref, r_ref, out_ref, *, n_attrs: int):
    q = q_ref[...]  # [TQ, LANES]
    r = r_ref[...]  # [TR, LANES]
    ok = jnp.ones((q.shape[0], r.shape[0]), dtype=jnp.bool_)
    for j in range(n_attrs):  # static unroll over attributes
        q_lo = q[:, j][:, None]
        q_hi = q[:, n_attrs + j][:, None]
        r_lo = r[:, j][None, :]
        r_hi = r[:, n_attrs + j][None, :]
        ok &= (q_lo <= r_hi) & (r_lo <= q_hi)
    out_ref[...] = ok.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("n_attrs", "block_q", "block_r", "interpret")
)
def range_join_mask(
    q_packed: jax.Array,
    r_packed: jax.Array,
    *,
    n_attrs: int,
    block_q: int = 256,
    block_r: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Overlap mask for padded ``[NQ, 128]`` × ``[NR, 128]`` int32 boxes.

    Row counts must be multiples of the block sizes; pad with empty boxes
    (``lo = 1, hi = 0``) which overlap nothing.
    """
    nq, lanes = q_packed.shape
    nr, lanes_r = r_packed.shape
    assert lanes == LANES and lanes_r == LANES
    assert nq % block_q == 0 and nr % block_r == 0
    grid = (nq // block_q, nr // block_r)
    return pl.pallas_call(
        functools.partial(_kernel, n_attrs=n_attrs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, LANES), lambda i, j: (i, 0)),
            pl.BlockSpec((block_r, LANES), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_r), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, nr), jnp.int32),
        interpret=interpret,
    )(q_packed, r_packed)
