"""Lock construction for ``repro.core`` (race-detector seam).

All ``threading.Lock``/``RLock`` instances in the core are minted here so
the dynamic race detector (``repro.tools.racecheck``) can substitute
instrumented equivalents.  With ``DSLOG_RACE_DETECT`` unset (the default)
these helpers return plain ``threading`` primitives and wrap nothing — zero
steady-state overhead, one env lookup at construction.

Every lock carries a name from the declared order table in
``repro.tools.lockorder``; see that module for the ranking rationale.
"""

from __future__ import annotations

import os
import threading


def _detect() -> bool:
    return os.environ.get("DSLOG_RACE_DETECT", "") not in ("", "0")


def new_lock(name: str):
    """A non-reentrant mutex named per the lock-order table."""
    if _detect():
        from repro.tools.racecheck import InstrumentedLock

        return InstrumentedLock(name, reentrant=False)
    return threading.Lock()


def new_rlock(name: str):
    """A reentrant mutex named per the lock-order table."""
    if _detect():
        from repro.tools.racecheck import InstrumentedLock

        return InstrumentedLock(name, reentrant=True)
    return threading.RLock()


def guard_mapping(data, guard, label: str):
    """Register a dict as shared state guarded by ``guard``.

    Under the race detector this returns a ``GuardedDict`` that flags
    mutations performed without ``guard`` held; otherwise it returns a plain
    dict built from ``data``.
    """
    if _detect():
        from repro.tools.racecheck import GuardedDict, InstrumentedLock

        if isinstance(guard, InstrumentedLock):
            return GuardedDict(data, guard, label)
    return dict(data)


def guard_sequence(data, guard, label: str):
    """List counterpart of :func:`guard_mapping` (shard caches)."""
    if _detect():
        from repro.tools.racecheck import GuardedList, InstrumentedLock

        if isinstance(guard, InstrumentedLock):
            return GuardedList(data, guard, label)
    return list(data)
