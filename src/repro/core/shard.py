"""Sharded lineage store: partitioned DAG, per-shard manifests, cross-shard
query planning.

One :class:`~repro.core.catalog.DSLog` stops scaling when the catalog must
serve production traffic: every save rewrites one manifest, every query
plans over one graph, and one process owns all blobs.
:class:`ShardedDSLog` splits the store into ``N`` independent shards while
keeping the single-store surface:

* **graph layer** — :class:`ShardedLineageGraph` assigns every array to a
  shard through a pluggable :class:`ShardPolicy` (stable hashing by default,
  explicit :class:`AffinityShardPolicy` pinning when the workload knows
  better).  Each shard keeps its own
  :class:`~repro.core.graph.LineageGraph`; lineage whose endpoints live on
  different shards is tracked in an explicit **boundary-edge table** (the
  entry itself is stored with its *output* array's shard, so backward
  queries start local — the SMOKE argument for tight per-partition
  indexes).

* **planner layer** — :class:`ShardedQueryPlanner` routes over the global
  DAG exactly like the single-store planner, then decomposes the plan into
  per-shard sub-plans stitched by :class:`ExchangeStep`s.  A frontier
  crossing a shard boundary is first coalesced with
  :func:`~repro.core.query.merge_boxes` so only merged cell boxes ship
  (predicate-pushdown style: prune before crossing), and the cost model
  adds a per-box exchange term (``_EXCHANGE_WEIGHT``) on top of the
  single-shard per-hop costs.

* **persistence layer** — the v2 manifest splits into a **root manifest**
  (``catalog.json`` with a ``"sharded"`` marker: policy, array→shard map,
  edge topology, boundary table, ops, predictor state, version counters)
  plus one ordinary DSLog manifest per shard under ``shard_XX/``.  Each
  shard dirty-tracks independently: ``save()`` rewrites only the manifests
  and blobs of shards that actually changed, and a reloaded store resolves
  a shard's manifest lazily, the first time a plan touches it.

* **facade layer** — ``ShardedDSLog`` reuses ``DSLog``'s method objects
  (``add_lineage``, ``register_operation``, ``prov_query`` …) over sharded
  storage, so ``N=1`` is the single-store special case with byte-identical
  query results, and existing ``prov_query(src, dst, cells)`` calls work
  unchanged on any ``N``.
"""

from __future__ import annotations

import json
import os
import zlib
from collections.abc import Mapping
from dataclasses import dataclass, field

from .catalog import ArrayDef, DSLog, _json_safe, _OpRecord, _vacuum_dir
from .graph import CycleError, LineageGraph
from .planner import _MERGE_SHRINK, EdgeStep, QueryPlan, QueryPlanner
from .query import QueryBox, merge_boxes
from .reuse import ReusePredictor
from .table import CompressedTable

__all__ = [
    "ShardPolicy",
    "HashShardPolicy",
    "AffinityShardPolicy",
    "ShardedLineageGraph",
    "ShardedDSLog",
    "ShardedQueryPlan",
    "ShardedQueryPlanner",
    "ExchangeStep",
]

_ROOT_MANIFEST_VERSION = 3

# Cost-model weight per frontier box shipped across a shard boundary
# (serialization + transfer, in the planner's unitless per-pair scale).
_EXCHANGE_WEIGHT = 4.0


def _base_name(name: str) -> str:
    """Strip a ``@k`` version suffix: versions of an array co-locate."""
    return name.split("@", 1)[0]


# --------------------------------------------------------------------------- #
# Shard assignment policies
# --------------------------------------------------------------------------- #
class ShardPolicy:
    """Maps array names to shard ids.  Must be deterministic: the same name
    resolves to the same shard across processes and reloads."""

    def __init__(self, n_shards: int):
        if n_shards < 1:
            raise ValueError("need at least one shard")
        self.n_shards = int(n_shards)

    def shard_of(self, name: str) -> int:
        raise NotImplementedError

    def to_manifest(self) -> dict:
        raise NotImplementedError

    @staticmethod
    def from_manifest(rec: dict) -> "ShardPolicy":
        kind = rec.get("kind", "hash")
        if kind == "hash":
            return HashShardPolicy(int(rec["n_shards"]))
        if kind == "affinity":
            return AffinityShardPolicy(
                int(rec["n_shards"]),
                {k: int(v) for k, v in rec.get("assign", {}).items()},
            )
        raise ValueError(f"unknown shard policy {kind!r}")


class HashShardPolicy(ShardPolicy):
    """Stable crc32 hash of the array's *base* name (``acc@3`` → ``acc``),
    so in-place version chains never cross a shard boundary."""

    def shard_of(self, name: str) -> int:
        return zlib.crc32(_base_name(name).encode()) % self.n_shards

    def to_manifest(self) -> dict:
        return {"kind": "hash", "n_shards": self.n_shards}


class AffinityShardPolicy(ShardPolicy):
    """Explicit name→shard pins with hash fallback for unpinned names.

    Lets a pipeline keep hot co-queried arrays on one shard (affinity)
    while everything else spreads by hash.
    """

    def __init__(self, n_shards: int, assign: dict[str, int] | None = None):
        super().__init__(n_shards)
        self.assign: dict[str, int] = {}
        for name, shard in (assign or {}).items():
            self.pin(name, shard)

    def pin(self, name: str, shard: int) -> None:
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range 0..{self.n_shards - 1}")
        self.assign[_base_name(name)] = int(shard)

    def shard_of(self, name: str) -> int:
        base = _base_name(name)
        if base in self.assign:
            return self.assign[base]
        return zlib.crc32(base.encode()) % self.n_shards

    def to_manifest(self) -> dict:
        return {
            "kind": "affinity",
            "n_shards": self.n_shards,
            "assign": dict(self.assign),
        }


# --------------------------------------------------------------------------- #
# Partitioned lineage DAG
# --------------------------------------------------------------------------- #
class ShardedLineageGraph:
    """Lineage DAG partitioned across shards.

    Keeps the global :class:`LineageGraph` (cycle checks and routing need
    whole-DAG reachability), one per-shard graph holding the edges each
    shard stores, and an explicit boundary table for edges whose src and
    dst arrays live on different shards.  Entries are owned by their *dst*
    array's shard.
    """

    def __init__(self, n_shards: int):
        self.n_shards = int(n_shards)
        self.global_graph = LineageGraph()
        self.shard_graphs = [LineageGraph() for _ in range(self.n_shards)]
        # lineage_id -> (src, dst, src_shard, dst_shard), cross-shard only
        self.boundary: dict[int, tuple[str, str, int, int]] = {}

    def add_edge(
        self, src: str, dst: str, lineage_id: int, src_shard: int, dst_shard: int
    ) -> None:
        """Record one entry; raises :class:`CycleError` (mutating nothing)
        when the edge would close a cycle anywhere in the global DAG."""
        self.global_graph.add_edge(src, dst, lineage_id)
        self.shard_graphs[dst_shard].add_edge(src, dst, lineage_id)
        if src_shard != dst_shard:
            self.boundary[lineage_id] = (src, dst, src_shard, dst_shard)

    def remove_edge(
        self, src: str, dst: str, lineage_id: int, src_shard: int, dst_shard: int
    ) -> None:
        self.global_graph.remove_edge(src, dst, lineage_id)
        self.shard_graphs[dst_shard].remove_edge(src, dst, lineage_id)
        self.boundary.pop(lineage_id, None)

    def shard_graph(self, shard: int) -> LineageGraph:
        return self.shard_graphs[shard]

    def is_boundary(self, lineage_id: int) -> bool:
        return lineage_id in self.boundary

    def boundary_edges(self) -> list[tuple[int, str, str, int, int]]:
        """Explicit boundary-edge table, ordered by lineage id."""
        return [
            (lid, src, dst, s, d)
            for lid, (src, dst, s, d) in sorted(self.boundary.items())
        ]

    def n_edges(self) -> int:
        return self.global_graph.n_edges()


# --------------------------------------------------------------------------- #
# Cross-shard query plans
# --------------------------------------------------------------------------- #
@dataclass
class ExchangeStep:
    """One frontier shipment across a shard boundary.

    ``side`` is "input" when a step's frontier array lives on a different
    shard than the entry executing the hop, "output" when the produced
    array does.  ``est_boxes``/``est_cost`` come from the planner;
    ``shipped_boxes`` is filled during execution.
    """

    array: str
    u: str  # plan-node key the consuming step reads from
    v: str  # plan-node key the step produces
    side: str  # "input" | "output"
    from_shard: int
    to_shard: int
    est_boxes: float = 1.0
    est_cost: float = 0.0
    shipped_boxes: int = 0


@dataclass
class ShardedQueryPlan(QueryPlan):
    """A :class:`QueryPlan` decomposed across shards.

    Every edge step carries an owning shard (``step_shard``); boundary
    crossings become explicit :class:`ExchangeStep`s whose cost is part of
    ``est_cost``.  :meth:`sub_plans` gives the per-shard view — the steps
    each shard executes locally, stitched back together by the exchanges.
    """

    node_shard: dict[str, int] = field(default_factory=dict)
    step_shard: dict[tuple[str, str], int] = field(default_factory=dict)
    exchanges: list[ExchangeStep] = field(default_factory=list)

    def __post_init__(self) -> None:
        self._ex_index: dict[tuple[str, str, str], ExchangeStep] = {}

    def add_exchange(self, ex: ExchangeStep) -> None:
        self.exchanges.append(ex)
        self._ex_index[(ex.u, ex.v, ex.side)] = ex
        self.est_cost += ex.est_cost

    def exchange_for(self, u: str, v: str, side: str) -> ExchangeStep | None:
        return self._ex_index.get((u, v, side))

    def shards_touched(self) -> list[int]:
        touched = set(self.step_shard.values())
        touched.update(self.node_shard[k] for k in self.starts)
        return sorted(touched)

    def sub_plans(self) -> dict[int, QueryPlan]:
        """Per-shard sub-plan views (local steps in global plan order)."""
        out: dict[int, QueryPlan] = {}
        for shard in self.shards_touched():
            steps: dict[str, list[EdgeStep]] = {}
            nodes: set[str] = set()
            for key, step_list in self.steps.items():
                local = [
                    s for s in step_list if self.step_shard[(s.u, s.v)] == shard
                ]
                if local:
                    steps[key] = local
                    nodes.add(key)
                    nodes.update(s.u for s in local)
            nodes.update(k for k in self.starts if self.node_shard[k] == shard)
            order = [k for k in self.order if k in nodes]
            cost = sum(
                c.est_cost for sl in steps.values() for s in sl for c in s.choices
            )
            out[shard] = QueryPlan(
                direction=self.direction,
                starts=tuple(k for k in self.starts if k in nodes),
                target_keys={
                    n: k for n, k in self.target_keys.items() if k in nodes
                },
                order=order,
                node_array={k: self.node_array[k] for k in order},
                steps=steps,
                est_cost=cost,
                est_boxes={k: self.est_boxes.get(k, 1.0) for k in order},
            )
        return out

    def describe(self) -> str:
        """EXPLAIN output: per-hop lines tagged with shards, then exchanges."""
        lines = [
            f"sharded {self.direction} plan, {len(self.order)} nodes, "
            f"shards={self.shards_touched()}, est_cost={self.est_cost:.0f}"
        ]
        for key in self.order:
            for step in self.steps.get(key, []):
                opts = ", ".join(
                    f"#{c.lineage_id}:{c.stored}/"
                    f"{'nat' if c.frontier_on == 'key' else 'inv'}/{c.route}"
                    for c in step.choices
                )
                shard = self.step_shard[(step.u, step.v)]
                lines.append(
                    f"  [s{shard}] {self.node_array[step.u]} -> "
                    f"{self.node_array[step.v]}  [{opts}]"
                )
        for ex in self.exchanges:
            lines.append(
                f"  exchange {ex.array!r} ({ex.side}) s{ex.from_shard} -> "
                f"s{ex.to_shard}  est_boxes={ex.est_boxes:.0f}"
            )
        return "\n".join(lines)


class ShardedQueryPlanner(QueryPlanner):
    """Plan over the global DAG, execute per shard with boundary exchanges.

    Routing, materialization choice, and per-hop costing are inherited from
    :class:`QueryPlanner` (run against the facade's global graph and lazy
    entry view); this subclass decomposes the result by owning shard, adds
    the cross-shard exchange cost term, and meters the frontiers that
    actually cross boundaries at execution time.
    """

    def plan(self, sources, targets, frontier=None) -> ShardedQueryPlan:
        return self._shardify(QueryPlanner.plan(self, sources, targets, frontier))

    def plan_path(self, path, frontier=None) -> ShardedQueryPlan:
        return self._shardify(QueryPlanner.plan_path(self, path, frontier))

    # ------------------------------------------------------------------ #
    def _shardify(self, base: QueryPlan) -> ShardedQueryPlan:
        log: "ShardedDSLog" = self.log
        plan = ShardedQueryPlan(
            direction=base.direction,
            starts=base.starts,
            target_keys=base.target_keys,
            order=base.order,
            node_array=base.node_array,
            steps=base.steps,
            est_cost=base.est_cost,
            est_boxes=base.est_boxes,
        )
        for key in plan.order:
            plan.node_shard[key] = log.shard_of_array(plan.node_array[key])
        for key, step_list in plan.steps.items():
            for step in step_list:
                # entries between one array pair share a dst, hence a shard
                owner = (
                    log.owner_shard(step.choices[0].lineage_id)
                    if step.choices
                    else plan.node_shard[key]
                )
                plan.step_shard[(step.u, step.v)] = owner
                if plan.node_shard[step.u] != owner:
                    nb = max(1.0, plan.est_boxes.get(step.u, 1.0))
                    plan.add_exchange(
                        ExchangeStep(
                            plan.node_array[step.u],
                            step.u,
                            step.v,
                            "input",
                            plan.node_shard[step.u],
                            owner,
                            nb,
                            _EXCHANGE_WEIGHT * nb,
                        )
                    )
                if plan.node_shard[step.v] != owner:
                    nb = max(1.0, step.est_pairs * _MERGE_SHRINK)
                    plan.add_exchange(
                        ExchangeStep(
                            plan.node_array[step.v],
                            step.u,
                            step.v,
                            "output",
                            owner,
                            plan.node_shard[step.v],
                            nb,
                            _EXCHANGE_WEIGHT * nb,
                        )
                    )
        return plan

    # ------------------------------------------------------------------ #
    # execution hooks: meter (and compress) boundary-crossing frontiers
    # ------------------------------------------------------------------ #
    def _incoming_frontier(self, plan, step, qs):
        if not isinstance(plan, ShardedQueryPlan):
            return qs
        ex = plan.exchange_for(step.u, step.v, "input")
        if ex is None:
            return qs
        shipped = [merge_boxes(q) for q in qs]  # prune before crossing
        n = sum(q.n_rows for q in shipped)
        ex.shipped_boxes += n
        self.log._bump("boxes_exchanged", n)
        return shipped

    def _record_step_output(self, plan, step, res_list):
        if not isinstance(plan, ShardedQueryPlan):
            return
        ex = plan.exchange_for(step.u, step.v, "output")
        if ex is None:
            return
        n = sum(r.n_rows for r in res_list)
        ex.shipped_boxes += n
        self.log._bump("boxes_exchanged", n)


# --------------------------------------------------------------------------- #
# The sharded store facade
# --------------------------------------------------------------------------- #
class _ShardedLineageView(Mapping):
    """Read-only ``lineage_id -> LineageEntry`` view across all shards.

    Resolving an id loads its owning shard's manifest (not its blobs) on
    first touch — the mechanism behind lazy shard loading.
    """

    def __init__(self, log: "ShardedDSLog"):
        self._log = log

    def __getitem__(self, lineage_id: int):
        shard = self._log.owner_shard(lineage_id)
        return self._log.shard(shard).lineage[lineage_id]

    def __iter__(self):
        return iter(self._log._lid_shard)

    def __len__(self) -> int:
        return len(self._log._lid_shard)


class ShardedDSLog:
    """N independent DSLog shards behind the single-store interface.

    ``N=1`` is the single-store special case: same planner decisions, same
    query bytes, one shard manifest under the root.  The shard of every
    array comes from ``policy`` (sticky: recorded in the root manifest so a
    later policy change cannot orphan existing data); a lineage entry is
    stored in its dst array's shard.  Lineage ids stay globally unique.
    """

    def __init__(
        self,
        n_shards: int = 1,
        root: str | None = None,
        policy: ShardPolicy | None = None,
        store_forward: bool = True,
        compress_method: str = "auto",
        reuse_m: int = 1,
        gzip: bool = True,
    ):
        self.policy = policy if policy is not None else HashShardPolicy(n_shards)
        self.n_shards = self.policy.n_shards
        self.root = root
        self.store_forward = store_forward
        self.compress_method = compress_method
        self.reuse_m = reuse_m
        self.gzip = gzip
        self.arrays: dict[str, ArrayDef] = {}
        self.sgraph = ShardedLineageGraph(self.n_shards)
        self.by_pair: dict[tuple[str, str], list[int]] = {}
        self.ops: list[_OpRecord] = []
        self.predictor = ReusePredictor(m=reuse_m)
        self.planner = ShardedQueryPlanner(self)
        self.lineage = _ShardedLineageView(self)
        self._next_id = 0
        self._versions: dict[str, int] = {}
        self._array_shard: dict[str, int] = {}
        self._lid_shard: dict[int, int] = {}
        self._shards: list[DSLog | None] = [None] * self.n_shards
        self._predictor_chunk: dict | None = None
        self._meta_dirty = False
        self._io: dict[str, int] = {"shards_loaded": 0, "boxes_exchanged": 0}
        if root:
            os.makedirs(root, exist_ok=True)

    # -- single-store machinery reused verbatim over sharded storage ----- #
    add_lineage = DSLog.add_lineage
    register_operation = DSLog.register_operation
    _rollback_op = DSLog._rollback_op
    _derive_forward = DSLog._derive_forward
    _check_shapes = DSLog._check_shapes
    prov_query = DSLog.prov_query
    prov_query_batch = DSLog.prov_query_batch
    _as_boxes = DSLog._as_boxes
    _parse_query_args = staticmethod(DSLog._parse_query_args)
    version = DSLog.version
    latest_version = DSLog.latest_version
    storage_bytes = DSLog.storage_bytes
    _write_predictor = DSLog._write_predictor

    # ------------------------------------------------------------------ #
    @property
    def graph(self) -> LineageGraph:
        """Global DAG view (the planner routes over this)."""
        return self.sgraph.global_graph

    def shard_of_array(self, name: str) -> int:
        """Sticky shard assignment: policy decides once, then it's recorded."""
        shard = self._array_shard.get(name)
        if shard is None:
            shard = self.policy.shard_of(name) % self.n_shards
            self._array_shard[name] = shard
        return shard

    def owner_shard(self, lineage_id: int) -> int:
        return self._lid_shard[lineage_id]

    def _shard_dir(self, shard: int) -> str | None:
        if self.root is None:
            return None
        return os.path.join(self.root, f"shard_{shard:02d}")

    def shard(self, shard: int) -> DSLog:
        """The shard's DSLog, loading its manifest lazily on first touch."""
        sh = self._shards[shard]
        if sh is None:
            sub = self._shard_dir(shard)
            if sub is not None and os.path.exists(
                os.path.join(sub, "catalog.json")
            ):
                sh = DSLog.load(sub)
                sh.store_forward = self.store_forward
                sh.compress_method = self.compress_method
                sh.gzip = self.gzip
                self._bump("shards_loaded")
            else:
                sh = DSLog(
                    root=sub,
                    store_forward=self.store_forward,
                    compress_method=self.compress_method,
                    reuse_m=self.reuse_m,
                    gzip=self.gzip,
                )
            self._shards[shard] = sh
        return sh

    def loaded_shards(self) -> list[int]:
        return [k for k, sh in enumerate(self._shards) if sh is not None]

    def _bump(self, key: str, n: int = 1) -> None:
        self._io[key] = self._io.get(key, 0) + n

    @property
    def io_stats(self) -> dict[str, int]:
        """Aggregated I/O counters: facade-level plus every loaded shard."""
        total = {
            "tables_loaded": 0,
            "tables_written": 0,
            "manifests_written": 0,
            "sig_tables_written": 0,
            "bytes_written": 0,
        }
        total.update(self._io)
        for sh in self._shards:
            if sh is None:
                continue
            for key, val in sh.io_stats.items():
                total[key] = total.get(key, 0) + val
        return total

    @property
    def dirty(self) -> bool:
        return (
            self._meta_dirty
            or self.predictor.dirty
            or any(sh is not None and sh.dirty for sh in self._shards)
        )

    # ------------------------------------------------------------------ #
    # Array / lineage definition (routes through the policy)
    # ------------------------------------------------------------------ #
    def define_array(self, name: str, shape: tuple[int, ...]) -> ArrayDef:
        arr = ArrayDef(name, tuple(int(d) for d in shape))
        self.arrays[name] = arr
        self.shard_of_array(name)
        self._meta_dirty = True
        return arr

    def _insert_entry(
        self,
        src: str,
        dst: str,
        bwd: CompressedTable,
        fwd: CompressedTable | None,
        op_name: str | None,
        reused_from: str | None = None,
    ):
        src_shard = self.shard_of_array(src)
        dst_shard = self.shard_of_array(dst)
        lineage_id = self._next_id
        # global cycle check first; a rejected edge leaves everything intact
        self.sgraph.add_edge(src, dst, lineage_id, src_shard, dst_shard)
        sh = self.shard(dst_shard)
        for name in (src, dst):
            arr = self.arrays.get(name)
            if arr is not None:
                sh.arrays.setdefault(name, ArrayDef(name, arr.shape))
        sh._next_id = lineage_id  # shards mint from the global id space
        try:
            entry = sh._insert_entry(src, dst, bwd, fwd, op_name, reused_from)
        except CycleError:  # pragma: no cover - global check already passed
            self.sgraph.remove_edge(src, dst, lineage_id, src_shard, dst_shard)
            raise
        self._next_id = sh._next_id
        self.by_pair.setdefault((src, dst), []).append(lineage_id)
        self._lid_shard[lineage_id] = dst_shard
        self._meta_dirty = True
        return entry

    def _remove_entry(self, lineage_id: int) -> None:
        dst_shard = self._lid_shard.pop(lineage_id)
        sh = self.shard(dst_shard)
        e = sh.lineage[lineage_id]
        sh._remove_entry(lineage_id)
        self.sgraph.remove_edge(
            e.src, e.dst, lineage_id, self.shard_of_array(e.src), dst_shard
        )
        ids = self.by_pair[(e.src, e.dst)]
        ids.remove(lineage_id)
        if not ids:
            del self.by_pair[(e.src, e.dst)]
        self._meta_dirty = True

    def drop_lineage(self, lineage_id: int) -> None:
        """Remove one entry; its blobs are vacuumed by :meth:`compact`."""
        if lineage_id not in self._lid_shard:
            raise KeyError(f"no lineage entry {lineage_id}")
        shard = self._lid_shard[lineage_id]
        self._remove_entry(lineage_id)
        sh = self.shard(shard)
        sh._persisted.pop(lineage_id, None)
        sh.hop_stats = {
            k: v
            for k, v in sh.hop_stats.items()
            if int(k.split(":", 1)[0]) != lineage_id
        }
        for op in self.ops:
            if lineage_id in op.lineage_ids:
                op.lineage_ids.remove(lineage_id)

    # ------------------------------------------------------------------ #
    # Planner cost-model feedback routes to the owning shard
    # ------------------------------------------------------------------ #
    def record_hop(
        self,
        lineage_id: int,
        stored: str,
        frontier_on: str,
        pairs: int,
        qrows: int,
    ) -> None:
        self.shard(self.owner_shard(lineage_id)).record_hop(
            lineage_id, stored, frontier_on, pairs, qrows
        )

    def hop_measurement(
        self, lineage_id: int, stored: str, frontier_on: str
    ) -> float | None:
        return self.shard(self.owner_shard(lineage_id)).hop_measurement(
            lineage_id, stored, frontier_on
        )

    # ------------------------------------------------------------------ #
    # Persistence: root manifest + independently saved shard manifests
    # ------------------------------------------------------------------ #
    def save(self) -> None:
        """Save dirty shards and (when needed) the root manifest.

        Each shard's DSLog dirty-tracks its own entries, so only shards
        that changed since the last save write anything — manifests
        included.  The root manifest (policy, array→shard map, topology,
        boundary table, ops, predictor) rewrites only when facade-level
        state changed.
        """
        if not self.root:
            raise ValueError("ShardedDSLog opened without a root directory")
        for sh in self._shards:
            if sh is not None and sh.dirty:
                sh.save()
        manifest = os.path.join(self.root, "catalog.json")
        if not (
            self._meta_dirty
            or self.predictor.dirty
            or self._predictor_chunk is None
            or not os.path.exists(manifest)
        ):
            return
        if self._predictor_chunk is None or self.predictor.dirty:
            self._predictor_chunk = self._write_predictor()
        edges = [
            [src, dst, lid, self._lid_shard[lid]]
            for (src, dst), ids in self.by_pair.items()
            for lid in ids
        ]
        meta = {
            "version": _ROOT_MANIFEST_VERSION,
            "sharded": True,
            "n_shards": self.n_shards,
            "policy": self.policy.to_manifest(),
            "arrays": {
                n: {"shape": list(a.shape), "shard": self.shard_of_array(n)}
                for n, a in self.arrays.items()
            },
            "edges": edges,
            "boundary": [list(rec) for rec in self.sgraph.boundary_edges()],
            "next_id": self._next_id,
            "versions": dict(self._versions),
            "ops": [
                {
                    "op": op.op_name,
                    "in": list(op.in_arrs),
                    "out": list(op.out_arrs),
                    "args": _json_safe(op.op_args),
                    "lineage_ids": list(op.lineage_ids),
                    "reused": op.reused,
                }
                for op in self.ops
            ],
            "predictor": self._predictor_chunk,
        }
        payload = json.dumps(meta)
        with open(manifest, "w") as f:
            f.write(payload)
        self._bump("manifests_written")
        self._bump("bytes_written", len(payload))
        self._meta_dirty = False

    @staticmethod
    def load(root: str, eager: bool = False) -> "ShardedDSLog":
        """Reopen a sharded root without touching any shard manifest.

        The root manifest restores the policy, array→shard map, global
        topology (graph + boundary table), ops, version counters, and
        predictor state; each shard's own manifest (and its blobs) resolves
        lazily the first time a plan or query touches that shard —
        ``io_stats["shards_loaded"]`` counts those resolutions.  Pass
        ``eager=True`` to open every shard up front.
        """
        with open(os.path.join(root, "catalog.json")) as f:
            meta = json.load(f)
        if not meta.get("sharded"):
            raise ValueError(
                f"{root!r} holds a plain DSLog catalog; use DSLog.load"
            )
        policy = ShardPolicy.from_manifest(meta["policy"])
        log = ShardedDSLog(n_shards=policy.n_shards, root=root, policy=policy)
        for name, rec in meta["arrays"].items():
            log.arrays[name] = ArrayDef(name, tuple(rec["shape"]))
            log._array_shard[name] = int(rec["shard"])
        for src, dst, lid, shard in meta["edges"]:
            lid, shard = int(lid), int(shard)
            log.sgraph.add_edge(src, dst, lid, log.shard_of_array(src), shard)
            log.by_pair.setdefault((src, dst), []).append(lid)
            log._lid_shard[lid] = shard
        log._next_id = int(meta["next_id"])
        log._versions = {k: int(v) for k, v in meta.get("versions", {}).items()}
        for op in meta.get("ops", []):
            log.ops.append(
                _OpRecord(
                    op["op"],
                    tuple(op["in"]),
                    tuple(op["out"]),
                    op["args"],
                    list(op["lineage_ids"]),
                    op["reused"],
                )
            )
        chunk = meta.get("predictor")
        if chunk is not None:

            def load_table(fn: str) -> CompressedTable:
                with open(os.path.join(root, fn), "rb") as f:
                    return CompressedTable.deserialize(f.read())

            log.predictor = ReusePredictor.from_manifest(chunk, load_table)
            log._predictor_chunk = chunk
        log._meta_dirty = False
        if eager:
            for k in range(log.n_shards):
                log.shard(k)
        return log

    def compact(self) -> dict[str, int]:
        """Vacuum every shard independently, plus root-level sig blobs."""
        if not self.root:
            raise ValueError("ShardedDSLog opened without a root directory")
        self.save()
        stats = {"files_removed": 0, "bytes_reclaimed": 0}
        for k in range(self.n_shards):
            sub = self._shard_dir(k)
            if sub is None or not os.path.isdir(sub):
                continue
            # the facade save() already synced dirty shards
            for key, val in self.shard(k).compact(save=False).items():
                stats[key] += val
        referenced = {"catalog.json"}
        if self._predictor_chunk:
            for rec in self._predictor_chunk.get("sigs", []):
                referenced.update(rec.get("tables", {}).values())
        for key, val in _vacuum_dir(self.root, referenced).items():
            stats[key] += val
        return stats

    # ------------------------------------------------------------------ #
    def __repr__(self) -> str:
        return (
            f"ShardedDSLog(n_shards={self.n_shards}, arrays={len(self.arrays)}, "
            f"entries={len(self._lid_shard)}, "
            f"boundary={len(self.sgraph.boundary)}, "
            f"loaded={self.loaded_shards()})"
        )
