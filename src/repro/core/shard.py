"""Sharded lineage store: partitioned DAG, per-shard manifests, cross-shard
query planning.

One :class:`~repro.core.catalog.DSLog` stops scaling when the catalog must
serve production traffic: every save rewrites one manifest, every query
plans over one graph, and one process owns all blobs.
:class:`ShardedDSLog` splits the store into ``N`` independent shards while
keeping the single-store surface:

* **graph layer** — :class:`ShardedLineageGraph` assigns every array to a
  shard through a pluggable :class:`ShardPolicy` (stable hashing by default,
  explicit :class:`AffinityShardPolicy` pinning when the workload knows
  better).  Each shard keeps its own
  :class:`~repro.core.graph.LineageGraph`; lineage whose endpoints live on
  different shards is tracked in an explicit **boundary-edge table** (the
  entry itself is stored with its *output* array's shard, so backward
  queries start local — the SMOKE argument for tight per-partition
  indexes).

* **planner layer** — :class:`ShardedQueryPlanner` routes over the global
  DAG exactly like the single-store planner, then decomposes the plan into
  per-shard sub-plans stitched by :class:`ExchangeStep`s.  A frontier
  crossing a shard boundary is first coalesced with
  :func:`~repro.core.query.merge_boxes` so only merged cell boxes ship
  (predicate-pushdown style: prune before crossing), and the cost model
  adds a per-box exchange term (``_EXCHANGE_WEIGHT``) on top of the
  single-shard per-hop costs.

* **persistence layer** — the v2 manifest splits into a **root manifest**
  (``catalog.json`` with a ``"sharded"`` marker: policy, array→shard map,
  edge topology, boundary table, ops, predictor state, version counters)
  plus one ordinary DSLog manifest per shard under ``shard_XX/``.  Each
  shard dirty-tracks independently: ``save()`` rewrites only the manifests
  and blobs of shards that actually changed, and a reloaded store resolves
  a shard's manifest lazily, the first time a plan touches it.

* **facade layer** — ``ShardedDSLog`` reuses ``DSLog``'s method objects
  (``add_lineage``, ``register_operation``, ``prov_query`` …) over sharded
  storage, so ``N=1`` is the single-store special case with byte-identical
  query results, and existing ``prov_query(src, dst, cells)`` calls work
  unchanged on any ``N``.
"""

from __future__ import annotations

import glob
import json
import os
import uuid
import zlib
from collections.abc import Mapping
from dataclasses import dataclass, field

from repro.kernels.autotune import GeometryTuner
from repro.obs.export import telemetry_snapshot
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import QueryTrace

from . import _locks
from .catalog import (
    ArrayDef,
    DSLog,
    SEED_COUNTERS,
    _apply_open_overrides,
    _atomic_write,
    _write_blob,
    _DEFAULT_HOP_DECAY,
    _json_safe,
    _OpRecord,
    _vacuum_dir,
    manifest_referenced_files,
)
from .commit import CommitPipeline, LeaseHeldError, WriterLease
from .graph import CycleError, LineageGraph
from .planner import _MERGE_SHRINK, _fmt_lid, EdgeStep, QueryPlan, QueryPlanner
from .query import QueryBox, merge_boxes
from .reuse import ReusePredictor
from .table import CompressedTable, TableHandle
from .views import ViewManager
from .wal import WAL_FILENAME, WriteAheadLog

__all__ = [
    "ShardPolicy",
    "HashShardPolicy",
    "AffinityShardPolicy",
    "ShardedLineageGraph",
    "ShardedDSLog",
    "ShardedQueryPlan",
    "ShardedQueryPlanner",
    "ExchangeStep",
]

_ROOT_MANIFEST_VERSION = 3

# Cost-model weight per frontier box shipped across a shard boundary
# (serialization + transfer, in the planner's unitless per-pair scale).
_EXCHANGE_WEIGHT = 4.0


def _base_name(name: str) -> str:
    """Strip a ``@k`` version suffix: versions of an array co-locate."""
    return name.split("@", 1)[0]


# --------------------------------------------------------------------------- #
# Shard assignment policies
# --------------------------------------------------------------------------- #
class ShardPolicy:
    """Maps array names to shard ids.  Must be deterministic: the same name
    resolves to the same shard across processes and reloads."""

    def __init__(self, n_shards: int):
        if n_shards < 1:
            raise ValueError("need at least one shard")
        self.n_shards = int(n_shards)

    def shard_of(self, name: str) -> int:
        raise NotImplementedError

    def to_manifest(self) -> dict:
        raise NotImplementedError

    @staticmethod
    def from_manifest(rec: dict) -> "ShardPolicy":
        kind = rec.get("kind", "hash")
        if kind == "hash":
            return HashShardPolicy(int(rec["n_shards"]))
        if kind == "affinity":
            return AffinityShardPolicy(
                int(rec["n_shards"]),
                {k: int(v) for k, v in rec.get("assign", {}).items()},
            )
        raise ValueError(f"unknown shard policy {kind!r}")


class HashShardPolicy(ShardPolicy):
    """Stable crc32 hash of the array's *base* name (``acc@3`` → ``acc``),
    so in-place version chains never cross a shard boundary."""

    def shard_of(self, name: str) -> int:
        return zlib.crc32(_base_name(name).encode()) % self.n_shards

    def to_manifest(self) -> dict:
        return {"kind": "hash", "n_shards": self.n_shards}


class AffinityShardPolicy(ShardPolicy):
    """Explicit name→shard pins with hash fallback for unpinned names.

    Lets a pipeline keep hot co-queried arrays on one shard (affinity)
    while everything else spreads by hash.
    """

    def __init__(self, n_shards: int, assign: dict[str, int] | None = None):
        super().__init__(n_shards)
        self.assign: dict[str, int] = {}
        for name, shard in (assign or {}).items():
            self.pin(name, shard)

    def pin(self, name: str, shard: int) -> None:
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range 0..{self.n_shards - 1}")
        self.assign[_base_name(name)] = int(shard)

    def shard_of(self, name: str) -> int:
        base = _base_name(name)
        if base in self.assign:
            return self.assign[base]
        return zlib.crc32(base.encode()) % self.n_shards

    def to_manifest(self) -> dict:
        return {
            "kind": "affinity",
            "n_shards": self.n_shards,
            "assign": dict(self.assign),
        }


# --------------------------------------------------------------------------- #
# Partitioned lineage DAG
# --------------------------------------------------------------------------- #
class ShardedLineageGraph:
    """Lineage DAG partitioned across shards.

    Keeps the global :class:`LineageGraph` (cycle checks and routing need
    whole-DAG reachability), one per-shard graph holding the edges each
    shard stores, and an explicit boundary table for edges whose src and
    dst arrays live on different shards.  Entries are owned by their *dst*
    array's shard.
    """

    def __init__(self, n_shards: int):
        self.n_shards = int(n_shards)
        self.global_graph = LineageGraph()
        self.shard_graphs = [LineageGraph() for _ in range(self.n_shards)]
        # lineage_id -> (src, dst, src_shard, dst_shard), cross-shard only
        self.boundary: dict[int, tuple[str, str, int, int]] = {}

    def add_edge(
        self, src: str, dst: str, lineage_id: int, src_shard: int, dst_shard: int
    ) -> None:
        """Record one entry; raises :class:`CycleError` (mutating nothing)
        when the edge would close a cycle anywhere in the global DAG."""
        self.global_graph.add_edge(src, dst, lineage_id)
        self.shard_graphs[dst_shard].add_edge(src, dst, lineage_id)
        if src_shard != dst_shard:
            self.boundary[lineage_id] = (src, dst, src_shard, dst_shard)

    def remove_edge(
        self, src: str, dst: str, lineage_id: int, src_shard: int, dst_shard: int
    ) -> None:
        self.global_graph.remove_edge(src, dst, lineage_id)
        self.shard_graphs[dst_shard].remove_edge(src, dst, lineage_id)
        self.boundary.pop(lineage_id, None)

    def shard_graph(self, shard: int) -> LineageGraph:
        return self.shard_graphs[shard]

    def is_boundary(self, lineage_id: int) -> bool:
        return lineage_id in self.boundary

    def boundary_edges(self) -> list[tuple[int, str, str, int, int]]:
        """Explicit boundary-edge table, ordered by lineage id."""
        return [
            (lid, src, dst, s, d)
            for lid, (src, dst, s, d) in sorted(self.boundary.items())
        ]

    def n_edges(self) -> int:
        return self.global_graph.n_edges()


# --------------------------------------------------------------------------- #
# Cross-shard query plans
# --------------------------------------------------------------------------- #
@dataclass
class ExchangeStep:
    """One frontier shipment across a shard boundary.

    ``side`` is "input" when a step's frontier array lives on a different
    shard than the entry executing the hop, "output" when the produced
    array does.  ``est_boxes``/``est_cost`` come from the planner;
    ``shipped_boxes`` is filled during execution.
    """

    array: str
    u: str  # plan-node key the consuming step reads from
    v: str  # plan-node key the step produces
    side: str  # "input" | "output"
    from_shard: int
    to_shard: int
    est_boxes: float = 1.0
    est_cost: float = 0.0
    shipped_boxes: int = 0


@dataclass
class ShardedQueryPlan(QueryPlan):
    """A :class:`QueryPlan` decomposed across shards.

    Every edge step carries an owning shard (``step_shard``); boundary
    crossings become explicit :class:`ExchangeStep`s whose cost is part of
    ``est_cost``.  :meth:`sub_plans` gives the per-shard view — the steps
    each shard executes locally, stitched back together by the exchanges.
    """

    node_shard: dict[str, int] = field(default_factory=dict)
    step_shard: dict[tuple[str, str], int] = field(default_factory=dict)
    exchanges: list[ExchangeStep] = field(default_factory=list)

    def __post_init__(self) -> None:
        self._ex_index: dict[tuple[str, str, str], ExchangeStep] = {}

    def add_exchange(self, ex: ExchangeStep) -> None:
        self.exchanges.append(ex)
        self._ex_index[(ex.u, ex.v, ex.side)] = ex
        self.est_cost += ex.est_cost

    def exchange_for(self, u: str, v: str, side: str) -> ExchangeStep | None:
        return self._ex_index.get((u, v, side))

    def shards_touched(self) -> list[int]:
        touched = set(self.step_shard.values())
        touched.update(self.node_shard[k] for k in self.starts)
        return sorted(touched)

    def sub_plans(self) -> dict[int, QueryPlan]:
        """Per-shard sub-plan views (local steps in global plan order)."""
        out: dict[int, QueryPlan] = {}
        for shard in self.shards_touched():
            steps: dict[str, list[EdgeStep]] = {}
            nodes: set[str] = set()
            for key, step_list in self.steps.items():
                local = [
                    s for s in step_list if self.step_shard[(s.u, s.v)] == shard
                ]
                if local:
                    steps[key] = local
                    nodes.add(key)
                    nodes.update(s.u for s in local)
            nodes.update(k for k in self.starts if self.node_shard[k] == shard)
            order = [k for k in self.order if k in nodes]
            cost = sum(
                c.est_cost for sl in steps.values() for s in sl for c in s.choices
            )
            out[shard] = QueryPlan(
                direction=self.direction,
                starts=tuple(k for k in self.starts if k in nodes),
                target_keys={
                    n: k for n, k in self.target_keys.items() if k in nodes
                },
                order=order,
                node_array={k: self.node_array[k] for k in order},
                steps=steps,
                est_cost=cost,
                est_boxes={k: self.est_boxes.get(k, 1.0) for k in order},
            )
        return out

    def describe(self, analyze: bool = False) -> str:
        """EXPLAIN output: per-hop lines tagged with shards, then exchanges.

        ``analyze=True`` adds the measured side per hop choice (see
        :meth:`QueryPlan.describe`) and measured shipped box counts per
        exchange.
        """
        header = (
            f"sharded {self.direction} plan, {len(self.order)} nodes, "
            f"shards={self.shards_touched()}, est_cost={self.est_cost:.0f}"
        )
        if analyze:
            exec_ms = self.measured.get("__exec_ms__")
            if exec_ms is not None:
                header += (
                    f", measured exec={exec_ms[0]:.3f}ms"
                    f" over {exec_ms[1]} dispatches"
                )
        lines = [header]
        for key in self.order:
            for step in self.steps.get(key, []):
                opts = ", ".join(
                    f"{_fmt_lid(c.lineage_id)}:{c.stored}/"
                    f"{'nat' if c.frontier_on == 'key' else 'inv'}/"
                    f"{c.describe_route()}"
                    for c in step.choices
                )
                shard = self.step_shard[(step.u, step.v)]
                lines.append(
                    f"  [s{shard}] {self.node_array[step.u]} -> "
                    f"{self.node_array[step.v]}  [{opts}]"
                )
                if analyze:
                    for c in step.choices:
                        lines.append(self._analyze_line(step, c))
        for ex in self.exchanges:
            line = (
                f"  exchange {ex.array!r} ({ex.side}) s{ex.from_shard} -> "
                f"s{ex.to_shard}  est_boxes={ex.est_boxes:.0f}"
            )
            if analyze:
                line += f" | measured shipped={ex.shipped_boxes}"
            lines.append(line)
        return "\n".join(lines)


class ShardedQueryPlanner(QueryPlanner):
    """Plan over the global DAG, execute per shard with boundary exchanges.

    Routing, materialization choice, and per-hop costing are inherited from
    :class:`QueryPlanner` (run against the facade's global graph and lazy
    entry view); this subclass decomposes the result by owning shard, adds
    the cross-shard exchange cost term, and meters the frontiers that
    actually cross boundaries at execution time.
    """

    def plan(
        self, sources, targets, frontier=None, batched=None
    ) -> ShardedQueryPlan:
        return self._shardify(
            QueryPlanner.plan(self, sources, targets, frontier, batched)
        )

    def plan_path(self, path, frontier=None, batched=None) -> ShardedQueryPlan:
        return self._shardify(
            QueryPlanner.plan_path(self, path, frontier, batched)
        )

    # ------------------------------------------------------------------ #
    def _shardify(self, base: QueryPlan) -> ShardedQueryPlan:
        log: "ShardedDSLog" = self.log
        plan = ShardedQueryPlan(
            direction=base.direction,
            starts=base.starts,
            target_keys=base.target_keys,
            order=base.order,
            node_array=base.node_array,
            steps=base.steps,
            est_cost=base.est_cost,
            est_boxes=base.est_boxes,
        )
        for key in plan.order:
            plan.node_shard[key] = log.shard_of_array(plan.node_array[key])
        for key, step_list in plan.steps.items():
            for step in step_list:
                # entries between one array pair share a dst, hence a shard
                if step.choices and step.choices[0].lineage_id < 0:
                    # whole-route view: lives on the root facade; run it on
                    # the frontier node's shard so no exchange is charged
                    owner = plan.node_shard[step.u]
                elif step.choices:
                    owner = log.owner_shard(step.choices[0].lineage_id)
                else:
                    owner = plan.node_shard[key]
                plan.step_shard[(step.u, step.v)] = owner
                if plan.node_shard[step.u] != owner:
                    nb = max(1.0, plan.est_boxes.get(step.u, 1.0))
                    plan.add_exchange(
                        ExchangeStep(
                            plan.node_array[step.u],
                            step.u,
                            step.v,
                            "input",
                            plan.node_shard[step.u],
                            owner,
                            nb,
                            _EXCHANGE_WEIGHT * nb,
                        )
                    )
                if plan.node_shard[step.v] != owner:
                    nb = max(1.0, step.est_pairs * _MERGE_SHRINK)
                    plan.add_exchange(
                        ExchangeStep(
                            plan.node_array[step.v],
                            step.u,
                            step.v,
                            "output",
                            owner,
                            plan.node_shard[step.v],
                            nb,
                            _EXCHANGE_WEIGHT * nb,
                        )
                    )
        return plan

    # ------------------------------------------------------------------ #
    # execution hooks: meter (and compress) boundary-crossing frontiers
    # ------------------------------------------------------------------ #
    def _incoming_frontier(self, plan, step, qs):
        if not isinstance(plan, ShardedQueryPlan):
            return qs
        ex = plan.exchange_for(step.u, step.v, "input")
        if ex is None:
            return qs
        shipped = [merge_boxes(q) for q in qs]  # prune before crossing
        n = sum(q.n_rows for q in shipped)
        with self.log._stats_lock:  # parallel sub-plans meter concurrently
            ex.shipped_boxes += n
        self.log._bump("boxes_exchanged", n)
        self._meter_exchange(ex, n)
        return shipped

    def _record_step_output(self, plan, step, res_list):
        if not isinstance(plan, ShardedQueryPlan):
            return
        ex = plan.exchange_for(step.u, step.v, "output")
        if ex is None:
            return
        n = sum(r.n_rows for r in res_list)
        with self.log._stats_lock:
            ex.shipped_boxes += n
        self.log._bump("boxes_exchanged", n)
        self._meter_exchange(ex, n)

    def _meter_exchange(self, ex: ExchangeStep, n: int) -> None:
        """Per-shard-pair exchange volume + trace event (outside locks)."""
        self.log.metrics.inc(
            "exchange_boxes",
            n,
            from_shard=str(ex.from_shard),
            to_shard=str(ex.to_shard),
        )
        tr = getattr(self.log, "_active_trace", None)
        if tr is not None:
            tr.event(
                "exchange",
                kind="exchange",
                array=ex.array,
                side=ex.side,
                from_shard=ex.from_shard,
                to_shard=ex.to_shard,
                boxes=n,
            )


# --------------------------------------------------------------------------- #
# The sharded store facade
# --------------------------------------------------------------------------- #
class _ShardedLineageView(Mapping):
    """Read-only ``lineage_id -> LineageEntry`` view across all shards.

    Resolving an id loads its owning shard's manifest (not its blobs) on
    first touch — the mechanism behind lazy shard loading.
    """

    def __init__(self, log: "ShardedDSLog"):
        self._log = log

    def __getitem__(self, lineage_id: int):
        shard = self._log.owner_shard(lineage_id)
        return self._log.shard(shard).lineage[lineage_id]

    def __iter__(self):
        return iter(self._log._lid_shard)

    def __len__(self) -> int:
        return len(self._log._lid_shard)


class ShardedDSLog:
    """N independent DSLog shards behind the single-store interface.

    ``N=1`` is the single-store special case: same planner decisions, same
    query bytes, one shard manifest under the root.  The shard of every
    array comes from ``policy`` (sticky: recorded in the root manifest so a
    later policy change cannot orphan existing data); a lineage entry is
    stored in its dst array's shard.  Lineage ids stay globally unique.
    """

    def __init__(
        self,
        n_shards: int = 1,
        root: str | None = None,
        policy: ShardPolicy | None = None,
        store_forward: bool = True,
        compress_method: str = "auto",
        reuse_m: int = 1,
        gzip: bool = True,
        hop_decay: float = _DEFAULT_HOP_DECAY,
    ):
        self.policy = policy if policy is not None else HashShardPolicy(n_shards)
        self.n_shards = self.policy.n_shards
        self.root = root
        self.store_forward = store_forward
        self.compress_method = compress_method
        self.reuse_m = reuse_m
        self.gzip = gzip
        self.hop_decay = float(hop_decay)
        self.arrays: dict[str, ArrayDef] = {}
        self.sgraph = ShardedLineageGraph(self.n_shards)
        self.by_pair: dict[tuple[str, str], list[int]] = {}
        self.ops: list[_OpRecord] = []
        self.predictor = ReusePredictor(m=reuse_m)
        self.planner = ShardedQueryPlanner(self)
        # whole-route views + answer cache live on the root facade (routes
        # cross shard boundaries); shard-level managers stay empty
        self.views = ViewManager(self)
        # facade-level geometry table: the cross-shard planner's executor
        # packs frontiers spanning shards, so tuning lives on the root
        self.autotune = GeometryTuner()
        self.lineage = _ShardedLineageView(self)
        self._next_id = 0
        # per-shard id streams: lineage_id = shard + n_shards * counter, so
        # concurrent writers leasing disjoint shards mint disjoint ids
        self._shard_next: list[int] = [0] * self.n_shards
        self._versions: dict[str, int] = {}
        self._array_shard: dict[str, int] = {}
        self._lid_shard: dict[int, int] = {}
        self._stats_lock = _locks.new_rlock("shard._stats_lock")
        # guards lazy shard loading: parallel plan execution may race two
        # worker threads onto the same cold shard
        self._shard_load_lock = _locks.new_lock("shard._shard_load_lock")
        self._shards: list[DSLog | None] = _locks.guard_sequence(
            [None] * self.n_shards, self._shard_load_lock, "ShardedDSLog._shards"
        )
        self._predictor_chunk: dict | None = None
        self._meta_dirty = False
        # facade-level telemetry: facade-minted counters (exchanges, shard
        # loads, query latency) live here; io_stats / metrics_snapshot()
        # aggregate this registry with every loaded shard's by key union.
        self.metrics = MetricsRegistry("dslog-root")
        self.metrics.seed_counters(SEED_COUNTERS)
        self.metrics.seed_counters(("shards_loaded", "boxes_exchanged"))
        self.metrics.register_collector(self._collect_gauges)
        self._active_trace: QueryTrace | None = None
        # durability subsystem (attached by open(); see DSLog for the
        # single-store equivalent).  _exclusive=False is writer mode: this
        # process appends to shard WALs under per-shard leases and never
        # rewrites manifests — the next exclusive open folds the logs in.
        self._wal: WriteAheadLog | None = None  # the root log
        self._pipeline: CommitPipeline | None = None
        self._root_lease: WriterLease | None = None
        self._presence_lease: WriterLease | None = None  # writer-mode marker
        self._shard_leases: dict[int, WriterLease] = {}
        self._exclusive = True
        self._wal_lsn = 0
        self._replaying = False
        self._closed = False
        if root:
            os.makedirs(root, exist_ok=True)

    # -- single-store machinery reused verbatim over sharded storage ----- #
    add_lineage = DSLog.add_lineage
    register_operation = DSLog.register_operation
    _rollback_op = DSLog._rollback_op
    _derive_forward = DSLog._derive_forward
    _check_shapes = DSLog._check_shapes
    prov_query = DSLog.prov_query
    prov_query_batch = DSLog.prov_query_batch
    _query_batch_impl = DSLog._query_batch_impl
    _as_boxes = DSLog._as_boxes
    _parse_query_args = staticmethod(DSLog._parse_query_args)
    version = DSLog.version
    latest_version = DSLog.latest_version
    storage_bytes = DSLog.storage_bytes
    _write_predictor = DSLog._write_predictor
    _wal_emit = DSLog._wal_emit
    _wal_append_root = DSLog._wal_append_root
    _op_wal_meta = staticmethod(DSLog._op_wal_meta)
    __enter__ = DSLog.__enter__
    __exit__ = DSLog.__exit__

    # ------------------------------------------------------------------ #
    @property
    def graph(self) -> LineageGraph:
        """Global DAG view (the planner routes over this)."""
        return self.sgraph.global_graph

    def shard_of_array(self, name: str) -> int:
        """Sticky shard assignment: policy decides once, then it's recorded."""
        shard = self._array_shard.get(name)
        if shard is None:
            shard = self.policy.shard_of(name) % self.n_shards
            self._array_shard[name] = shard
        return shard

    def owner_shard(self, lineage_id: int) -> int:
        return self._lid_shard[lineage_id]

    def _shard_dir(self, shard: int) -> str | None:
        if self.root is None:
            return None
        return os.path.join(self.root, f"shard_{shard:02d}")

    def shard(self, shard: int) -> DSLog:
        """The shard's DSLog, loading its manifest lazily on first touch.

        Loading also replays the shard's WAL tail (``DSLog.load`` handles
        the truncation of torn records) and *absorbs* any replayed entries
        into the facade's topology — the root manifest has not seen them
        yet, only the log has.
        """
        sh = self._shards[shard]
        if sh is not None:
            return sh
        with self._shard_load_lock:  # parallel execution races cold shards
            sh = self._shards[shard]
            if sh is not None:
                return sh
            sub = self._shard_dir(shard)
            has_manifest = sub is not None and os.path.exists(
                os.path.join(sub, "catalog.json")
            )
            has_wal = sub is not None and os.path.exists(
                os.path.join(sub, WAL_FILENAME)
            )
            if has_manifest or has_wal:
                # lazy shard materialisation deliberately does recovery
                # I/O (WAL flock/replay, lease rename) under the load
                # lock: it is a single-fire latch, and publishing a
                # half-recovered shard would be worse.  The shard→shard
                # self-edge is a borrowed-method over-approximation: a
                # sub-log's replay never dispatches back via the facade.
                # dsflow: ignore[lock-fsync,lock-order,wal-lease]
                sh = DSLog.load(sub)
                sh.store_forward = self.store_forward
                sh.compress_method = self.compress_method
                sh.gzip = self.gzip
                sh.hop_decay = self.hop_decay
                self._bump("shards_loaded")
            else:
                sh = DSLog(
                    root=sub,
                    store_forward=self.store_forward,
                    compress_method=self.compress_method,
                    reuse_m=self.reuse_m,
                    gzip=self.gzip,
                    hop_decay=self.hop_decay,
                )
            if self._pipeline is not None and sub is not None:
                if sh._wal is None:
                    # same latch: attaching the WAL acquires the shard
                    # lease (rename) and must finish before publication
                    # dsflow: ignore[lock-fsync,lock-order]
                    sh._attach_wal(self._pipeline)
                else:
                    sh._pipeline = self._pipeline
                    self._pipeline.attach(sh._wal)
            self._absorb_shard_entries(shard, sh)
            self._shards[shard] = sh
        return sh

    def _absorb_shard_entries(self, shard: int, sh: DSLog) -> None:
        """Fold entries the shard knows but the facade does not (WAL-replayed
        tail past the root manifest) into the global topology."""
        fresh = [lid for lid in sh.lineage if lid not in self._lid_shard]
        for lid in sorted(fresh):
            e = sh.lineage[lid]
            self._shard_next[shard] = max(
                self._shard_next[shard], lid // self.n_shards + 1
            )
            self._next_id = max(self._next_id, lid + 1)
            for name in (e.src, e.dst):
                if name not in self.arrays and name in sh.arrays:
                    self.arrays[name] = ArrayDef(name, sh.arrays[name].shape)
            self._array_shard.setdefault(e.dst, shard)
            src_shard = self.shard_of_array(e.src)
            try:
                self.sgraph.add_edge(e.src, e.dst, lid, src_shard, shard)
            except CycleError:
                # concurrent writers each passed their *local* cycle check
                # but jointly closed a cross-shard cycle; recovery must not
                # wedge the store — quarantine the later entry instead
                sh._remove_entry(lid)
                sh._persisted.pop(lid, None)
                self._meta_dirty = True
                continue
            self.by_pair.setdefault((e.src, e.dst), []).append(lid)
            self._lid_shard[lid] = shard
            self._meta_dirty = True
            # a recovered entry is new topology as far as the root knows:
            # views/answers spanning this edge's route are stale
            self.views.on_new_edge(e.src, e.dst)
        # dirty/mutation records replayed inside the shard's own log fired
        # that shard's (inert) ViewManager — mirror the precise
        # invalidation here, where the cross-shard views actually live
        for lid in sorted(sh._dirty):
            self.views.on_mutation(lid)

    def _ensure_shard_lease(self, shard: int) -> None:
        """Writer mode: take the shard's writer lease before the first
        mutation lands there (one concurrent writer per shard)."""
        if self.root is None or shard in self._shard_leases:
            return
        if WriterLease.held(self.root):
            raise LeaseHeldError(
                f"store {self.root!r} is open exclusively; writer-mode "
                "ingest must wait for the exclusive owner to close"
            )
        sub = self._shard_dir(shard)
        assert sub is not None
        self._shard_leases[shard] = WriterLease.acquire(
            sub, what=f"shard {shard} of"
        )
        sh = self._shards[shard]
        if sh is not None and sh._wal is not None:
            sh._wal.repair()  # now the leased owner of this shard's log

    def loaded_shards(self) -> list[int]:
        return [k for k, sh in enumerate(self._shards) if sh is not None]

    def _bump(self, key: str, n: int = 1) -> None:
        self.metrics.inc(key, n)

    def _collect_gauges(self):
        """Facade snapshot-time gauges: view-manager state (the cross-shard
        views live here; per-shard hop gauges ride the shard registries)."""
        try:
            vstats = self.views.stats()
        except Exception:
            return
        for name, val in vstats.items():
            if isinstance(val, (int, float)):
                yield (f"views_{name}", {}, val)

    @property
    def io_stats(self) -> dict[str, int]:
        """Aggregated I/O counters: facade-level plus every loaded shard.

        Aggregation is by *key union* over the facade registry and every
        loaded shard's counters — a counter a shard mints after this
        facade was built (or one only some shards know) still shows up.
        """
        total = self.metrics.counters_flat()
        for sh in self._shards:
            if sh is None:
                continue
            for key, val in sh.io_stats.items():
                total[key] = total.get(key, 0) + val
        return total

    def metrics_snapshot(self) -> dict:
        """Merged telemetry: the facade registry plus every loaded shard's,
        unioned by (instrument, labels) — histograms and labeled series
        aggregate the same way ``io_stats`` unions counters."""
        snaps = [self.metrics.snapshot()]
        snaps.extend(
            sh.metrics.snapshot() for sh in self._shards if sh is not None
        )
        return MetricsRegistry.merge_snapshots(snaps, name="dslog-root")

    def health(self, run_fsck: bool = True) -> dict:
        """Registry red-flags + ``fsck`` findings (``repro.obs.export``)."""
        from repro.obs.export import health as _health

        return _health(self, run_fsck=run_fsck)

    @property
    def dirty(self) -> bool:
        return (
            self._meta_dirty
            or self.predictor.dirty
            or self.views.dirty
            or any(sh is not None and sh.dirty for sh in self._shards)
        )

    # ------------------------------------------------------------------ #
    # Array / lineage definition (routes through the policy)
    # ------------------------------------------------------------------ #
    def define_array(self, name: str, shape: tuple[int, ...]) -> ArrayDef:
        arr = ArrayDef(name, tuple(int(d) for d in shape))
        self.arrays[name] = arr
        self.shard_of_array(name)
        self._meta_dirty = True
        self._wal_append_root("array", {"name": name, "shape": list(arr.shape)})
        return arr

    def _insert_entry(
        self,
        src: str,
        dst: str,
        bwd: CompressedTable,
        fwd: CompressedTable | None,
        op_name: str | None,
        reused_from: str | None = None,
    ):
        src_shard = self.shard_of_array(src)
        dst_shard = self.shard_of_array(dst)
        if not self._exclusive:
            self._ensure_shard_lease(dst_shard)
        # per-shard id stream: with one (leased) writer per shard these
        # never collide, even across concurrent writer processes
        counter = self._shard_next[dst_shard]
        lineage_id = dst_shard + self.n_shards * counter
        # global cycle check first; a rejected edge leaves everything intact
        self.sgraph.add_edge(src, dst, lineage_id, src_shard, dst_shard)
        sh = self.shard(dst_shard)
        for name in (src, dst):
            arr = self.arrays.get(name)
            if arr is not None:
                sh.arrays.setdefault(name, ArrayDef(name, arr.shape))
        sh._next_id = lineage_id  # shards mint from the facade's id space
        try:
            entry = sh._insert_entry(src, dst, bwd, fwd, op_name, reused_from)
        except CycleError:  # pragma: no cover - global check already passed
            self.sgraph.remove_edge(src, dst, lineage_id, src_shard, dst_shard)
            raise
        self._shard_next[dst_shard] = counter + 1
        self._next_id = max(self._next_id, lineage_id + 1)
        self.by_pair.setdefault((src, dst), []).append(lineage_id)
        self._lid_shard[lineage_id] = dst_shard
        self._meta_dirty = True
        self.views.on_new_edge(src, dst)
        return entry

    def _remove_entry(self, lineage_id: int) -> None:
        dst_shard = self._lid_shard.pop(lineage_id)
        sh = self.shard(dst_shard)
        e = sh.lineage[lineage_id]
        sh._remove_entry(lineage_id)
        self.sgraph.remove_edge(
            e.src, e.dst, lineage_id, self.shard_of_array(e.src), dst_shard
        )
        ids = self.by_pair[(e.src, e.dst)]
        ids.remove(lineage_id)
        if not ids:
            del self.by_pair[(e.src, e.dst)]
        self._meta_dirty = True

    def drop_lineage(self, lineage_id: int) -> None:
        """Remove one entry; its blobs are vacuumed by :meth:`compact`."""
        if lineage_id not in self._lid_shard:
            raise KeyError(f"no lineage entry {lineage_id}")
        shard = self._lid_shard[lineage_id]
        self._remove_entry(lineage_id)
        sh = self.shard(shard)
        sh._persisted.pop(lineage_id, None)
        sh._drop_hop_stats(lineage_id)
        self.views.on_mutation(lineage_id)
        for op in self.ops:
            if lineage_id in op.lineage_ids:
                op.lineage_ids.remove(lineage_id)
        self._wal_append_root("drop", {"id": lineage_id})

    def mark_dirty(self, lineage_id: int) -> None:
        """Declare an entry's tables mutated in place (see
        :meth:`DSLog.mark_dirty`); the invalidation record lands in the
        owning shard's WAL."""
        if lineage_id not in self._lid_shard:
            raise KeyError(f"no lineage entry {lineage_id}")
        shard = self.owner_shard(lineage_id)
        if not self._exclusive:
            self._ensure_shard_lease(shard)
        self.shard(shard).mark_dirty(lineage_id)
        # the record lands in the shard WAL, but whole-route views and
        # cached answers live on the root — invalidate across the boundary
        self.views.on_mutation(lineage_id)

    # ------------------------------------------------------------------ #
    # Planner cost-model feedback routes to the owning shard
    # ------------------------------------------------------------------ #
    def record_hop(
        self,
        lineage_id: int,
        stored: str,
        frontier_on: str,
        pairs: int,
        qrows: int,
    ) -> None:
        if lineage_id < 0:  # view hop: owned by the root's ViewManager
            return self.views.record_hop(
                lineage_id, stored, frontier_on, pairs, qrows
            )
        self.shard(self.owner_shard(lineage_id)).record_hop(
            lineage_id, stored, frontier_on, pairs, qrows
        )

    def hop_measurement(
        self, lineage_id: int, stored: str, frontier_on: str
    ) -> float | None:
        if lineage_id < 0:
            return self.views.hop_measurement(lineage_id, stored, frontier_on)
        return self.shard(self.owner_shard(lineage_id)).hop_measurement(
            lineage_id, stored, frontier_on
        )

    # ------------------------------------------------------------------ #
    # Durable concurrent ingest: leases, WALs, recovery (see DSLog.open)
    # ------------------------------------------------------------------ #
    @classmethod
    def open(
        cls,
        root: str,
        n_shards: int = 1,
        *,
        exclusive: bool = True,
        durability: str = "group",
        flush_interval: float = 0.005,
        max_batch: int = 256,
        lease_ttl: float = 300.0,
        policy: ShardPolicy | None = None,
        **ctor_kw,
    ) -> "ShardedDSLog":
        """Open a sharded root durably, as one of two kinds of writer.

        **Exclusive** (default): takes the root writer lock — refusing to
        open while any live writer (root or shard) exists — recovers every
        log tail, and may checkpoint (``save()``/``close()`` fold the WALs
        into the manifests).  A store that does not exist yet is created
        and its initial root manifest written immediately.

        **Writer mode** (``exclusive=False``): for concurrent ingest.  The
        process appends to the shared root log and to the WALs of shards it
        acquires leases for (taken lazily, on the first write landing on a
        shard) and *never rewrites a manifest* — two writer processes
        ingesting into disjoint shards therefore never contend on shared
        files at all beyond the flock-serialized root log.  Durability is
        the group-committed WAL; the next exclusive open replays and
        checkpoints everything.  Requires an initialized store.
        """
        presence_lease = None
        if exclusive:
            root_lease = WriterLease.acquire(root, ttl=lease_ttl)
            try:
                blockers = sorted(
                    glob.glob(os.path.join(root, "shard_*"))
                ) + sorted(glob.glob(os.path.join(root, "writers", "*")))
                for sub in blockers:
                    if not os.path.isdir(sub):
                        continue
                    if WriterLease.held(sub, lease_ttl):
                        holder = WriterLease.holder(sub)
                        raise LeaseHeldError(
                            f"{sub!r} has a live writer "
                            f"(pid {holder and holder.get('pid')}); "
                            "exclusive open must wait for writers to close"
                        )
                    if os.path.dirname(sub).endswith("writers"):
                        # crashed writer's presence slot: clean it up
                        try:
                            lock = os.path.join(sub, WriterLease.FILENAME)
                            if os.path.exists(lock):
                                os.remove(lock)
                            os.rmdir(sub)
                        except OSError:
                            pass
            except BaseException:
                root_lease.release()
                raise
        else:
            root_lease = None
            if not os.path.exists(os.path.join(root, "catalog.json")):
                raise FileNotFoundError(
                    f"writer-mode open needs an initialized store at "
                    f"{root!r}; create it with ShardedDSLog.open(root, "
                    "n_shards, exclusive=True) first"
                )
            if WriterLease.held(root, lease_ttl):
                raise LeaseHeldError(
                    f"store {root!r} is open exclusively; writer-mode "
                    "ingest must wait for the exclusive owner to close"
                )
            # register presence *before* touching any file, so a racing
            # exclusive open sees this writer even while it is idle (its
            # shard leases are only taken on the first write)
            presence_lease = WriterLease.acquire(
                os.path.join(root, "writers", uuid.uuid4().hex),
                ttl=lease_ttl,
                what="writer slot of",
            )
            if WriterLease.held(root, lease_ttl):  # exclusive won the race
                presence_lease.release()
                raise LeaseHeldError(
                    f"store {root!r} is open exclusively; writer-mode "
                    "ingest must wait for the exclusive owner to close"
                )
        try:
            pipeline = CommitPipeline(durability, flush_interval, max_batch)
            if os.path.exists(os.path.join(root, "catalog.json")):
                log = cls.load(root, pipeline=pipeline)
                _apply_open_overrides(log, ctor_kw)
            else:
                log = cls(n_shards=n_shards, root=root, policy=policy, **ctor_kw)
                log._pipeline = pipeline
            log._exclusive = exclusive
            log._root_lease = root_lease
            log._presence_lease = presence_lease
            # the pipeline predates the store object: retarget its
            # instruments at the facade registry (interim counts carry over)
            pipeline.bind_metrics(log.metrics)
            if log._wal is None:
                log._wal = WriteAheadLog(
                    os.path.join(root, WAL_FILENAME),
                    shared=True,
                    metrics=log.metrics,
                )
            pipeline.attach(log._wal)
            if exclusive:
                # sole owner (root lock held, no live writers): torn tails
                # may be physically cut from every log we recovered
                log._wal.repair()
                for sh in log._shards:
                    if sh is not None and sh._wal is not None:
                        sh._wal.repair()
                if not os.path.exists(os.path.join(root, "catalog.json")):
                    log.save()  # initial manifest: writer mode needs it
            return log
        except BaseException:
            if root_lease is not None:
                root_lease.release()
            if presence_lease is not None:
                presence_lease.release()
            raise

    def close(self, checkpoint: bool = True) -> None:
        """Flush, checkpoint when allowed, release every lease (idempotent).

        An exclusive owner checkpoints (manifests rewritten, logs
        truncated) unless ``checkpoint=False``; a writer-mode process only
        flushes its logs — its work becomes manifest state at the next
        exclusive open.  A store that was merely ``load()``-ed (no root
        lock held) never checkpoints on close: truncating logs without the
        locks could destroy a live writer's records.
        """
        if self._closed:
            return
        self._closed = True
        try:
            if self._pipeline is not None:
                self._pipeline.commit()
            if (
                checkpoint
                and self._exclusive
                and self.root
                and self._root_lease is not None
            ):
                self.save()
        finally:
            if self._pipeline is not None:
                self._pipeline.close()
            if self._wal is not None:
                self._wal.close()
                self._wal = None
            for sh in self._shards:
                if sh is not None and sh._wal is not None:
                    sh._wal.close()
                    sh._wal = None
            for lease in self._shard_leases.values():
                lease.release()
            self._shard_leases.clear()
            if self._presence_lease is not None:
                slot = os.path.dirname(self._presence_lease.path)
                self._presence_lease.release()
                self._presence_lease = None
                try:
                    os.rmdir(slot)
                except OSError:
                    pass
            if self._root_lease is not None:
                self._root_lease.release()
                self._root_lease = None

    def commit(self) -> None:
        """Durability barrier over the root log and every shard log."""
        if self._pipeline is not None:
            self._pipeline.commit()
        else:
            for wal in [self._wal] + [
                sh._wal for sh in self._shards if sh is not None
            ]:
                if wal is not None:
                    wal.flush(sync=True)

    def checkpoint(self) -> None:
        """Exclusive-mode checkpoint: incremental save + log truncation."""
        self.save()

    # ------------------------------------------------------------------ #
    # Persistence: root manifest + independently saved shard manifests
    # ------------------------------------------------------------------ #
    def save(self) -> None:
        """Save dirty shards and (when needed) the root manifest.

        Each shard's DSLog dirty-tracks its own entries, so only shards
        that changed since the last save write anything — manifests
        included.  The root manifest (policy, array→shard map, topology,
        boundary table, ops, predictor) rewrites only when facade-level
        state changed.  When WALs are attached this is the **checkpoint**:
        every saved log is truncated after its manifest records the
        checkpoint LSN.  Writer-mode stores must not call this — their
        manifests belong to the next exclusive owner.
        """
        if not self.root:
            raise ValueError("ShardedDSLog opened without a root directory")
        if not self._exclusive:
            raise RuntimeError(
                "writer-mode store persists through its WALs; manifests are "
                "rewritten by the next exclusive open/close"
            )
        # Phase 1: shard manifests, WAL truncation DEFERRED — a crash
        # before the root manifest lands must leave the shard logs
        # replayable, or the new cross-shard topology would be lost.
        saved_shards: list[DSLog] = []
        for sh in self._shards:
            if sh is not None and (
                sh.dirty or (sh._wal is not None and sh._wal.has_records)
            ):
                sh.save(checkpoint_wal=False)
                saved_shards.append(sh)
        # write-only telemetry sidecar (facade + loaded shards merged);
        # refreshed on every checkpoint, never read back by load()
        _atomic_write(
            os.path.join(self.root, "telemetry.json"),
            json.dumps(telemetry_snapshot(self)),
        )
        manifest = os.path.join(self.root, "catalog.json")
        if not (
            self._meta_dirty
            or self.predictor.dirty
            or self.views.dirty
            or self._predictor_chunk is None
            or (self._wal is not None and self._wal.has_records)
            or not os.path.exists(manifest)
        ):
            # no root rewrite needed (nothing topology-level changed, so
            # the shard logs held no entries the root does not know)
            if self._root_lease is not None:
                self._checkpoint_shard_wals(saved_shards)
            return
        if self._predictor_chunk is None or self.predictor.dirty:
            self._predictor_chunk = self._write_predictor()
        edges = [
            [src, dst, lid, self._lid_shard[lid]]
            for (src, dst), ids in self.by_pair.items()
            for lid in ids
        ]
        meta = {
            "version": _ROOT_MANIFEST_VERSION,
            "sharded": True,
            "n_shards": self.n_shards,
            "policy": self.policy.to_manifest(),
            "arrays": {
                n: {"shape": list(a.shape), "shard": self.shard_of_array(n)}
                for n, a in self.arrays.items()
            },
            "edges": edges,
            "boundary": [list(rec) for rec in self.sgraph.boundary_edges()],
            "next_id": self._next_id,
            "shard_next": list(self._shard_next),
            "versions": dict(self._versions),
            "hop_decay": self.hop_decay,
            "ops": [
                {
                    "op": op.op_name,
                    "in": list(op.in_arrs),
                    "out": list(op.out_arrs),
                    "args": _json_safe(op.op_args),
                    "lineage_ids": list(op.lineage_ids),
                    "reused": op.reused,
                }
                for op in self.ops
            ],
            "predictor": self._predictor_chunk,
        }
        if self._wal is not None:
            self.commit()
            meta["wal_lsn"] = self._wal.end_lsn
        # whole-route views live on the root: their routes cross shard
        # boundaries, so only the facade sees every invalidation source
        meta["views"] = self.views.manifest_chunk(self._write_view_blob)
        _atomic_write(
            os.path.join(self.root, "answers.json"),
            json.dumps(self.views.cache_chunk()),
        )
        _atomic_write(
            os.path.join(self.root, "autotune.json"),
            json.dumps(self.autotune.to_manifest()),
        )
        self.autotune.dirty = False
        payload = json.dumps(meta)
        _atomic_write(manifest, payload)
        self._bump("manifests_written")
        self._bump("bytes_written", len(payload))
        self._meta_dirty = False
        # Phase 2: every manifest is durable — now the logs may truncate,
        # but only as the locked owner (a merely load()-ed store saving
        # must not cut logs a live writer may be appending to; replay
        # skips its records via the wal_lsn values just recorded)
        if self._root_lease is not None:
            self._checkpoint_shard_wals(saved_shards)
            if self._wal is not None:
                self._wal_lsn = self._wal.checkpoint()

    @staticmethod
    def _checkpoint_shard_wals(shards: list[DSLog]) -> None:
        for sh in shards:
            if sh._wal is not None:
                sh._wal_lsn = sh._wal.checkpoint()

    # borrowed writer: view blobs land in the root dir next to sig tables
    _write_view_blob = DSLog._write_view_blob

    def _view_lsns(self) -> dict[str, int]:
        """End LSN of every WAL that could invalidate a view: the root log
        plus each shard's — a view's route may span any subset of shards,
        so all logs count.  Unloaded shards are probed by file (cheap frame
        scan) rather than forcing a manifest load.  An in-memory store has
        no WALs: every horizon is 0."""
        if self.root is None:
            return {"root": 0, **{f"shard_{k:02d}": 0 for k in range(self.n_shards)}}
        lsns = {"root": self._wal.end_lsn if self._wal is not None else 0}
        for k in range(self.n_shards):
            sh = self._shards[k]
            if sh is not None and sh._wal is not None:
                end = sh._wal.end_lsn
            else:
                sub = self._shard_dir(k)
                end = (
                    WriteAheadLog.file_end_lsn(os.path.join(sub, WAL_FILENAME))
                    if sub is not None
                    else 0
                )
            lsns[f"shard_{k:02d}"] = end
        return lsns

    def _make_view_handle(self, fn: str, rows) -> TableHandle:
        assert self.root is not None
        root = self.root

        def load() -> CompressedTable:
            with open(os.path.join(root, fn), "rb") as f:
                return CompressedTable.deserialize(f.read())

        return TableHandle(
            load,
            None if rows is None else int(rows),
            lambda: self._bump("tables_loaded"),
        )

    @staticmethod
    def load(
        root: str,
        eager: bool = False,
        pipeline: "CommitPipeline | None" = None,
    ) -> "ShardedDSLog":
        """Reopen a sharded root without touching any *clean* shard.

        The root manifest restores the policy, array→shard map, global
        topology (graph + boundary table), ops, version counters, and
        predictor state; each shard's own manifest (and its blobs) resolves
        lazily the first time a plan or query touches that shard —
        ``io_stats["shards_loaded"]`` counts those resolutions.  Pass
        ``eager=True`` to open every shard up front.

        **Crash recovery**: the root log's tail past the manifest's
        checkpoint LSN is replayed (arrays, ops, versions, predictor
        observations, drops), and every shard whose WAL holds records is
        opened eagerly so its entry tail replays and folds back into the
        global topology.  Recovery cost is proportional to the
        un-checkpointed tails, not to the store.
        """
        with open(os.path.join(root, "catalog.json")) as f:
            meta = json.load(f)
        if not meta.get("sharded"):
            raise ValueError(
                f"{root!r} holds a plain DSLog catalog; use DSLog.load"
            )
        policy = ShardPolicy.from_manifest(meta["policy"])
        log = ShardedDSLog(n_shards=policy.n_shards, root=root, policy=policy)
        log._pipeline = pipeline
        for name, rec in meta["arrays"].items():
            log.arrays[name] = ArrayDef(name, tuple(rec["shape"]))
            log._array_shard[name] = int(rec["shard"])
        for src, dst, lid, shard in meta["edges"]:
            lid, shard = int(lid), int(shard)
            log.sgraph.add_edge(src, dst, lid, log.shard_of_array(src), shard)
            log.by_pair.setdefault((src, dst), []).append(lid)
            log._lid_shard[lid] = shard
        log._next_id = int(meta["next_id"])
        if "shard_next" in meta:
            log._shard_next = [int(x) for x in meta["shard_next"]]
        else:  # pre-WAL manifest: ids were minted sequentially — start all
            # per-shard streams past the global max so nothing can collide
            base = (log._next_id + log.n_shards - 1) // log.n_shards
            log._shard_next = [base] * log.n_shards
        log._versions = {k: int(v) for k, v in meta.get("versions", {}).items()}
        log.hop_decay = float(meta.get("hop_decay", log.hop_decay))
        for op in meta.get("ops", []):
            log.ops.append(
                _OpRecord(
                    op["op"],
                    tuple(op["in"]),
                    tuple(op["out"]),
                    op["args"],
                    list(op["lineage_ids"]),
                    op["reused"],
                )
            )
        chunk = meta.get("predictor")
        if chunk is not None:

            def load_table(fn: str) -> CompressedTable:
                with open(os.path.join(root, fn), "rb") as f:
                    return CompressedTable.deserialize(f.read())

            log.predictor = ReusePredictor.from_manifest(chunk, load_table)
            log._predictor_chunk = chunk
        log._meta_dirty = False
        log._wal_lsn = int(meta.get("wal_lsn", 0))
        # views + cached answers restore BEFORE WAL replay (root tail and
        # shard tails alike): replayed entry/drop/dirty records then fire
        # the same precise invalidation they did live
        log.views.load_chunk(meta.get("views"), log._make_view_handle)
        answers = os.path.join(root, "answers.json")
        if os.path.exists(answers):
            try:
                with open(answers) as f:
                    log.views.load_cache_chunk(json.load(f))
            except (ValueError, KeyError):
                pass  # torn/stale sidecar: start with a cold cache
        autotune = os.path.join(root, "autotune.json")
        if os.path.exists(autotune):
            try:
                with open(autotune) as f:
                    log.autotune.load_manifest(json.load(f))
            except ValueError:
                pass  # torn sidecar: start with a cold geometry table
        log._recover_wals()
        if eager:
            for k in range(log.n_shards):
                log.shard(k)
        return log

    def _recover_wals(self) -> None:
        """Replay the root-log tail, then every shard whose WAL holds
        records (their entries fold into the topology via ``shard()``)."""
        assert self.root is not None
        drops: list[int] = []
        if os.path.exists(os.path.join(self.root, WAL_FILENAME)):
            self._wal = WriteAheadLog(
                os.path.join(self.root, WAL_FILENAME), shared=True
            )
            if self._pipeline is not None:
                self._pipeline.attach(self._wal)
            replayed = self._wal.recover(self._wal_lsn)
            for rec in replayed:
                self._replay_root_record(rec, drops)
            if replayed:
                self._bump("wal_replayed", len(replayed))
        for k in range(self.n_shards):
            sub = self._shard_dir(k)
            if sub is None:
                continue
            wal_path = os.path.join(sub, WAL_FILENAME)
            if WriteAheadLog.file_has_records(wal_path):
                self.shard(k)  # DSLog.load replays; shard() absorbs
        for lid in drops:
            if lid in self._lid_shard:
                self._replaying = True
                try:
                    self.drop_lineage(lid)
                finally:
                    self._replaying = False

    # store-level branches (array/version/op/obs) shared with DSLog replay
    _replay_store_record = DSLog._replay_store_record

    def _replay_root_record(self, rec, drops: list[int]) -> None:
        """Apply one recovered root-log record (store-level state only;
        entries live in, and replay from, the shard logs).  Drops are
        deferred so they apply after the shard tails are absorbed."""
        if rec.type == "drop":
            drops.append(int(rec.meta["id"]))
            return
        self._replaying = True
        try:
            self._replay_store_record(rec)
        finally:
            self._replaying = False

    def compact(self) -> dict[str, int]:
        """Vacuum every shard independently, plus root-level sig blobs."""
        if not self.root:
            raise ValueError("ShardedDSLog opened without a root directory")
        self.save()
        stats = {"files_removed": 0, "bytes_reclaimed": 0}
        for k in range(self.n_shards):
            sub = self._shard_dir(k)
            if sub is None or not os.path.isdir(sub):
                continue
            # the facade save() already synced dirty shards
            for key, val in self.shard(k).compact(save=False).items():
                stats[key] += val
        # the root dir owns no lineage blobs, only predictor sig tables
        # and materialized-view blobs
        referenced = manifest_referenced_files((), self._predictor_chunk)
        referenced |= self.views.blob_files()
        for key, val in _vacuum_dir(self.root, referenced).items():
            stats[key] += val
        return stats

    # ------------------------------------------------------------------ #
    def __repr__(self) -> str:
        return (
            f"ShardedDSLog(n_shards={self.n_shards}, arrays={len(self.arrays)}, "
            f"entries={len(self._lid_shard)}, "
            f"boundary={len(self.sgraph.boundary)}, "
            f"loaded={self.loaded_shards()})"
        )
