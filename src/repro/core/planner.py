"""Cost-based multi-hop query planning over the lineage DAG (paper §V, grown).

The paper's ``prov_query`` walks a user-supplied *path* of arrays.  This
module replaces the hand-spelled path with a plan over the
:class:`~repro.core.graph.LineageGraph`:

1. **Routing** — given source/target endpoint sets, the planner finds the
   sub-DAG of arrays lying on any dataflow path between them (two BFS
   passes, never an exponential path enumeration) and orders it
   topologically, so converging branches of a diamond are *merged* at their
   fan-in array instead of re-walked once per path.
2. **Materialization choice** — per hop and per stored
   :class:`~repro.core.catalog.LineageEntry`, the planner picks the cheapest
   way to execute the θ-join: the table whose *key* side matches the
   frontier (natural join) or the opposite materialization through the
   inverse join, and the indexed vs dense route — reusing the
   :class:`~repro.core.index.IntervalIndex` machinery: a cached index gives
   an exact candidate estimate for the first hop
   (:meth:`~repro.core.index.IntervalIndex.estimate_candidates`); deeper
   hops use the closed-form per-attribute overlap model of
   :func:`~repro.core.index.interval_stats`.
3. **Frontier dedup** — between hops every array's frontier is the
   concatenation of all incoming contributions, deduplicated and coalesced
   with :func:`~repro.core.query.merge_boxes`, so diamond-shaped DAGs do not
   multiply the box count path by path.

Plans cost and execute against *lazy* catalogs: row counts come from the
manifest (``LineageEntry.backward_rows`` / ``forward_rows``) so planning a
query over a freshly loaded store touches no blobs; only the tables on the
chosen hops deserialize, at execution time.

``plan_path`` keeps the paper's explicit-path form alive on the same
executor (one hop per adjacent pair, every stored entry between the pair
contributing), so ``DSLog.prov_query`` serves both forms from one engine.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Iterable, Sequence

import numpy as np

from .query import (
    DENSE_FRACTION,
    INDEX_MIN_ROWS,
    BatchedJoinExecutor,
    JoinRequest,
    QueryBox,
    canonical_boxes,
    dense_backend,
    merge_boxes,
    theta_join_batch,
    theta_join_inverse_batch,
)

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .catalog import DSLog, LineageEntry

__all__ = ["HopChoice", "EdgeStep", "QueryPlan", "QueryPlanner"]


def _fmt_lid(lineage_id: int) -> str:
    """EXPLAIN label for a hop id: negative ids are materialized views."""
    if lineage_id < 0:
        return f"view#{-lineage_id - 1}"
    return f"#{lineage_id}"

# Cost-model constants (unitless "per candidate pair" work).
_INVERSE_OVERHEAD = 2.0  # inverse join does strictly more per-pair work
_INDEX_BUILD_WEIGHT = 0.25  # amortized first-build cost of an uncached index
_POINT_ROW_COVER = 4.0  # unloaded-table fallback: rows a point probe hits
_MERGE_SHRINK = 0.5  # expected box-count shrink from merge_boxes
# measured per-pair advantage of the packed batched-dense engine over the
# per-hop blocked loop (contiguous int32 columns + one dispatch per
# frontier); makes "batched" competitive where "dense" would lose to the
# index by less than ~2x.  This is the *prior* at perfect tile occupancy —
# the effective discount scales by the executor's measured tile waste
# (scheduled tile cells / useful pair cells), so frontiers whose shape pads
# badly stop looking artificially cheap to the batched route.
_BATCHED_PAIR_DISCOUNT = 0.5


@dataclass
class HopChoice:
    """One executable option for one lineage entry on one hop."""

    lineage_id: int
    stored: str  # "backward" | "forward": which materialization to read
    frontier_on: str  # "key" (natural join) | "value" (inverse join)
    route: str  # "index" | "dense" | "batched" (packed frontier execution)
    est_pairs: float
    est_cost: float
    # dense-route backend annotation ("tpu", "np:cpu", "np:wide", "np:i64")
    # — why a dense hop will or won't ride the kernel; shown by describe()
    note: str = ""

    def describe_route(self) -> str:
        return f"{self.route}({self.note})" if self.note else self.route


@dataclass
class EdgeStep:
    """Process every lineage entry between one frontier/produced node pair."""

    u: str  # plan-node key the frontier is read from
    v: str  # plan-node key the step produces
    choices: list[HopChoice]

    @property
    def est_pairs(self) -> float:
        return sum(c.est_pairs for c in self.choices)


@dataclass
class QueryPlan:
    """Ordered, costed execution plan between two endpoint sets.

    Plan nodes are opaque keys (equal to array names for graph plans; path
    plans suffix the position so a path may revisit an array).  ``steps``
    maps each produced node to its incoming :class:`EdgeStep`s; ``order``
    lists every node in frontier-propagation order, starts first.
    """

    direction: str  # "forward" | "backward" | "path"
    starts: tuple[str, ...]  # node keys where the query frontier lands
    target_keys: dict[str, str]  # array name -> plan-node key
    order: list[str]
    node_array: dict[str, str]  # plan-node key -> array name
    steps: dict[str, list[EdgeStep]] = field(default_factory=dict)
    est_cost: float = 0.0
    # estimated frontier box count per plan node (filled by the planner;
    # consumed by the sharded planner's boundary-exchange cost term)
    est_boxes: dict[str, float] = field(default_factory=dict)
    # EXPLAIN ANALYZE accumulators, filled as the plan executes (plans are
    # memoized and shared across queries, so these are totals over every
    # execution): (u, v, lineage_id, stored, frontier_on) -> counters,
    # plus "__exec_ms__" for packed-dispatch wall time.  Guarded by the
    # owning store's _stats_lock.
    measured: dict = field(default_factory=dict)

    def _measured_for(self, step: "EdgeStep", choice: "HopChoice"):
        return self.measured.get(
            (step.u, step.v, choice.lineage_id, choice.stored, choice.frontier_on)
        )

    def _analyze_line(self, step: "EdgeStep", choice: "HopChoice") -> str:
        rec = self._measured_for(step, choice)
        est = (
            f"est_pairs={choice.est_pairs:.0f} est_cost={choice.est_cost:.0f}"
        )
        if rec is None:
            return f"      {_fmt_lid(choice.lineage_id)}: {est} | not executed"
        measured = (
            f"measured pairs={rec['pairs']} qrows={rec['qrows']} "
            f"calls={rec['calls']}"
        )
        if rec["timed"]:
            measured += f" time={rec['ms']:.3f}ms"
        return f"      {_fmt_lid(choice.lineage_id)}: {est} | {measured}"

    def describe(self, analyze: bool = False) -> str:
        """Human-readable plan, one line per hop (EXPLAIN-style).

        ``analyze=True`` is EXPLAIN ANALYZE: each hop choice gains a
        sub-line comparing the cost model's estimates against measured
        pair counts (and per-hop wall time where the serial engine timed
        individual joins) accumulated over the plan's executions.
        """
        header = (
            f"{self.direction} plan, {len(self.order)} nodes, "
            f"est_cost={self.est_cost:.0f}"
        )
        if analyze:
            exec_ms = self.measured.get("__exec_ms__")
            if exec_ms is not None:
                header += (
                    f", measured exec={exec_ms[0]:.3f}ms"
                    f" over {exec_ms[1]} dispatches"
                )
        lines = [header]
        for key in self.order:
            for step in self.steps.get(key, []):
                opts = ", ".join(
                    f"{_fmt_lid(c.lineage_id)}:{c.stored}/"
                    f"{'nat' if c.frontier_on == 'key' else 'inv'}/"
                    f"{c.describe_route()}"
                    for c in step.choices
                )
                lines.append(
                    f"  {self.node_array[step.u]} -> "
                    f"{self.node_array[step.v]}  [{opts}]"
                )
                if analyze:
                    for c in step.choices:
                        lines.append(self._analyze_line(step, c))
        return "\n".join(lines)


class QueryPlanner:
    """Plan and execute multi-hop lineage queries for one :class:`DSLog`."""

    def __init__(self, log: "DSLog"):
        self.log = log
        # default thread-pool width for execute(); None/1 = serial
        self.parallel: int | None = None
        # pack each frontier's dense joins into one blocked evaluation
        # (the BatchedJoinExecutor); False = the per-hop join loop
        self.batched: bool = True
        self._executor: BatchedJoinExecutor | None = None

    @property
    def executor(self) -> BatchedJoinExecutor:
        """The (lazily created) batched join engine, metering io_stats.

        Launch geometry comes from the store's persisted autotune table
        (``log.autotune``), so a reopened store starts on its measured
        winners instead of re-tuning.
        """
        if self._executor is None:
            self._executor = BatchedJoinExecutor(
                stats=self.log._bump,
                tuner=getattr(self.log, "autotune", None),
                metrics=getattr(self.log, "metrics", None),
                trace_source=lambda: getattr(self.log, "_active_trace", None),
            )
        return self._executor

    def _batched_discount(self) -> float:
        """Per-pair cost multiplier for the batched-dense route.

        The flat prior sharpened by the executor's measured tile occupancy:
        before any dispatch this is exactly ``_BATCHED_PAIR_DISCOUNT``;
        once frontiers run, padding-heavy shapes raise it toward (and past)
        parity with the per-hop dense cost, capped at 1.0 so measurement
        never makes batched look *worse* than the engine it replaces wholesale.
        """
        return min(1.0, _BATCHED_PAIR_DISCOUNT * self.executor.measured_waste)

    def _entry(self, lineage_id: int) -> "LineageEntry":
        """Resolve a hop id to its entry; negative ids are view shortcuts
        (``repro.core.views``), served by the store's :class:`ViewManager`."""
        if lineage_id < 0:
            return self.log.views.entry_for(lineage_id)
        return self.log.lineage[lineage_id]

    # ------------------------------------------------------------------ #
    # planning
    # ------------------------------------------------------------------ #
    def plan(
        self,
        sources: str | Iterable[str],
        targets: str | Iterable[str],
        frontier: Sequence[QueryBox] | None = None,
        batched: bool | None = None,
    ) -> QueryPlan:
        """Plan between endpoint sets; query cells live on ``sources``.

        Orientation is inferred from the graph: a *forward* query when the
        targets are downstream of the sources, *backward* when upstream.
        ``frontier`` (the actual initial boxes, when already known) sharpens
        the first hop's cost estimates; the plan is valid without it.
        ``batched`` (default ``planner.batched``) selects the engine the
        cost model targets, so routes always match the engine that will
        execute them.
        """
        batched = self.batched if batched is None else batched
        g = self.log.graph
        src_set = {sources} if isinstance(sources, str) else set(sources)
        dst_set = {targets} if isinstance(targets, str) else set(targets)
        for name in src_set | dst_set:
            if name not in self.log.arrays:
                raise KeyError(f"unknown array {name!r}")
        if src_set & dst_set:
            raise ValueError("source and target sets must be disjoint")

        nodes, edges = g.induced_subdag(src_set, dst_set)
        if nodes:
            direction = "forward"
            up_set, down_set = src_set, dst_set
        else:
            nodes, edges = g.induced_subdag(dst_set, src_set)
            if not nodes:
                raise KeyError(
                    f"no lineage route between {sorted(src_set)} and "
                    f"{sorted(dst_set)}"
                )
            direction = "backward"
            up_set, down_set = dst_set, src_set
        covered_dst = nodes & dst_set
        if covered_dst != dst_set:
            missing = sorted(dst_set - covered_dst)
            raise KeyError(f"no lineage route to target(s) {missing}")

        topo = g.topo_order(nodes)
        order = topo if direction == "forward" else topo[::-1]
        plan = QueryPlan(
            direction=direction,
            starts=tuple(sorted(src_set & nodes)),
            target_keys={n: n for n in sorted(dst_set)},
            order=order,
            node_array={n: n for n in nodes},
        )
        # Estimated frontier box count per node, seeded by the real frontier.
        nq0 = self._frontier_boxes(frontier)
        est_boxes = plan.est_boxes
        est_boxes.update({s: nq0 for s in plan.starts})
        for key in order:
            if key in plan.starts:
                continue
            if direction == "forward":
                frontier_nodes = sorted({u for (u, v) in edges if v == key})
            else:  # frontier flows dataflow-downstream → upstream
                frontier_nodes = sorted({v for (u, v) in edges if u == key})
            for u in frontier_nodes:
                entries = (
                    g.edge_ids(u, key)
                    if direction == "forward"
                    else g.edge_ids(key, u)
                )
                step = self._build_step(
                    u,
                    key,
                    entries,
                    traverse="forward" if direction == "forward" else "backward",
                    nq=max(est_boxes.get(u, 1.0), 1.0),
                    frontier=frontier if u in plan.starts else None,
                    batched=batched,
                )
                plan.steps.setdefault(key, []).append(step)
                plan.est_cost += sum(c.est_cost for c in step.choices)
                est_boxes[key] = est_boxes.get(key, 0.0) + max(
                    1.0, step.est_pairs * _MERGE_SHRINK
                )
        # Materialized-view shortcut: when a composed view covers the whole
        # route, cost a one-hop plan over it and race it against the base
        # plan — the view wins exactly when the cost model says it should.
        if len(src_set) == 1 and len(dst_set) == 1:
            vplan = self._view_plan(
                next(iter(src_set)), next(iter(dst_set)), frontier, nq0, batched
            )
            tr = getattr(self.log, "_active_trace", None)
            if vplan is not None and vplan.est_cost < plan.est_cost:
                self.log._bump("view_hits")
                if tr is not None:
                    tr.event(
                        "view_race",
                        kind="view",
                        winner="view",
                        view_cost=round(vplan.est_cost, 3),
                        base_cost=round(plan.est_cost, 3),
                    )
                return vplan
            self.log._bump("view_misses")
            if tr is not None:
                tr.event(
                    "view_race",
                    kind="view",
                    winner="base",
                    view_cost=(
                        None if vplan is None else round(vplan.est_cost, 3)
                    ),
                    base_cost=round(plan.est_cost, 3),
                )
        return plan

    def _view_plan(
        self,
        src: str,
        dst: str,
        frontier: Sequence[QueryBox] | None,
        nq0: float,
        batched: bool,
    ) -> QueryPlan | None:
        """One-hop plan over a materialized view covering ``src -> dst``
        (either orientation), or None when no live view matches."""
        views = getattr(self.log, "views", None)
        if views is None:
            return None
        pid = views.shortcut_for(src, dst)
        if pid is None:
            return None
        g = self.log.graph
        direction = (
            "forward" if g.shortcut_id(src, dst) == pid else "backward"
        )
        vplan = QueryPlan(
            direction=direction,
            starts=(src,),
            target_keys={dst: dst},
            order=[src, dst],
            node_array={src: src, dst: dst},
        )
        step = self._build_step(
            src, dst, [pid], traverse=direction, nq=nq0,
            frontier=frontier, batched=batched,
        )
        vplan.steps[dst] = [step]
        vplan.est_cost = sum(c.est_cost for c in step.choices)
        vplan.est_boxes.update(
            {src: nq0, dst: max(1.0, step.est_pairs * _MERGE_SHRINK)}
        )
        return vplan

    def plan_path(
        self,
        path: Sequence[str],
        frontier: Sequence[QueryBox] | None = None,
        batched: bool | None = None,
    ) -> QueryPlan:
        """Plan the paper's explicit-path query form on the same executor.

        One hop per adjacent pair; every stored entry between the pair
        contributes, whichever dataflow direction it was registered in.
        Node keys carry the position so a path may legally revisit an array.
        """
        batched = self.batched if batched is None else batched
        if len(path) < 2:
            raise ValueError("path needs at least two arrays")
        keys = [f"{k}:{name}" for k, name in enumerate(path)]
        plan = QueryPlan(
            direction="path",
            starts=(keys[0],),
            target_keys={path[-1]: keys[-1]},
            order=list(keys),
            node_array=dict(zip(keys, path)),
        )
        nq = self._frontier_boxes(frontier)
        plan.est_boxes[keys[0]] = nq
        for k, (a, b) in enumerate(zip(path[:-1], path[1:])):
            # entries stored with dataflow b -> a: frontier sits on their dst
            ids_down = self.log.by_pair.get((b, a), [])
            # entries stored with dataflow a -> b: frontier sits on their src
            ids_up = self.log.by_pair.get((a, b), [])
            if not ids_down and not ids_up:
                raise KeyError(f"no lineage stored between {a!r} and {b!r}")
            choices: list[HopChoice] = []
            hop_frontier = frontier if k == 0 else None
            for lid in ids_down:
                choices.append(
                    self._best_choice(lid, "backward", nq, hop_frontier, batched)
                )
            for lid in ids_up:
                choices.append(
                    self._best_choice(lid, "forward", nq, hop_frontier, batched)
                )
            step = EdgeStep(keys[k], keys[k + 1], choices)
            plan.steps[keys[k + 1]] = [step]
            plan.est_cost += sum(c.est_cost for c in choices)
            nq = max(1.0, step.est_pairs * _MERGE_SHRINK)
            plan.est_boxes[keys[k + 1]] = nq
        return plan

    # ------------------------------------------------------------------ #
    def _build_step(
        self,
        u: str,
        v: str,
        lineage_ids: list[int],
        traverse: str,
        nq: float,
        frontier: Sequence[QueryBox] | None,
        batched: bool = True,
    ) -> EdgeStep:
        choices = [
            self._best_choice(lid, traverse, nq, frontier, batched)
            for lid in lineage_ids
        ]
        return EdgeStep(u, v, choices)

    def _best_choice(
        self,
        lineage_id: int,
        traverse: str,
        nq: float,
        frontier: Sequence[QueryBox] | None,
        batched: bool = True,
    ) -> HopChoice:
        """Cheapest (materialization, route) for one entry on one hop.

        ``traverse`` is relative to the entry's dataflow: "forward" moves the
        frontier src→dst (frontier matches the *forward* table's keys or the
        backward table's values), "backward" the reverse.
        """
        entry = self._entry(lineage_id)
        options: list[HopChoice] = []
        if traverse == "backward":
            options.append(
                self._cost_option(
                    entry, lineage_id, "backward", "key", nq, frontier, batched
                )
            )
            if entry.has_forward:
                options.append(
                    self._cost_option(
                        entry, lineage_id, "forward", "value", nq, frontier,
                        batched,
                    )
                )
        else:
            if entry.has_forward:
                options.append(
                    self._cost_option(
                        entry, lineage_id, "forward", "key", nq, frontier,
                        batched,
                    )
                )
            options.append(
                self._cost_option(
                    entry, lineage_id, "backward", "value", nq, frontier,
                    batched,
                )
            )
        return min(options, key=lambda c: c.est_cost)

    def _cost_option(
        self,
        entry: "LineageEntry",
        lineage_id: int,
        stored: str,
        frontier_on: str,
        nq: float,
        frontier: Sequence[QueryBox] | None,
        batched: bool = True,
    ) -> HopChoice:
        nr = entry.backward_rows if stored == "backward" else entry.forward_rows
        nr = max(int(nr), 1)
        table = entry.peek_table(stored)  # None while the blob is unloaded
        measured = self.log.hop_measurement(lineage_id, stored, frontier_on)
        est_pairs = self._estimate_pairs(
            table, nr, frontier_on, nq, frontier, measured
        )
        dense_cost = nq * nr * (self._batched_discount() if batched else 1.0)
        # route: small tables and unselective frontiers go dense
        if nr < INDEX_MIN_ROWS or est_pairs > DENSE_FRACTION * nq * nr:
            route = "batched" if batched else "dense"
            join_cost = dense_cost
        else:
            route = "index"
            join_cost = est_pairs + nq * math.log2(nr + 1)
            has_index = table is not None and (
                table.cached_key_index() is not None
                if frontier_on == "key"
                else table.cached_val_index() is not None
            )
            if not has_index:
                join_cost += _INDEX_BUILD_WEIGHT * nr * math.log2(nr + 1)
            # the batched-route option: with packed frontier execution the
            # dense engine is cheap enough to beat a selective index on
            # some hops the per-hop model would never route dense
            if batched and dense_cost < join_cost:
                route, join_cost = "batched", dense_cost
        if route != "index":
            choice_note = self._dense_note(
                entry, stored, frontier_on, table, segmented=route == "batched"
            )
        else:
            choice_note = ""
        if frontier_on == "value":
            join_cost *= _INVERSE_OVERHEAD
        return HopChoice(
            lineage_id, stored, frontier_on, route, est_pairs, join_cost,
            note=choice_note,
        )

    def _dense_note(
        self,
        entry: "LineageEntry",
        stored: str,
        frontier_on: str,
        table,
        segmented: bool = True,
    ) -> str:
        """Backend annotation for a dense/batched hop (see ``dense_backend``).

        Attribute width comes from the array shapes (known without loading
        the blob); the int32-overflow check needs the actual bounds, so it
        only sharpens the note once the table is resident — execution
        re-checks exactly either way.
        """
        key_name = entry.dst if stored == "backward" else entry.src
        val_name = entry.src if stored == "backward" else entry.dst
        side = key_name if frontier_on == "key" else val_name
        n_attrs = len(self.log.arrays[side].shape)
        int32_ok = True
        if table is not None:
            int32_ok = table.int32_safe(
                "key" if frontier_on == "key" else "value"
            )
        note = dense_backend(n_attrs, int32_ok, segmented=segmented)
        if segmented:
            # batched hops also show the launch geometry the executor will
            # use, e.g. "batched(tpu:64x256)" / "batched(np:cpu:4m)"
            note = f"{note}:{self.executor.geometry_label(note)}"
        return note

    def _estimate_pairs(
        self,
        table,
        nr: int,
        frontier_on: str,
        nq: float,
        frontier: Sequence[QueryBox] | None,
        measured: float | None = None,
    ) -> float:
        """Expected candidate pairs for one hop.

        Preference order: an already-cached IntervalIndex probed with the
        *real* frontier (exact, first hop only) → the measured per-box pair
        count fed back from earlier executions of this hop
        (:meth:`~repro.core.catalog.DSLog.hop_measurement`) → closed-form
        overlap model from the table's interval stats → row-cover fallback
        when the blob has not been deserialized yet.
        """
        if table is not None and frontier is not None:
            boxes = [q for q in frontier if q.n_rows]
            if boxes:
                q_lo = np.concatenate([q.lo for q in boxes], axis=0)
                q_hi = np.concatenate([q.hi for q in boxes], axis=0)
                idx = (
                    table.cached_key_index()
                    if frontier_on == "key"
                    else table.cached_val_index()
                )
                if idx is not None:
                    total = idx.estimate_candidates(q_lo, q_hi)
                    return max(1.0, total / len(frontier))
                if measured is None:
                    mean_q = (q_hi - q_lo + 1).mean(axis=0)
                    return self._overlap_model(table, frontier_on, nq, mean_q)
        if measured is not None:
            return max(1.0, measured * nq)
        if table is None:
            return nq * min(float(nr), _POINT_ROW_COVER)
        return self._overlap_model(table, frontier_on, nq, None)

    @staticmethod
    def _overlap_model(table, frontier_on, nq, mean_q) -> float:
        mean_r, span = (
            table.key_stats() if frontier_on == "key" else table.val_stats()
        )
        if mean_q is None:
            mean_q = np.ones_like(mean_r)
        p = np.minimum(1.0, (mean_q + mean_r - 1.0) / span)
        return float(nq) * table.n_rows * float(np.prod(p))

    @staticmethod
    def _frontier_boxes(frontier: Sequence[QueryBox] | None) -> float:
        if not frontier:
            return 1.0
        return max(1.0, float(np.mean([q.n_rows for q in frontier])))

    # ------------------------------------------------------------------ #
    # execution
    # ------------------------------------------------------------------ #
    def execute(
        self,
        plan: QueryPlan,
        queries: "Sequence[QueryBox] | dict[str, Sequence[QueryBox]]",
        merge: bool = True,
        collect: str = "targets",
        parallel: int | None = None,
        batched: bool | None = None,
    ) -> dict[str, list[QueryBox]]:
        """Run ``plan`` for a batch of queries rooted at its start node(s).

        Nodes are processed in plan order; each node concatenates the
        contributions of all incoming steps (plus its share of the initial
        frontier, for start nodes) and — with ``merge`` — deduplicates the
        combined frontier via ``merge_boxes``: the diamond fan-in
        optimization.  ``queries`` is the batch for a single-start plan, or
        ``{array name: batch}`` when the plan has several start arrays (all
        batches the same length).  Returns ``{array name: [QueryBox per
        query]}`` for the targets (or every node with ``collect="all"``).

        ``parallel=N`` (or setting ``planner.parallel``) runs *independent*
        plan nodes — parallel branches of the DAG and, on a sharded store,
        per-shard sub-plans with no pending exchange between them — on an
        N-thread pool.  Each node still accumulates its incoming steps in
        plan order, so results are identical to serial execution.

        ``batched`` (default ``planner.batched``) picks the join engine:
        ``True`` packs every dense join ready in a plan frontier — across
        branches and sub-plans — into one blocked evaluation through the
        :class:`~repro.core.query.BatchedJoinExecutor` (in parallel mode,
        one packed evaluation per node, with the GIL-releasing twin letting
        workers overlap); ``False`` is the serial per-hop join loop.  Both
        engines return bit-identical results.
        """
        if isinstance(queries, dict):
            start_by_array = {plan.node_array[k]: k for k in plan.starts}
            unknown = sorted(set(queries) - set(start_by_array))
            if unknown:
                raise KeyError(
                    f"query batches for non-start array(s) {unknown}; "
                    f"plan starts at {sorted(start_by_array)}"
                )
            missing = sorted(set(start_by_array) - set(queries))
            if missing:
                raise ValueError(
                    f"missing query batch for start array(s) {missing}"
                )
            by_start = {
                start_by_array[name]: qs for name, qs in queries.items()
            }
        else:
            if len(plan.starts) != 1:
                raise ValueError(
                    "multi-start plan: pass queries as {array name: batch}"
                )
            by_start = {plan.starts[0]: queries}
        init: dict[str, list[QueryBox]] = {}
        lengths = set()
        for key, qs in by_start.items():
            shape = self.log.arrays[plan.node_array[key]].shape
            boxes = [
                q if isinstance(q, QueryBox) else QueryBox.from_cells(shape, q)
                for q in qs
            ]
            if merge:
                boxes = [merge_boxes(q) for q in boxes]
            init[key] = boxes
            lengths.add(len(boxes))
        if len(lengths) > 1:
            raise ValueError("per-start query batches must have equal length")
        nB = lengths.pop() if lengths else 0

        workers = parallel if parallel is not None else self.parallel
        use_batched = self.batched if batched is None else batched
        if use_batched and plan.steps:
            frontier = self._execute_waves(plan, init, nB, merge, workers)
        elif workers is not None and workers > 1 and len(plan.order) > 1:
            frontier = self._execute_parallel(plan, init, nB, merge, workers)
        else:
            frontier = {}
            for key in plan.order:
                frontier[key] = self._compute_node(
                    plan, key, init, frontier, nB, merge, use_batched
                )
        if collect == "all":
            return {plan.node_array[k]: v for k, v in frontier.items()}
        out = {
            name: frontier[key] for name, key in plan.target_keys.items()
        }
        if merge:
            # Final normal form: merge_boxes fixpoints depend on the route
            # taken (per-hop chain vs composed view, sharded vs not), so
            # target answers are re-cut into the canonical decomposition —
            # equal cell sets become equal bytes, whatever plan produced
            # them.
            out = {
                name: [canonical_boxes(q) for q in boxes]
                for name, boxes in out.items()
            }
        return out

    # ------------------------------------------------------------------ #
    # node execution: gather join requests, run them, assemble frontiers
    # ------------------------------------------------------------------ #
    def _gather_requests(
        self,
        plan: QueryPlan,
        key: str,
        frontier: dict[str, list[QueryBox]],
    ) -> list[tuple[EdgeStep, HopChoice, list[QueryBox]]]:
        """One node's pending joins, in plan order of its incoming steps."""
        gathered: list[tuple[EdgeStep, HopChoice, list[QueryBox]]] = []
        for step in plan.steps.get(key, []):
            qs = self._incoming_frontier(plan, step, frontier[step.u])
            for choice in step.choices:
                gathered.append((step, choice, qs))
        return gathered

    def _requests_for(
        self, gathered: list[tuple[EdgeStep, HopChoice, list[QueryBox]]]
    ) -> list[JoinRequest]:
        reqs = []
        for _step, choice, qs in gathered:
            entry = self._entry(choice.lineage_id)
            table = (
                entry.backward if choice.stored == "backward" else entry.forward
            )
            reqs.append(
                JoinRequest(
                    qs,
                    table,
                    inverse=choice.frontier_on == "value",
                    merge=False,
                    path=choice.route,
                )
            )
        return reqs

    def _assemble_node(
        self,
        plan: QueryPlan,
        key: str,
        init: dict[str, list[QueryBox]],
        gathered: list[tuple[EdgeStep, HopChoice, list[QueryBox]]],
        res_lists: list[list[QueryBox]],
        nB: int,
        merge: bool,
        timings: list[float] | None = None,
    ) -> list[QueryBox]:
        """One node's frontier: its init share plus every step's results."""
        shape = self.log.arrays[plan.node_array[key]].shape
        nd = len(shape)
        if key in init and not plan.steps.get(key, []):
            return init[key]
        acc_lo: list[list[np.ndarray]] = [[] for _ in range(nB)]
        acc_hi: list[list[np.ndarray]] = [[] for _ in range(nB)]
        for k, q in enumerate(init.get(key, [])):
            acc_lo[k].append(q.lo)
            acc_hi[k].append(q.hi)
        for i, ((step, choice, qs), res_list) in enumerate(
            zip(gathered, res_lists)
        ):
            self._record_step_output(plan, step, res_list)
            self._record_choice(
                choice,
                qs,
                res_list,
                plan=plan,
                step=step,
                elapsed=None if timings is None else timings[i],
            )
            for k, res in enumerate(res_list):
                acc_lo[k].append(res.lo)
                acc_hi[k].append(res.hi)
        boxes = []
        for k in range(nB):
            lo = (
                np.concatenate(acc_lo[k])
                if acc_lo[k]
                else np.zeros((0, nd), np.int64)
            )
            hi = (
                np.concatenate(acc_hi[k])
                if acc_hi[k]
                else np.zeros((0, nd), np.int64)
            )
            res = QueryBox(shape, lo, hi)
            boxes.append(merge_boxes(res) if merge else res)
        return boxes

    def _compute_node(
        self,
        plan: QueryPlan,
        key: str,
        init: dict[str, list[QueryBox]],
        frontier: dict[str, list[QueryBox]],
        nB: int,
        merge: bool,
        use_batched: bool = False,
    ) -> list[QueryBox]:
        """One node's frontier: its init share plus every incoming step.

        With ``use_batched`` the node's joins — every choice of every
        incoming step — run as one packed executor batch; this is the
        per-node granularity parallel mode uses (each worker packs the node
        it owns).  Results are identical either way.
        """
        gathered = self._gather_requests(plan, key, frontier)
        timings: list[float] | None = None
        if use_batched and gathered:
            res_lists = self.executor.run(self._requests_for(gathered))
        else:
            # the per-hop loop is the one engine that can time individual
            # joins — EXPLAIN ANALYZE shows true per-hop wall time here
            res_lists = []
            timings = []
            for _s, choice, qs in gathered:
                t0 = time.perf_counter()
                res_lists.append(self._join_choice(choice, qs))
                timings.append(time.perf_counter() - t0)
        return self._assemble_node(
            plan, key, init, gathered, res_lists, nB, merge, timings=timings
        )

    def _execute_waves(
        self,
        plan: QueryPlan,
        init: dict[str, list[QueryBox]],
        nB: int,
        merge: bool,
        workers: int | None = None,
    ) -> dict[str, list[QueryBox]]:
        """Frontier execution with whole-wave join batching.

        The plan runs as a sequence of *waves*: every node whose
        dependencies are satisfied is ready, and all ready nodes' joins —
        across plan branches and, on sharded plans, across exchange-free
        per-shard sub-plans — are packed into one
        :meth:`BatchedJoinExecutor.run` dispatch.  Per-node assembly then
        proceeds in plan order, so results are bit-identical to the serial
        per-hop loop.

        ``workers=N`` hands each wave's packed dense segments to an
        N-thread pool inside the executor: the segment tasks are almost
        entirely GIL-releasing blocked numpy, which is what makes thread
        parallelism actually pay on CPU (node-granularity threading — the
        non-batched engine's mode — loses its win to GIL hand-offs between
        the small Python-held assembly steps).
        """
        deps = {
            key: {s.u for s in plan.steps.get(key, [])} for key in plan.order
        }
        frontier: dict[str, list[QueryBox]] = {}
        done: set[str] = set()
        pending = list(plan.order)
        while pending:
            wave = [k for k in pending if deps[k] <= done]
            gathered = {
                k: self._gather_requests(plan, k, frontier) for k in wave
            }
            reqs: list[JoinRequest] = []
            for k in wave:
                reqs.extend(self._requests_for(gathered[k]))
            if reqs:
                t0 = time.perf_counter()
                res = self.executor.run(reqs, workers=workers)
                dt_ms = (time.perf_counter() - t0) * 1e3
                with self.log._stats_lock:
                    acc = plan.measured.setdefault("__exec_ms__", [0.0, 0])
                    acc[0] += dt_ms
                    acc[1] += 1
            else:
                res = []
            off = 0
            for k in wave:
                n = len(gathered[k])
                frontier[k] = self._assemble_node(
                    plan, k, init, gathered[k], res[off : off + n], nB, merge
                )
                off += n
                done.add(k)
            pending = [k for k in pending if k not in done]
        return frontier

    def _execute_parallel(
        self,
        plan: QueryPlan,
        init: dict[str, list[QueryBox]],
        nB: int,
        merge: bool,
        workers: int,
    ) -> dict[str, list[QueryBox]]:
        """Dependency-driven node-level execution on a thread pool.

        The non-batched engine's parallel mode (PR 4): a node is *ready*
        once every node feeding one of its steps has a computed frontier,
        so non-dependent branches — and, through the sharded planner's
        step ownership, exchange-free per-shard sub-plans — run
        concurrently.  Within a node, incoming steps still execute in plan
        order: per-node results are bit-identical to serial execution.
        (With batching enabled, ``execute`` uses wave execution with
        worker-split dense segments instead — see ``_execute_waves``.)
        """
        import concurrent.futures as cf
        import threading

        deps = {
            key: {s.u for s in plan.steps.get(key, [])} for key in plan.order
        }
        frontier: dict[str, list[QueryBox]] = {}
        done: set[str] = set()
        scheduled: set[str] = set()
        errors: list[BaseException] = []
        cond = threading.Condition()
        pool = cf.ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="dslog-exec"
        )

        def schedule_ready_locked() -> None:
            for key in plan.order:
                if key not in scheduled and deps[key] <= done:
                    scheduled.add(key)
                    fut = pool.submit(
                        self._compute_node, plan, key, init, frontier,
                        nB, merge,
                    )
                    fut.add_done_callback(
                        lambda f, key=key: on_done(key, f)
                    )

        def on_done(key: str, fut: "cf.Future") -> None:
            # runs on the worker that finished the node: successors are
            # submitted here, without a round trip through the main thread
            with cond:
                exc = fut.exception()
                if exc is not None:
                    errors.append(exc)
                else:
                    frontier[key] = fut.result()
                    done.add(key)
                    if not errors:
                        schedule_ready_locked()
                cond.notify_all()

        try:
            with cond:
                schedule_ready_locked()
                while len(done) < len(plan.order) and not errors:
                    cond.wait()
            if errors:
                raise errors[0]
        finally:
            pool.shutdown(wait=True)
        return frontier

    def _incoming_frontier(
        self, plan: QueryPlan, step: EdgeStep, qs: list[QueryBox]
    ) -> list[QueryBox]:
        """Hook: transform a step's input frontier before the joins run.

        The base planner passes it through; the sharded planner overrides
        this to account for (and compress) frontiers crossing a shard
        boundary.
        """
        return qs

    def _record_step_output(
        self, plan: QueryPlan, step: EdgeStep, res_list: list[QueryBox]
    ) -> None:
        """Hook: observe one choice's per-query results (sharded planner
        uses it to meter output-side boundary exchanges)."""

    def _join_choice(
        self, choice: HopChoice, qs: list[QueryBox]
    ) -> list[QueryBox]:
        """The per-hop join loop: one choice, one ``theta_join_batch``."""
        entry = self._entry(choice.lineage_id)
        table = entry.backward if choice.stored == "backward" else entry.forward
        if choice.frontier_on == "key":
            return theta_join_batch(qs, table, merge=False, path=choice.route)
        return theta_join_inverse_batch(
            qs, table, merge=False, path=choice.route
        )

    def _record_choice(
        self,
        choice: HopChoice,
        qs: list[QueryBox],
        res: list[QueryBox],
        plan: QueryPlan | None = None,
        step: EdgeStep | None = None,
        elapsed: float | None = None,
    ) -> None:
        # cost-model feedback: the true pair counts this hop produced, keyed
        # by (entry, materialization, join side) — replanning the same
        # catalog prefers these measurements over the closed-form model
        qrows = sum(q.n_rows for q in qs)
        pairs = sum(r.n_rows for r in res)
        if qrows:
            self.log.record_hop(
                choice.lineage_id,
                choice.stored,
                choice.frontier_on,
                pairs=pairs,
                qrows=qrows,
            )
        if plan is not None and step is not None:
            # EXPLAIN ANALYZE: accumulate the measured side against the
            # plan's estimates (plans are memoized — totals over runs)
            mkey = (
                step.u,
                step.v,
                choice.lineage_id,
                choice.stored,
                choice.frontier_on,
            )
            with self.log._stats_lock:
                rec = plan.measured.get(mkey)
                if rec is None:
                    rec = plan.measured[mkey] = {
                        "pairs": 0,
                        "qrows": 0,
                        "calls": 0,
                        "ms": 0.0,
                        "timed": 0,
                    }
                rec["pairs"] += pairs
                rec["qrows"] += qrows
                rec["calls"] += 1
                if elapsed is not None:
                    rec["ms"] += elapsed * 1e3
                    rec["timed"] += 1
        tr = getattr(self.log, "_active_trace", None)
        if tr is not None and step is not None:
            tr.event(
                "hop",
                kind="hop",
                u=plan.node_array[step.u] if plan is not None else step.u,
                v=plan.node_array[step.v] if plan is not None else step.v,
                lid=choice.lineage_id,
                stored=choice.stored,
                route=choice.describe_route(),
                qrows=qrows,
                pairs=pairs,
                duration=elapsed,
            )

    def _run_choice(
        self, choice: HopChoice, qs: list[QueryBox]
    ) -> list[QueryBox]:
        """One choice's join plus its cost feedback (per-hop loop form)."""
        res = self._join_choice(choice, qs)
        self._record_choice(choice, qs, res)
        return res
