"""Cost-based multi-hop query planning over the lineage DAG (paper §V, grown).

The paper's ``prov_query`` walks a user-supplied *path* of arrays.  This
module replaces the hand-spelled path with a plan over the
:class:`~repro.core.graph.LineageGraph`:

1. **Routing** — given source/target endpoint sets, the planner finds the
   sub-DAG of arrays lying on any dataflow path between them (two BFS
   passes, never an exponential path enumeration) and orders it
   topologically, so converging branches of a diamond are *merged* at their
   fan-in array instead of re-walked once per path.
2. **Materialization choice** — per hop and per stored
   :class:`~repro.core.catalog.LineageEntry`, the planner picks the cheapest
   way to execute the θ-join: the table whose *key* side matches the
   frontier (natural join) or the opposite materialization through the
   inverse join, and the indexed vs dense route — reusing the
   :class:`~repro.core.index.IntervalIndex` machinery: a cached index gives
   an exact candidate estimate for the first hop
   (:meth:`~repro.core.index.IntervalIndex.estimate_candidates`); deeper
   hops use the closed-form per-attribute overlap model of
   :func:`~repro.core.index.interval_stats`.
3. **Frontier dedup** — between hops every array's frontier is the
   concatenation of all incoming contributions, deduplicated and coalesced
   with :func:`~repro.core.query.merge_boxes`, so diamond-shaped DAGs do not
   multiply the box count path by path.

Plans cost and execute against *lazy* catalogs: row counts come from the
manifest (``LineageEntry.backward_rows`` / ``forward_rows``) so planning a
query over a freshly loaded store touches no blobs; only the tables on the
chosen hops deserialize, at execution time.

``plan_path`` keeps the paper's explicit-path form alive on the same
executor (one hop per adjacent pair, every stored entry between the pair
contributing), so ``DSLog.prov_query`` serves both forms from one engine.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Iterable, Sequence

import numpy as np

from .query import (
    DENSE_FRACTION,
    INDEX_MIN_ROWS,
    QueryBox,
    merge_boxes,
    theta_join_batch,
    theta_join_inverse_batch,
)

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .catalog import DSLog, LineageEntry

__all__ = ["HopChoice", "EdgeStep", "QueryPlan", "QueryPlanner"]

# Cost-model constants (unitless "per candidate pair" work).
_INVERSE_OVERHEAD = 2.0  # inverse join does strictly more per-pair work
_INDEX_BUILD_WEIGHT = 0.25  # amortized first-build cost of an uncached index
_POINT_ROW_COVER = 4.0  # unloaded-table fallback: rows a point probe hits
_MERGE_SHRINK = 0.5  # expected box-count shrink from merge_boxes


@dataclass
class HopChoice:
    """One executable option for one lineage entry on one hop."""

    lineage_id: int
    stored: str  # "backward" | "forward": which materialization to read
    frontier_on: str  # "key" (natural join) | "value" (inverse join)
    route: str  # "index" | "dense"
    est_pairs: float
    est_cost: float


@dataclass
class EdgeStep:
    """Process every lineage entry between one frontier/produced node pair."""

    u: str  # plan-node key the frontier is read from
    v: str  # plan-node key the step produces
    choices: list[HopChoice]

    @property
    def est_pairs(self) -> float:
        return sum(c.est_pairs for c in self.choices)


@dataclass
class QueryPlan:
    """Ordered, costed execution plan between two endpoint sets.

    Plan nodes are opaque keys (equal to array names for graph plans; path
    plans suffix the position so a path may revisit an array).  ``steps``
    maps each produced node to its incoming :class:`EdgeStep`s; ``order``
    lists every node in frontier-propagation order, starts first.
    """

    direction: str  # "forward" | "backward" | "path"
    starts: tuple[str, ...]  # node keys where the query frontier lands
    target_keys: dict[str, str]  # array name -> plan-node key
    order: list[str]
    node_array: dict[str, str]  # plan-node key -> array name
    steps: dict[str, list[EdgeStep]] = field(default_factory=dict)
    est_cost: float = 0.0
    # estimated frontier box count per plan node (filled by the planner;
    # consumed by the sharded planner's boundary-exchange cost term)
    est_boxes: dict[str, float] = field(default_factory=dict)

    def describe(self) -> str:
        """Human-readable plan, one line per hop (EXPLAIN-style)."""
        lines = [
            f"{self.direction} plan, {len(self.order)} nodes, "
            f"est_cost={self.est_cost:.0f}"
        ]
        for key in self.order:
            for step in self.steps.get(key, []):
                opts = ", ".join(
                    f"#{c.lineage_id}:{c.stored}/"
                    f"{'nat' if c.frontier_on == 'key' else 'inv'}/{c.route}"
                    for c in step.choices
                )
                lines.append(
                    f"  {self.node_array[step.u]} -> "
                    f"{self.node_array[step.v]}  [{opts}]"
                )
        return "\n".join(lines)


class QueryPlanner:
    """Plan and execute multi-hop lineage queries for one :class:`DSLog`."""

    def __init__(self, log: "DSLog"):
        self.log = log
        # default thread-pool width for execute(); None/1 = serial
        self.parallel: int | None = None

    # ------------------------------------------------------------------ #
    # planning
    # ------------------------------------------------------------------ #
    def plan(
        self,
        sources: str | Iterable[str],
        targets: str | Iterable[str],
        frontier: Sequence[QueryBox] | None = None,
    ) -> QueryPlan:
        """Plan between endpoint sets; query cells live on ``sources``.

        Orientation is inferred from the graph: a *forward* query when the
        targets are downstream of the sources, *backward* when upstream.
        ``frontier`` (the actual initial boxes, when already known) sharpens
        the first hop's cost estimates; the plan is valid without it.
        """
        g = self.log.graph
        src_set = {sources} if isinstance(sources, str) else set(sources)
        dst_set = {targets} if isinstance(targets, str) else set(targets)
        for name in src_set | dst_set:
            if name not in self.log.arrays:
                raise KeyError(f"unknown array {name!r}")
        if src_set & dst_set:
            raise ValueError("source and target sets must be disjoint")

        nodes, edges = g.induced_subdag(src_set, dst_set)
        if nodes:
            direction = "forward"
            up_set, down_set = src_set, dst_set
        else:
            nodes, edges = g.induced_subdag(dst_set, src_set)
            if not nodes:
                raise KeyError(
                    f"no lineage route between {sorted(src_set)} and "
                    f"{sorted(dst_set)}"
                )
            direction = "backward"
            up_set, down_set = dst_set, src_set
        covered_dst = nodes & dst_set
        if covered_dst != dst_set:
            missing = sorted(dst_set - covered_dst)
            raise KeyError(f"no lineage route to target(s) {missing}")

        topo = g.topo_order(nodes)
        order = topo if direction == "forward" else topo[::-1]
        plan = QueryPlan(
            direction=direction,
            starts=tuple(sorted(src_set & nodes)),
            target_keys={n: n for n in sorted(dst_set)},
            order=order,
            node_array={n: n for n in nodes},
        )
        # Estimated frontier box count per node, seeded by the real frontier.
        nq0 = self._frontier_boxes(frontier)
        est_boxes = plan.est_boxes
        est_boxes.update({s: nq0 for s in plan.starts})
        for key in order:
            if key in plan.starts:
                continue
            if direction == "forward":
                frontier_nodes = sorted({u for (u, v) in edges if v == key})
            else:  # frontier flows dataflow-downstream → upstream
                frontier_nodes = sorted({v for (u, v) in edges if u == key})
            for u in frontier_nodes:
                entries = (
                    g.edge_ids(u, key)
                    if direction == "forward"
                    else g.edge_ids(key, u)
                )
                step = self._build_step(
                    u,
                    key,
                    entries,
                    traverse="forward" if direction == "forward" else "backward",
                    nq=max(est_boxes.get(u, 1.0), 1.0),
                    frontier=frontier if u in plan.starts else None,
                )
                plan.steps.setdefault(key, []).append(step)
                plan.est_cost += sum(c.est_cost for c in step.choices)
                est_boxes[key] = est_boxes.get(key, 0.0) + max(
                    1.0, step.est_pairs * _MERGE_SHRINK
                )
        return plan

    def plan_path(
        self,
        path: Sequence[str],
        frontier: Sequence[QueryBox] | None = None,
    ) -> QueryPlan:
        """Plan the paper's explicit-path query form on the same executor.

        One hop per adjacent pair; every stored entry between the pair
        contributes, whichever dataflow direction it was registered in.
        Node keys carry the position so a path may legally revisit an array.
        """
        if len(path) < 2:
            raise ValueError("path needs at least two arrays")
        keys = [f"{k}:{name}" for k, name in enumerate(path)]
        plan = QueryPlan(
            direction="path",
            starts=(keys[0],),
            target_keys={path[-1]: keys[-1]},
            order=list(keys),
            node_array=dict(zip(keys, path)),
        )
        nq = self._frontier_boxes(frontier)
        plan.est_boxes[keys[0]] = nq
        for k, (a, b) in enumerate(zip(path[:-1], path[1:])):
            # entries stored with dataflow b -> a: frontier sits on their dst
            ids_down = self.log.by_pair.get((b, a), [])
            # entries stored with dataflow a -> b: frontier sits on their src
            ids_up = self.log.by_pair.get((a, b), [])
            if not ids_down and not ids_up:
                raise KeyError(f"no lineage stored between {a!r} and {b!r}")
            choices: list[HopChoice] = []
            hop_frontier = frontier if k == 0 else None
            for lid in ids_down:
                choices.append(
                    self._best_choice(lid, "backward", nq, hop_frontier)
                )
            for lid in ids_up:
                choices.append(
                    self._best_choice(lid, "forward", nq, hop_frontier)
                )
            step = EdgeStep(keys[k], keys[k + 1], choices)
            plan.steps[keys[k + 1]] = [step]
            plan.est_cost += sum(c.est_cost for c in choices)
            nq = max(1.0, step.est_pairs * _MERGE_SHRINK)
            plan.est_boxes[keys[k + 1]] = nq
        return plan

    # ------------------------------------------------------------------ #
    def _build_step(
        self,
        u: str,
        v: str,
        lineage_ids: list[int],
        traverse: str,
        nq: float,
        frontier: Sequence[QueryBox] | None,
    ) -> EdgeStep:
        choices = [
            self._best_choice(lid, traverse, nq, frontier) for lid in lineage_ids
        ]
        return EdgeStep(u, v, choices)

    def _best_choice(
        self,
        lineage_id: int,
        traverse: str,
        nq: float,
        frontier: Sequence[QueryBox] | None,
    ) -> HopChoice:
        """Cheapest (materialization, route) for one entry on one hop.

        ``traverse`` is relative to the entry's dataflow: "forward" moves the
        frontier src→dst (frontier matches the *forward* table's keys or the
        backward table's values), "backward" the reverse.
        """
        entry = self.log.lineage[lineage_id]
        options: list[HopChoice] = []
        if traverse == "backward":
            options.append(
                self._cost_option(
                    entry, lineage_id, "backward", "key", nq, frontier
                )
            )
            if entry.has_forward:
                options.append(
                    self._cost_option(
                        entry, lineage_id, "forward", "value", nq, frontier
                    )
                )
        else:
            if entry.has_forward:
                options.append(
                    self._cost_option(
                        entry, lineage_id, "forward", "key", nq, frontier
                    )
                )
            options.append(
                self._cost_option(
                    entry, lineage_id, "backward", "value", nq, frontier
                )
            )
        return min(options, key=lambda c: c.est_cost)

    def _cost_option(
        self,
        entry: "LineageEntry",
        lineage_id: int,
        stored: str,
        frontier_on: str,
        nq: float,
        frontier: Sequence[QueryBox] | None,
    ) -> HopChoice:
        nr = entry.backward_rows if stored == "backward" else entry.forward_rows
        nr = max(int(nr), 1)
        table = entry.peek_table(stored)  # None while the blob is unloaded
        measured = self.log.hop_measurement(lineage_id, stored, frontier_on)
        est_pairs = self._estimate_pairs(
            table, nr, frontier_on, nq, frontier, measured
        )
        # route: small tables and unselective frontiers go dense
        if nr < INDEX_MIN_ROWS or est_pairs > DENSE_FRACTION * nq * nr:
            route = "dense"
            join_cost = nq * nr
        else:
            route = "index"
            join_cost = est_pairs + nq * math.log2(nr + 1)
            has_index = table is not None and (
                table.cached_key_index() is not None
                if frontier_on == "key"
                else table.cached_val_index() is not None
            )
            if not has_index:
                join_cost += _INDEX_BUILD_WEIGHT * nr * math.log2(nr + 1)
        if frontier_on == "value":
            join_cost *= _INVERSE_OVERHEAD
        return HopChoice(lineage_id, stored, frontier_on, route, est_pairs, join_cost)

    def _estimate_pairs(
        self,
        table,
        nr: int,
        frontier_on: str,
        nq: float,
        frontier: Sequence[QueryBox] | None,
        measured: float | None = None,
    ) -> float:
        """Expected candidate pairs for one hop.

        Preference order: an already-cached IntervalIndex probed with the
        *real* frontier (exact, first hop only) → the measured per-box pair
        count fed back from earlier executions of this hop
        (:meth:`~repro.core.catalog.DSLog.hop_measurement`) → closed-form
        overlap model from the table's interval stats → row-cover fallback
        when the blob has not been deserialized yet.
        """
        if table is not None and frontier is not None:
            boxes = [q for q in frontier if q.n_rows]
            if boxes:
                q_lo = np.concatenate([q.lo for q in boxes], axis=0)
                q_hi = np.concatenate([q.hi for q in boxes], axis=0)
                idx = (
                    table.cached_key_index()
                    if frontier_on == "key"
                    else table.cached_val_index()
                )
                if idx is not None:
                    total = idx.estimate_candidates(q_lo, q_hi)
                    return max(1.0, total / len(frontier))
                if measured is None:
                    mean_q = (q_hi - q_lo + 1).mean(axis=0)
                    return self._overlap_model(table, frontier_on, nq, mean_q)
        if measured is not None:
            return max(1.0, measured * nq)
        if table is None:
            return nq * min(float(nr), _POINT_ROW_COVER)
        return self._overlap_model(table, frontier_on, nq, None)

    @staticmethod
    def _overlap_model(table, frontier_on, nq, mean_q) -> float:
        mean_r, span = (
            table.key_stats() if frontier_on == "key" else table.val_stats()
        )
        if mean_q is None:
            mean_q = np.ones_like(mean_r)
        p = np.minimum(1.0, (mean_q + mean_r - 1.0) / span)
        return float(nq) * table.n_rows * float(np.prod(p))

    @staticmethod
    def _frontier_boxes(frontier: Sequence[QueryBox] | None) -> float:
        if not frontier:
            return 1.0
        return max(1.0, float(np.mean([q.n_rows for q in frontier])))

    # ------------------------------------------------------------------ #
    # execution
    # ------------------------------------------------------------------ #
    def execute(
        self,
        plan: QueryPlan,
        queries: "Sequence[QueryBox] | dict[str, Sequence[QueryBox]]",
        merge: bool = True,
        collect: str = "targets",
        parallel: int | None = None,
    ) -> dict[str, list[QueryBox]]:
        """Run ``plan`` for a batch of queries rooted at its start node(s).

        Nodes are processed in plan order; each node concatenates the
        contributions of all incoming steps (plus its share of the initial
        frontier, for start nodes) and — with ``merge`` — deduplicates the
        combined frontier via ``merge_boxes``: the diamond fan-in
        optimization.  ``queries`` is the batch for a single-start plan, or
        ``{array name: batch}`` when the plan has several start arrays (all
        batches the same length).  Returns ``{array name: [QueryBox per
        query]}`` for the targets (or every node with ``collect="all"``).

        ``parallel=N`` (or setting ``planner.parallel``) runs *independent*
        plan nodes — parallel branches of the DAG and, on a sharded store,
        per-shard sub-plans with no pending exchange between them — on an
        N-thread pool.  Each node still accumulates its incoming steps in
        plan order, so results are identical to serial execution.
        """
        if isinstance(queries, dict):
            start_by_array = {plan.node_array[k]: k for k in plan.starts}
            unknown = sorted(set(queries) - set(start_by_array))
            if unknown:
                raise KeyError(
                    f"query batches for non-start array(s) {unknown}; "
                    f"plan starts at {sorted(start_by_array)}"
                )
            missing = sorted(set(start_by_array) - set(queries))
            if missing:
                raise ValueError(
                    f"missing query batch for start array(s) {missing}"
                )
            by_start = {
                start_by_array[name]: qs for name, qs in queries.items()
            }
        else:
            if len(plan.starts) != 1:
                raise ValueError(
                    "multi-start plan: pass queries as {array name: batch}"
                )
            by_start = {plan.starts[0]: queries}
        init: dict[str, list[QueryBox]] = {}
        lengths = set()
        for key, qs in by_start.items():
            shape = self.log.arrays[plan.node_array[key]].shape
            boxes = [
                q if isinstance(q, QueryBox) else QueryBox.from_cells(shape, q)
                for q in qs
            ]
            if merge:
                boxes = [merge_boxes(q) for q in boxes]
            init[key] = boxes
            lengths.add(len(boxes))
        if len(lengths) > 1:
            raise ValueError("per-start query batches must have equal length")
        nB = lengths.pop() if lengths else 0

        workers = parallel if parallel is not None else self.parallel
        if workers is not None and workers > 1 and len(plan.order) > 1:
            frontier = self._execute_parallel(plan, init, nB, merge, workers)
        else:
            frontier = {}
            for key in plan.order:
                frontier[key] = self._compute_node(plan, key, init, frontier, nB, merge)
        if collect == "all":
            return {plan.node_array[k]: v for k, v in frontier.items()}
        return {
            name: frontier[key] for name, key in plan.target_keys.items()
        }

    def _compute_node(
        self,
        plan: QueryPlan,
        key: str,
        init: dict[str, list[QueryBox]],
        frontier: dict[str, list[QueryBox]],
        nB: int,
        merge: bool,
    ) -> list[QueryBox]:
        """One node's frontier: its init share plus every incoming step."""
        shape = self.log.arrays[plan.node_array[key]].shape
        nd = len(shape)
        steps = plan.steps.get(key, [])
        if key in init and not steps:
            return init[key]
        acc_lo: list[list[np.ndarray]] = [[] for _ in range(nB)]
        acc_hi: list[list[np.ndarray]] = [[] for _ in range(nB)]
        for k, q in enumerate(init.get(key, [])):
            acc_lo[k].append(q.lo)
            acc_hi[k].append(q.hi)
        for step in steps:
            qs = self._incoming_frontier(plan, step, frontier[step.u])
            for choice in step.choices:
                res_list = self._run_choice(choice, qs)
                self._record_step_output(plan, step, res_list)
                for k, res in enumerate(res_list):
                    acc_lo[k].append(res.lo)
                    acc_hi[k].append(res.hi)
        boxes = []
        for k in range(nB):
            lo = (
                np.concatenate(acc_lo[k])
                if acc_lo[k]
                else np.zeros((0, nd), np.int64)
            )
            hi = (
                np.concatenate(acc_hi[k])
                if acc_hi[k]
                else np.zeros((0, nd), np.int64)
            )
            res = QueryBox(shape, lo, hi)
            boxes.append(merge_boxes(res) if merge else res)
        return boxes

    def _execute_parallel(
        self,
        plan: QueryPlan,
        init: dict[str, list[QueryBox]],
        nB: int,
        merge: bool,
        workers: int,
    ) -> dict[str, list[QueryBox]]:
        """Dependency-driven execution on a thread pool.

        A node is *ready* once every node feeding one of its steps has a
        computed frontier, so non-dependent branches — and, through the
        sharded planner's step ownership, exchange-free per-shard sub-plans
        — run concurrently.  Within a node, incoming steps still execute in
        plan order: per-node results are bit-identical to serial execution.
        """
        import concurrent.futures as cf
        import threading

        deps = {
            key: {s.u for s in plan.steps.get(key, [])} for key in plan.order
        }
        frontier: dict[str, list[QueryBox]] = {}
        done: set[str] = set()
        scheduled: set[str] = set()
        errors: list[BaseException] = []
        cond = threading.Condition()
        pool = cf.ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="dslog-exec"
        )

        def schedule_ready_locked() -> None:
            for key in plan.order:
                if key not in scheduled and deps[key] <= done:
                    scheduled.add(key)
                    fut = pool.submit(
                        self._compute_node, plan, key, init, frontier,
                        nB, merge,
                    )
                    fut.add_done_callback(
                        lambda f, key=key: on_done(key, f)
                    )

        def on_done(key: str, fut: "cf.Future") -> None:
            # runs on the worker that finished the node: successors are
            # submitted here, without a round trip through the main thread
            with cond:
                exc = fut.exception()
                if exc is not None:
                    errors.append(exc)
                else:
                    frontier[key] = fut.result()
                    done.add(key)
                    if not errors:
                        schedule_ready_locked()
                cond.notify_all()

        try:
            with cond:
                schedule_ready_locked()
                while len(done) < len(plan.order) and not errors:
                    cond.wait()
            if errors:
                raise errors[0]
        finally:
            pool.shutdown(wait=True)
        return frontier

    def _incoming_frontier(
        self, plan: QueryPlan, step: EdgeStep, qs: list[QueryBox]
    ) -> list[QueryBox]:
        """Hook: transform a step's input frontier before the joins run.

        The base planner passes it through; the sharded planner overrides
        this to account for (and compress) frontiers crossing a shard
        boundary.
        """
        return qs

    def _record_step_output(
        self, plan: QueryPlan, step: EdgeStep, res_list: list[QueryBox]
    ) -> None:
        """Hook: observe one choice's per-query results (sharded planner
        uses it to meter output-side boundary exchanges)."""

    def _run_choice(
        self, choice: HopChoice, qs: list[QueryBox]
    ) -> list[QueryBox]:
        entry = self.log.lineage[choice.lineage_id]
        table = entry.backward if choice.stored == "backward" else entry.forward
        if choice.frontier_on == "key":
            res = theta_join_batch(qs, table, merge=False, path=choice.route)
        else:
            res = theta_join_inverse_batch(
                qs, table, merge=False, path=choice.route
            )
        # cost-model feedback: the true pair counts this hop produced, keyed
        # by (entry, materialization, join side) — replanning the same
        # catalog prefers these measurements over the closed-form model
        qrows = sum(q.n_rows for q in qs)
        if qrows:
            self.log.record_hop(
                choice.lineage_id,
                choice.stored,
                choice.frontier_on,
                pairs=sum(r.n_rows for r in res),
                qrows=qrows,
            )
        return res
