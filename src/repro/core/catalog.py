"""DSLog — the lineage storage manager (paper §III, §V, §VI).

The catalog owns:

* named, shape-declared **Arrays** (§III.A ``Array``),
* **lineage entries** — ProvRC-compressed backward (+ optionally forward)
  tables between array pairs (§III.A ``Lineage``),
* the **lineage DAG** (:class:`~repro.core.graph.LineageGraph`) — built
  incrementally as entries arrive (with cycle rejection) and rebuilt from
  the manifest on load,
* **operation registrations** that bundle multiple lineage entries under an
  operation signature and drive automatic reuse prediction (§VI),
* **persistence v2** — a versioned JSON manifest plus one packed binary
  blob per table (optionally zlib-compressed, i.e. ProvRC-GZip).  Reloaded
  tables are *lazy* (:class:`~repro.core.table.TableHandle`): a blob
  deserializes the first time a query or stat actually touches it, and
  ``save()`` rewrites only entries added since the last save/load
  (dirty tracking).  Op records and the
  :class:`~repro.core.reuse.ReusePredictor` state round-trip too, so a
  reopened catalog keeps its confirmed reuse mappings.

Multi-hop ``prov_query`` (§V) comes in two forms, both served by the
cost-based :class:`~repro.core.planner.QueryPlanner`:

* ``prov_query(path, cells)`` — the paper's explicit array path;
* ``prov_query(src, dst, cells)`` — graph form: the planner routes over the
  lineage DAG itself, merging converging branches at fan-in arrays.

Growth beyond the paper: :meth:`DSLog.compact` vacuums blobs orphaned by
:meth:`DSLog.drop_lineage` and predictor updates; :meth:`DSLog.version`
mints ``acc@k`` names for in-place ops; executed hops feed their true pair
counts back into the manifest (:meth:`DSLog.record_hop` /
:meth:`DSLog.hop_measurement`) so replanning uses measured selectivities;
and :class:`~repro.core.shard.ShardedDSLog` serves this whole surface over
N independently persisted shards.
"""

from __future__ import annotations

import hashlib
import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import numpy as np

from repro.kernels.autotune import GeometryTuner  # jax-free geometry table
from repro.obs.export import telemetry_snapshot
from repro.obs.metrics import IoStatsView, MetricsRegistry
from repro.obs.trace import QueryTrace, maybe_span

from . import _locks
from .commit import CommitPipeline, WriterLease
from .graph import CycleError, LineageGraph
from .index import IntervalIndex
from .planner import QueryPlanner
from .provrc import compress
from .query import QueryBox
from .relation import LineageRelation
from .reuse import (
    ReusePredictor,
    sig_key_base,
    sig_key_dim,
    sig_key_gen,
)
from .table import CompressedTable, TableHandle
from .views import ViewManager
from .wal import WAL_FILENAME, WalRecord, WriteAheadLog

__all__ = ["DSLog", "ArrayDef", "LineageEntry"]

# Tables at or above this row count get their key index built and persisted
# at save time, so a reloaded catalog serves its first selective query
# without paying the O(n log n) sort.
_INDEX_PERSIST_MIN_ROWS = 4096

_MANIFEST_VERSION = 3

# Constructor options that open() may apply to an already-loaded store.
# (reuse_m lands on the predictor: the ctor only forwards it there.)
_OPEN_OVERRIDES = ("store_forward", "compress_method", "gzip", "hop_decay", "reuse_m")

# Counters pre-seeded at zero in every store registry so reads and `in`
# checks on the io_stats view behave like the historical dict did.
SEED_COUNTERS = (
    "tables_loaded",
    "tables_written",
    "manifests_written",
    "sig_tables_written",
    "bytes_written",
    # batched plan-step execution: packed dense dispatches (device kernel
    # launches, or their CPU-twin equivalents), how many joins rode each,
    # and pack occupancy (rows used vs padded)
    "kernel_launches",
    "joins_packed",
    "batch_rows",
    "batch_rows_padded",
    # tile schedule of those dispatches: tiles actually evaluated vs the
    # cross-product tiles the block-diagonal layout skipped
    "batch_tiles_visited",
    "batch_tiles_skipped",
    # materialized views + answer cache (repro/core/views.py)
    "view_hits",
    "view_misses",
    "cache_hits",
    "cache_misses",
    "views_materialized",
    "views_demoted",
    "views_invalidated",
)


def _apply_open_overrides(log, ctor_kw: dict) -> None:
    for key, val in ctor_kw.items():
        if key not in _OPEN_OVERRIDES:
            raise TypeError(
                f"unknown store option {key!r} for open(); valid on an "
                f"existing store: {', '.join(_OPEN_OVERRIDES)}"
            )
        if key == "reuse_m" and not hasattr(log, "reuse_m"):
            log.predictor.m = int(val)
        else:
            setattr(log, key, val)
            if key == "reuse_m":
                log.predictor.m = int(val)

# Cost-feedback aging: every new hop measurement decays the accumulated
# (pairs, qrows) mass by this factor before adding its own, so the measured
# selectivity is an exponential moving average — replanning stays honest
# after the workload shifts instead of being pinned to ancient traffic.
_DEFAULT_HOP_DECAY = 0.9
# ...and the accumulated qrows mass is capped, bounding how much history a
# shifted workload has to out-shout (the "sample cap" of the EMA).
_HOP_SAMPLE_CAP = 1e6


def _sig_blob_name(key: str, label: str) -> str:
    """Stable per-(signature, pair-label) blob name.

    Deterministic naming is what makes per-signature dirty tracking work: a
    re-saved signature overwrites its own blobs, a clean signature's blobs
    are never touched, and blobs orphaned by a rejected signature are
    recognizable to :meth:`DSLog.compact`.
    """
    h = hashlib.sha1(key.encode()).hexdigest()[:10]
    return f"sig_{h}_{label.replace(':', '-')}.prvc"


def _atomic_write(path: str, payload: str) -> None:
    """Crash-safe manifest write: temp file + fsync + atomic rename, so a
    torn save can never leave a half-written ``catalog.json`` behind."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _write_blob(path: str, blob: bytes) -> None:
    """Write a manifest-referenced blob durably (write + fsync).

    The manifest only becomes visible through :func:`_atomic_write`'s
    rename; every blob it references must already be on stable storage by
    then, or a crash right after the rename could publish a manifest
    pointing at torn blobs.  Module-level because ``ShardedDSLog`` borrows
    the ``DSLog`` writer methods that call it.
    """
    with open(path, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())


def is_catalog_blob(fn: str) -> bool:
    """Is ``fn`` a blob the catalog owns (and may therefore vacuum)?

    Shared by :func:`_vacuum_dir`'s sweep and ``repro.tools.fsck``'s
    orphan-blob check so GC and verification agree on ownership.
    """
    return (
        (fn.startswith("lineage_") and fn.endswith((".prvc", ".idx")))
        or (fn.startswith("sig_") and fn.endswith(".prvc"))
        or (fn.startswith("view_") and fn.endswith(".prvc"))
    )


def manifest_referenced_files(
    lineage_recs, predictor_chunk, views_chunk=None
) -> set[str]:
    """The blob closure of a manifest: every file its records reference.

    ``lineage_recs`` is an iterable of persisted lineage records (the
    manifest's ``lineage`` list, or ``DSLog._persisted.values()`` — same
    schema); ``predictor_chunk``/``views_chunk`` are the manifest's
    ``predictor``/``views`` chunks or ``None``.  Single source of truth
    shared by :meth:`DSLog.compact` and ``repro.tools.fsck``, so the
    vacuum and the orphan check can't drift.
    """
    referenced = {"catalog.json"}
    for rec in lineage_recs:
        for key in ("file", "idx", "fwd", "fwd_idx"):
            if rec.get(key):
                referenced.add(rec[key])
    if predictor_chunk:
        for rec in predictor_chunk.get("sigs", []):
            referenced.update(rec.get("tables", {}).values())
    if views_chunk:
        for rec in views_chunk.get("views", []):
            for key in ("file", "fwd"):
                if rec.get(key):
                    referenced.add(rec[key])
    return referenced


def _vacuum_dir(root: str, referenced: set[str]) -> dict[str, int]:
    """Delete catalog-owned blob files under ``root`` not in ``referenced``.

    Only files matching the catalog's own naming patterns
    (:func:`is_catalog_blob`) are candidates; anything else in the
    directory is left alone.
    """
    removed = reclaimed = 0
    for fn in os.listdir(root):
        path = os.path.join(root, fn)
        if not os.path.isfile(path) or fn in referenced:
            continue
        if not is_catalog_blob(fn):
            continue
        reclaimed += os.path.getsize(path)
        os.remove(path)
        removed += 1
    return {"files_removed": removed, "bytes_reclaimed": reclaimed}


@dataclass
class ArrayDef:
    name: str
    shape: tuple[int, ...]


class LineageEntry:
    """Compressed lineage between an op input (src) and op output (dst).

    After ``DSLog.load`` the tables are :class:`TableHandle`s: reading
    :attr:`backward` / :attr:`forward` deserializes the blob on first touch.
    Row counts (:meth:`backward_rows` / :meth:`forward_rows`) come from the
    manifest, so the planner can cost a hop without any I/O.
    """

    def __init__(
        self,
        lineage_id: int,
        src: str,
        dst: str,
        backward: "CompressedTable | TableHandle",
        forward: "CompressedTable | TableHandle | None" = None,
        op_name: str | None = None,
        reused_from: str | None = None,
    ):
        self.lineage_id = lineage_id
        self.src = src  # input array name
        self.dst = dst  # output array name
        self.op_name = op_name
        self.reused_from = reused_from
        self._bwd = backward
        self._fwd = forward

    # ------------------------------------------------------------------ #
    @property
    def backward(self) -> CompressedTable:
        """Backward table (keys = dst axes); loads a lazy handle."""
        if isinstance(self._bwd, TableHandle):
            return self._bwd.get()
        return self._bwd

    @property
    def forward(self) -> CompressedTable | None:
        """Forward table (keys = src axes) or None; loads a lazy handle."""
        if isinstance(self._fwd, TableHandle):
            return self._fwd.get()
        return self._fwd

    @property
    def has_forward(self) -> bool:
        """Whether a forward materialization exists, without loading it."""
        return self._fwd is not None

    @property
    def backward_loaded(self) -> bool:
        return not isinstance(self._bwd, TableHandle) or self._bwd.loaded

    @property
    def forward_loaded(self) -> bool:
        if self._fwd is None:
            return False
        return not isinstance(self._fwd, TableHandle) or self._fwd.loaded

    @property
    def backward_rows(self) -> int:
        if isinstance(self._bwd, TableHandle):
            return self._bwd.rows
        return self._bwd.n_rows

    @property
    def forward_rows(self) -> int | None:
        if self._fwd is None:
            return None
        if isinstance(self._fwd, TableHandle):
            return self._fwd.rows
        return self._fwd.n_rows

    def peek_table(self, stored: str) -> CompressedTable | None:
        """The materialized table, or None while the blob is unloaded."""
        obj = self._bwd if stored == "backward" else self._fwd
        if obj is None or isinstance(obj, CompressedTable):
            return obj
        return obj._table

    def __repr__(self) -> str:  # keep the old dataclass-ish readability
        state = "loaded" if self.backward_loaded else "lazy"
        return (
            f"LineageEntry(id={self.lineage_id}, {self.src!r}->{self.dst!r}, "
            f"op={self.op_name!r}, {state})"
        )


@dataclass
class _OpRecord:
    op_name: str
    in_arrs: tuple[str, ...]
    out_arrs: tuple[str, ...]
    op_args: Any
    lineage_ids: list[int] = field(default_factory=list)
    reused: str | None = None


def _json_safe(op_args: Any) -> Any:
    """Best-effort JSON projection of op args for the manifest.

    Non-JSON args degrade to a repr marker: the op record survives the
    round-trip, but signature keys derived from it will no longer match the
    original live object (document-level caveat, not an error).
    """
    try:
        json.dumps(op_args)
        return op_args
    except TypeError:
        return {"__repr__": repr(op_args)}


class DSLog:
    """The lineage index service."""

    def __init__(
        self,
        root: str | None = None,
        store_forward: bool = True,
        compress_method: str = "auto",
        reuse_m: int = 1,
        gzip: bool = True,
        hop_decay: float = _DEFAULT_HOP_DECAY,
    ):
        self.root = root
        self.store_forward = store_forward
        self.compress_method = compress_method
        self.gzip = gzip
        self.hop_decay = float(hop_decay)
        self.arrays: dict[str, ArrayDef] = {}
        self.lineage: dict[int, LineageEntry] = {}
        self.by_pair: dict[tuple[str, str], list[int]] = {}
        self.graph = LineageGraph()
        self.ops: list[_OpRecord] = []
        self.predictor = ReusePredictor(m=reuse_m)
        self.planner = QueryPlanner(self)
        self.views = ViewManager(self)
        # measured launch geometries for the batched join engines, persisted
        # as an autotune.json sidecar and consulted by planner.executor
        self.autotune = GeometryTuner()
        self._next_id = 0
        # persistence bookkeeping: which entries need (re)writing, the
        # manifest records of already-persisted entries, and lazy-I/O
        # counters that tests/benchmarks assert on.
        self._dirty: set[int] = set()
        self._persisted: dict[int, dict] = {}
        self._predictor_chunk: dict | None = None
        # non-blob manifest state (arrays, ops, versions, hop stats) changed
        # since the last save/load — what a sharded root consults to decide
        # whether this shard's manifest needs rewriting at all
        self._meta_dirty = False
        self._stats_lock = _locks.new_rlock("catalog._stats_lock")
        # measured per-hop selectivities: "lid:stored:side" -> [pairs, qrows]
        self.hop_stats: dict[str, list[float]] = _locks.guard_mapping(
            {}, self._stats_lock, "DSLog.hop_stats"
        )
        # versioned-name counters for in-place ops: base name -> latest k
        self._versions: dict[str, int] = {}
        # telemetry: all I/O meters live in the registry (internally
        # locked, rank above _stats_lock); io_stats is a live read-only
        # dict view over its unlabeled counters.
        self.metrics = MetricsRegistry("dslog")
        self.metrics.seed_counters(SEED_COUNTERS)
        self.metrics.register_collector(self._collect_gauges)
        self.io_stats = IoStatsView(self.metrics)
        # per-query structured tracing (prov_query(..., trace=True));
        # None = off, the only cost on untraced hot paths.
        self._active_trace: QueryTrace | None = None
        # durability subsystem (attached by open()/load(); None = legacy
        # explicit-save store with no write-ahead log)
        self._wal: WriteAheadLog | None = None
        self._pipeline: CommitPipeline | None = None
        self._lease: WriterLease | None = None
        self._wal_lsn = 0  # manifest checkpoint LSN: replay starts past it
        self._replaying = False
        self._closed = False
        if root:
            os.makedirs(root, exist_ok=True)

    def _bump(self, key: str, n: int = 1) -> None:
        self.metrics.inc(key, n)

    def _collect_gauges(self):
        """Snapshot-time gauges: hop-stat EMAs and view-manager state.

        Runs outside the registry lock (it takes ``_stats_lock`` /
        ``views._lock``), so derived state exports with zero hot-path
        cost.
        """
        with self._stats_lock:
            hops = {k: tuple(v) for k, v in self.hop_stats.items()}
        # Cap the per-hop series so a huge store exports a bounded page.
        top = sorted(hops.items(), key=lambda kv: -kv[1][0])[:32]
        for key, (pairs, qrows) in top:
            yield ("hop_pairs_ema", {"hop": key}, pairs)
            yield ("hop_qrows_ema", {"hop": key}, qrows)
        try:
            vstats = self.views.stats()
        except Exception:
            return
        for name, val in vstats.items():
            if isinstance(val, (int, float)):
                yield (f"views_{name}", {}, val)

    def metrics_snapshot(self) -> dict:
        """Structured dump of every instrument (see ``repro.obs``)."""
        return self.metrics.snapshot()

    def health(self, run_fsck: bool = True) -> dict:
        """Registry red-flags + ``fsck`` findings (``repro.obs.export``)."""
        from repro.obs.export import health as _health

        return _health(self, run_fsck=run_fsck)

    def _drop_hop_stats(self, lineage_id: int) -> None:
        """Forget measured selectivities for one entry, under the stats lock.

        Deletes in place — never rebinds ``hop_stats`` — so concurrent
        readers (and the race detector's guard wrapper) keep observing the
        same mapping object.
        """
        with self._stats_lock:
            stale = [
                k for k in self.hop_stats if int(k.split(":", 1)[0]) == lineage_id
            ]
            for k in stale:
                del self.hop_stats[k]

    @property
    def dirty(self) -> bool:
        """Anything (entries, predictor, views, or manifest metadata)
        unsaved?"""
        return (
            bool(self._dirty)
            or self.predictor.dirty
            or self._meta_dirty
            or self.views.dirty
        )

    # ------------------------------------------------------------------ #
    # Durable concurrent ingest: WAL, group commit, leases, recovery
    # ------------------------------------------------------------------ #
    @classmethod
    def open(
        cls,
        root: str,
        *,
        durability: str = "group",
        flush_interval: float = 0.005,
        max_batch: int = 256,
        lease_ttl: float = 300.0,
        **ctor_kw,
    ) -> "DSLog":
        """Open ``root`` as the store's (single) writer, durably.

        Acquires the directory's writer lease (a second concurrent open
        raises :class:`~repro.core.commit.LeaseHeldError`), loads the
        manifest if one exists, replays the write-ahead log tail past the
        last checkpoint — truncating any torn trailing record — and
        attaches a :class:`~repro.core.commit.CommitPipeline` so every
        subsequent mutation is logged before it is acknowledged.

        ``durability`` is ``"group"`` (default: one fsync per
        ``flush_interval`` / ``max_batch`` batch), ``"sync"`` (fsync per
        record), or ``"manual"`` (fsync only at :meth:`commit` /
        :meth:`checkpoint`).  Use as a context manager::

            with DSLog.open("/data/lineage") as log:
                log.add_lineage(...)
            # exit = checkpoint (incremental save + log truncation),
            # lease release
        """
        os.makedirs(root, exist_ok=True)
        lease = WriterLease.acquire(root, ttl=lease_ttl)
        try:
            if os.path.exists(os.path.join(root, "catalog.json")):
                log = cls.load(root)
                _apply_open_overrides(log, ctor_kw)
            else:
                log = cls(root=root, **ctor_kw)
            if log._wal is None:
                # fresh store, or an existing store opened durably for the
                # first time: create the log (replays nothing).  A crashed
                # store's log was already replayed by load() above.
                log._attach_wal()
            log._wal.repair()  # we hold the lease: torn tails may be cut
            log._pipeline = CommitPipeline(
                durability, flush_interval, max_batch, metrics=log.metrics
            )
            log._pipeline.attach(log._wal)
            log._lease = lease
            return log
        except BaseException:
            lease.release()
            raise

    def __enter__(self) -> "DSLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self, checkpoint: bool = True) -> None:
        """Flush, optionally checkpoint, and release the writer lease.

        ``checkpoint=False`` leaves the WAL as the only record of unsaved
        work (the next open replays it) — what a crashed writer looks like,
        minus the torn tail.  A store that was merely ``load()``-ed (no
        lease held) never checkpoints on close: truncating the log without
        the lease could destroy a live writer's records.  Idempotent.
        """
        if self._closed:
            return
        self._closed = True
        try:
            if self._pipeline is not None:
                self._pipeline.commit()
            if self._wal is not None:
                if checkpoint and self._lease is not None:
                    self.checkpoint()
                else:
                    self._wal.flush(sync=True)
        finally:
            if self._pipeline is not None:
                self._pipeline.close()
            if self._wal is not None:
                self._wal.close()
                self._wal = None
            if self._lease is not None:
                self._lease.release()
                self._lease = None

    def commit(self) -> None:
        """Durability barrier: every logged mutation is on disk on return."""
        if self._pipeline is not None:
            self._pipeline.commit()
        elif self._wal is not None:
            self._wal.flush(sync=True)

    def checkpoint(self) -> None:
        """Fold the WAL into the manifest: incremental save + truncation."""
        self.save()

    def mark_dirty(self, lineage_id: int) -> None:
        """Declare an entry's tables mutated in place.

        The catalog's dirty tracking only sees *new* entries; a workflow
        that edits a stored table in place must call this so the mutation
        is (a) logged to the WAL now — an explicit invalidation record
        carrying the current table bytes, so a crash cannot silently revert
        it — and (b) rewritten by the next checkpoint.  Cached interval
        indexes and stale hop measurements for the entry are dropped.
        """
        if lineage_id not in self.lineage:
            raise KeyError(f"no lineage entry {lineage_id}")
        e = self.lineage[lineage_id]
        bwd = e.backward  # a mutated table is necessarily resident
        bwd.invalidate_index()
        fwd = e.forward
        if fwd is not None:
            fwd.invalidate_index()
        self._dirty.add(lineage_id)
        self._meta_dirty = True
        self._drop_hop_stats(lineage_id)
        self.views.on_mutation(lineage_id)
        blobs = [bwd.serialize(compress=self.gzip)]
        meta = {"id": lineage_id, "fwd": fwd is not None}
        if fwd is not None:
            blobs.append(fwd.serialize(compress=self.gzip))
        self._wal_append_entry("dirty", meta, blobs)

    # -- internal plumbing --------------------------------------------- #
    def _attach_wal(
        self,
        pipeline: CommitPipeline | None = None,
        truncate: bool = False,
    ) -> int:
        """Open (or create) the root's WAL and replay its tail past the
        manifest checkpoint LSN.  Returns the number of replayed records.

        ``truncate=True`` (torn-tail repair) is reserved for callers that
        hold the store's writer lease — a plain ``load()`` must never
        mutate a log a live writer may still be appending to."""
        assert self.root is not None
        if self._wal is None:
            self._wal = WriteAheadLog(
                os.path.join(self.root, WAL_FILENAME), metrics=self.metrics
            )
        if pipeline is not None:
            self._pipeline = pipeline
            pipeline.attach(self._wal)
        replayed = self._wal.recover(self._wal_lsn, truncate=truncate)
        for rec in replayed:
            self._replay_record(rec)
        if replayed:
            self._bump("wal_replayed", len(replayed))
        return len(replayed)

    def _wal_emit(
        self, wal: WriteAheadLog | None, rtype: str, meta: dict, blobs=()
    ) -> None:
        if wal is None or self._replaying:
            return
        # legacy single-writer stores append without a lease by design:
        # they flush synchronously (below) and never truncate, so a torn
        # tail is the worst a crash leaves.  Truncation stays lease-gated
        # in the save()/checkpoint paths.
        wal.append(rtype, meta, blobs)  # dsflow: ignore[wal-lease]
        if self._pipeline is not None:
            self._pipeline.notify(wal)
        else:  # no pipeline attached (plain load): stay conservative
            wal.flush(sync=True)

    def _wal_append_root(self, rtype: str, meta: dict, blobs=()) -> None:
        """Log a store-level record (arrays, ops, versions, predictor).

        On the sharded facade this targets the root log instead."""
        self._wal_emit(self._wal, rtype, meta, blobs)

    def _wal_append_entry(self, rtype: str, meta: dict, blobs=()) -> None:
        """Log an entry-level record (entry bytes, in-place invalidation)."""
        self._wal_emit(self._wal, rtype, meta, blobs)

    def _entry_wal_record(self, entry: LineageEntry) -> tuple[dict, list]:
        blobs = [entry.backward.serialize(compress=self.gzip)]
        meta = {
            "id": entry.lineage_id,
            "src": entry.src,
            "dst": entry.dst,
            "op": entry.op_name,
            "reused": entry.reused_from,
            "src_shape": list(self.arrays[entry.src].shape),
            "dst_shape": list(self.arrays[entry.dst].shape),
            "fwd": entry.has_forward,
        }
        if entry.has_forward:
            blobs.append(entry.forward.serialize(compress=self.gzip))
        return meta, blobs

    def _replay_store_record(self, rec: WalRecord) -> bool:
        """Apply one *store-level* record (array/version/op/obs) — the
        branches shared verbatim between single-store replay and the
        sharded facade's root-log replay.  Returns False for record types
        the caller must handle itself.  Caller holds ``_replaying``.
        """
        t, m = rec.type, rec.meta
        if t == "array":
            self.define_array(m["name"], tuple(m["shape"]))
        elif t == "version":
            base = m["base"]
            self._versions[base] = max(self._versions.get(base, 0), int(m["k"]))
            self._meta_dirty = True
        elif t == "op":
            self.ops.append(
                _OpRecord(
                    m["op"],
                    tuple(m["in"]),
                    tuple(m["out"]),
                    m["args"],
                    list(m["lids"]),
                    m.get("reused"),
                )
            )
            self._meta_dirty = True
        elif t == "obs":
            captured = {
                label: CompressedTable.deserialize(bytes(blob))
                for label, blob in zip(m["labels"], rec.blobs)
            }
            shapes_token = tuple(tuple(int(x) for x in s) for s in m["shapes"])
            self.predictor.observe(m["dim"], m["gen"], shapes_token, captured)
        else:
            return False
        return True

    def _replay_record(self, rec: WalRecord) -> None:
        """Apply one recovered WAL record to in-memory state.

        Replayed mutations are dirty (the manifest has not seen them) and
        must not re-log themselves — ``_replaying`` gates the WAL hooks.
        """
        t, m = rec.type, rec.meta
        self._replaying = True
        try:
            if self._replay_store_record(rec):
                pass
            elif t == "entry":
                bwd = CompressedTable.deserialize(bytes(rec.blobs[0]))
                fwd = (
                    CompressedTable.deserialize(bytes(rec.blobs[1]))
                    if m.get("fwd")
                    else None
                )
                self.arrays.setdefault(
                    m["src"], ArrayDef(m["src"], tuple(m["src_shape"]))
                )
                self.arrays.setdefault(
                    m["dst"], ArrayDef(m["dst"], tuple(m["dst_shape"]))
                )
                nxt = self._next_id
                self._next_id = int(m["id"])
                self._insert_entry(
                    m["src"], m["dst"], bwd, fwd, m.get("op"), m.get("reused")
                )
                self._next_id = max(nxt, int(m["id"]) + 1)
            elif t == "drop":
                if int(m["id"]) in self.lineage:
                    self.drop_lineage(int(m["id"]))
            elif t == "dirty":
                lid = int(m["id"])
                e = self.lineage.get(lid)
                if e is not None:
                    e._bwd = CompressedTable.deserialize(bytes(rec.blobs[0]))
                    if m.get("fwd") and len(rec.blobs) > 1:
                        e._fwd = CompressedTable.deserialize(bytes(rec.blobs[1]))
                    self._dirty.add(lid)
                    self._meta_dirty = True
                    # replay fires the same precise invalidation the live
                    # mark_dirty call did — views/answers over this entry's
                    # route must not survive recovery
                    self.views.on_mutation(lid)
            # unknown record types are skipped: forward compatibility
        finally:
            self._replaying = False

    # ------------------------------------------------------------------ #
    # Array / lineage definition (paper §III.A)
    # ------------------------------------------------------------------ #
    def define_array(self, name: str, shape: tuple[int, ...]) -> ArrayDef:
        arr = ArrayDef(name, tuple(int(d) for d in shape))
        self.arrays[name] = arr
        self._meta_dirty = True
        self._wal_append_root("array", {"name": name, "shape": list(arr.shape)})
        return arr

    # ------------------------------------------------------------------ #
    # Versioned array names for in-place ops (acc@1 → acc@2 → …)
    # ------------------------------------------------------------------ #
    def version(self, name: str, shape: tuple[int, ...] | None = None) -> str:
        """Mint (and define) the next versioned name for ``name``.

        The lineage DAG rejects self-lineage (``acc → acc``), so in-place /
        accumulator-style updates must be logged under fresh names.  Each
        call returns ``base@k`` with ``k`` increasing from 1; the new array
        is auto-defined with ``shape`` (or the latest version's shape when
        omitted), so the idiom is::

            prev = log.latest_version("acc")
            cur = log.version("acc")
            log.add_lineage(prev, cur, relation)

        Version counters persist in the manifest, so a reloaded catalog
        keeps minting from where it left off.
        """
        base = name.split("@", 1)[0]
        if shape is None:
            prev = self.latest_version(base)
            if prev in self.arrays:
                shape = self.arrays[prev].shape
        k = self._versions.get(base, 0) + 1
        self._versions[base] = k
        new = f"{base}@{k}"
        if shape is not None:
            self.define_array(new, shape)
        self._meta_dirty = True
        self._wal_append_root("version", {"base": base, "k": k})
        return new

    def latest_version(self, name: str) -> str:
        """Current name of ``name``: ``base@k`` after k ``version()`` calls,
        the base name itself before the first."""
        base = name.split("@", 1)[0]
        k = self._versions.get(base, 0)
        return base if k == 0 else f"{base}@{k}"

    def add_lineage(
        self,
        src: str,
        dst: str,
        relation: LineageRelation,
        op_name: str | None = None,
        tables: tuple[CompressedTable, CompressedTable | None] | None = None,
        reused_from: str | None = None,
    ) -> LineageEntry:
        """Ingest one captured relation (src = op input, dst = op output).

        Raises :class:`~repro.core.graph.CycleError` (leaving the catalog
        untouched) when the new edge would make the lineage DAG cyclic.
        """
        self._check_shapes(src, dst, relation)
        if tables is not None:
            bwd, fwd = tables
        else:
            bwd = compress(relation, "backward", self.compress_method)
            fwd = (
                compress(relation, "forward", self.compress_method)
                if self.store_forward
                else None
            )
        return self._insert_entry(src, dst, bwd, fwd, op_name, reused_from)

    def _insert_entry(
        self,
        src: str,
        dst: str,
        bwd: CompressedTable,
        fwd: CompressedTable | None,
        op_name: str | None,
        reused_from: str | None = None,
    ) -> LineageEntry:
        # cycle check first: a rejected edge must not leave a half-inserted
        # entry (graph.add_edge mutates nothing when it raises)
        self.graph.add_edge(src, dst, self._next_id)
        entry = LineageEntry(
            self._next_id, src, dst, bwd, fwd, op_name, reused_from
        )
        self._next_id += 1
        self.lineage[entry.lineage_id] = entry
        self.by_pair.setdefault((src, dst), []).append(entry.lineage_id)
        self._dirty.add(entry.lineage_id)
        self._meta_dirty = True
        self.views.on_new_edge(src, dst)
        if self._wal is not None and not self._replaying:
            meta, blobs = self._entry_wal_record(entry)
            self._wal_append_entry("entry", meta, blobs)
        return entry

    def _remove_entry(self, lineage_id: int) -> None:
        """Undo one :meth:`_insert_entry` (multi-entry rollback)."""
        e = self.lineage.pop(lineage_id)
        ids = self.by_pair[(e.src, e.dst)]
        ids.remove(lineage_id)
        if not ids:
            del self.by_pair[(e.src, e.dst)]
        self.graph.remove_edge(e.src, e.dst, lineage_id)
        self._dirty.discard(lineage_id)
        self._meta_dirty = True

    def drop_lineage(self, lineage_id: int) -> None:
        """Remove one lineage entry from the catalog.

        The entry leaves the graph, pair index, and op records immediately;
        its persisted blobs (if any) stay on disk until :meth:`compact`
        vacuums them — mirroring how dirty-tracked saves never delete files.
        """
        if lineage_id not in self.lineage:
            raise KeyError(f"no lineage entry {lineage_id}")
        self._remove_entry(lineage_id)
        self._persisted.pop(lineage_id, None)
        self._drop_hop_stats(lineage_id)
        self.views.on_mutation(lineage_id)
        for op in self.ops:
            if lineage_id in op.lineage_ids:
                op.lineage_ids.remove(lineage_id)
        self._wal_append_root("drop", {"id": lineage_id})

    # ------------------------------------------------------------------ #
    # Planner cost-model feedback (measured per-hop selectivities)
    # ------------------------------------------------------------------ #
    @staticmethod
    def _hop_key(lineage_id: int, stored: str, frontier_on: str) -> str:
        return f"{lineage_id}:{stored}:{frontier_on}"

    def record_hop(
        self,
        lineage_id: int,
        stored: str,
        frontier_on: str,
        pairs: int,
        qrows: int,
    ) -> None:
        """Fold the true pair count one executed hop produced into the
        measured selectivity — an exponential moving average (each new
        measurement decays the accumulated mass by ``hop_decay``) with a
        sample cap, so the feedback tracks workload shifts instead of
        averaging over all history.  Thread-safe (parallel execution calls
        this from worker threads)."""
        if lineage_id < 0:  # view hop: the ViewManager keeps its own EMA
            return self.views.record_hop(
                lineage_id, stored, frontier_on, pairs, qrows
            )
        with self._stats_lock:
            st = self.hop_stats.setdefault(
                self._hop_key(lineage_id, stored, frontier_on), [0.0, 0.0]
            )
            st[0] = st[0] * self.hop_decay + float(pairs)
            st[1] = st[1] * self.hop_decay + float(qrows)
            if st[1] > _HOP_SAMPLE_CAP:
                scale = _HOP_SAMPLE_CAP / st[1]
                st[0] *= scale
                st[1] *= scale
            self._meta_dirty = True

    def hop_measurement(
        self, lineage_id: int, stored: str, frontier_on: str
    ) -> float | None:
        """Measured pairs-per-query-box for one hop, or None if never run."""
        if lineage_id < 0:
            return self.views.hop_measurement(lineage_id, stored, frontier_on)
        st = self.hop_stats.get(self._hop_key(lineage_id, stored, frontier_on))
        if not st or st[1] <= 0:
            return None
        return st[0] / st[1]

    def _check_shapes(self, src: str, dst: str, rel: LineageRelation) -> None:
        if src in self.arrays and self.arrays[src].shape != rel.in_shape:
            raise ValueError(
                f"array {src} declared {self.arrays[src].shape}, lineage says {rel.in_shape}"
            )
        if dst in self.arrays and self.arrays[dst].shape != rel.out_shape:
            raise ValueError(
                f"array {dst} declared {self.arrays[dst].shape}, lineage says {rel.out_shape}"
            )
        self.arrays.setdefault(src, ArrayDef(src, rel.in_shape))
        self.arrays.setdefault(dst, ArrayDef(dst, rel.out_shape))

    # ------------------------------------------------------------------ #
    # Operation registration with automatic reuse (§III.A, §VI)
    # ------------------------------------------------------------------ #
    def register_operation(
        self,
        op_name: str,
        in_arrs: list[str],
        out_arrs: list[str],
        capture: Callable[[], dict[tuple[int, int], LineageRelation]] | None = None,
        op_args: Any = None,
        reuse: bool | None = None,
    ) -> _OpRecord:
        """Register one executed operation and its lineage.

        ``capture()`` returns ``{(out_pos, in_pos): relation}``.  When reuse
        is enabled (default) and a confirmed signature mapping exists, the
        capture callable is *not* invoked — the stored tables are linked
        instead (this is the paper's capture-bypass).
        """
        in_arrs, out_arrs = tuple(in_arrs), tuple(out_arrs)
        in_shapes = tuple(self.arrays[a].shape for a in in_arrs)
        out_shapes = tuple(self.arrays[a].shape for a in out_arrs)
        dim_key = sig_key_dim(op_name, in_shapes + out_shapes, op_args)
        gen_key = sig_key_gen(op_name, op_args)
        shapes_token = in_shapes + out_shapes
        rec = _OpRecord(op_name, in_arrs, out_arrs, op_args)
        use_reuse = reuse if reuse is not None else True

        pair_shapes = {}
        for oi, oname in enumerate(out_arrs):
            for ii, iname in enumerate(in_arrs):
                pair_shapes[f"{oi}:{ii}"] = (
                    self.arrays[oname].shape,
                    self.arrays[iname].shape,
                )

        if use_reuse:
            decision = self.predictor.lookup(
                dim_key, gen_key, shapes_token, pair_shapes
            )
            if decision.reused:
                assert decision.tables is not None
                try:
                    for label, bwd in decision.tables.items():
                        oi, ii = (int(x) for x in label.split(":"))
                        entry = self._insert_entry(
                            in_arrs[ii],
                            out_arrs[oi],
                            bwd,
                            self._derive_forward(bwd)
                            if self.store_forward
                            else None,
                            op_name,
                            reused_from=decision.source,
                        )
                        rec.lineage_ids.append(entry.lineage_id)
                except CycleError:
                    self._rollback_op(rec)
                    raise
                rec.reused = decision.source
                self.ops.append(rec)
                self._wal_append_root("op", self._op_wal_meta(rec))
                return rec

        if capture is None:
            raise ValueError(
                f"no confirmed reuse mapping for {op_name} and no capture given"
            )
        rels = capture()
        captured_tables: dict[str, CompressedTable] = {}
        try:
            for (oi, ii), rel in rels.items():
                entry = self.add_lineage(
                    in_arrs[ii], out_arrs[oi], rel, op_name=op_name
                )
                rec.lineage_ids.append(entry.lineage_id)
                captured_tables[f"{oi}:{ii}"] = entry.backward
        except CycleError:
            self._rollback_op(rec)
            raise
        if use_reuse:
            self.predictor.observe(dim_key, gen_key, shapes_token, captured_tables)
            if self._wal is not None and not self._replaying:
                labels = sorted(captured_tables)
                self._wal_append_root(
                    "obs",
                    {
                        "dim": dim_key,
                        "gen": gen_key,
                        "shapes": [list(s) for s in shapes_token],
                        "labels": labels,
                    },
                    [
                        captured_tables[label].serialize(compress=self.gzip)
                        for label in labels
                    ],
                )
        self.ops.append(rec)
        self._wal_append_root("op", self._op_wal_meta(rec))
        return rec

    @staticmethod
    def _op_wal_meta(rec: _OpRecord) -> dict:
        return {
            "op": rec.op_name,
            "in": list(rec.in_arrs),
            "out": list(rec.out_arrs),
            "args": _json_safe(rec.op_args),
            "lids": list(rec.lineage_ids),
            "reused": rec.reused,
        }

    def _rollback_op(self, rec: _OpRecord) -> None:
        """Registration is atomic: a mid-op CycleError (one pair of a
        multi-entry op closes a cycle) must not leave the already-inserted
        sibling entries behind."""
        for lid in reversed(rec.lineage_ids):
            self._remove_entry(lid)
        rec.lineage_ids.clear()

    def _derive_forward(self, bwd: CompressedTable) -> CompressedTable | None:
        """Forward table from a reused backward table (via decompress only
        when small; otherwise serve forward queries with the inverse join)."""
        if bwd.n_rows <= 4096:
            rel = bwd.decompress()
            return compress(rel, "forward", self.compress_method)
        return None

    # ------------------------------------------------------------------ #
    # Multi-hop queries (§V) — both forms served by the planner
    # ------------------------------------------------------------------ #
    def prov_query(
        self,
        *args,
        merge: bool = True,
        parallel: int | None = None,
        batched: bool | None = None,
        trace: bool = False,
    ) -> "QueryBox | dict | tuple":
        """Lineage between cells of two arrays.

        Two call forms::

            prov_query(path, cells)        # explicit array path (paper §V)
            prov_query(src, dst, cells)    # planner routes over the DAG

        In graph form the planner infers direction (forward when ``dst`` is
        downstream of ``src``), merges converging branches at fan-in arrays,
        and picks the cheapest stored materialization per hop.  ``dst`` may
        be a sequence of array names — the result is then a dict
        ``{name: QueryBox}``.  ``parallel=N`` executes independent plan
        branches (and, on a sharded store, per-shard sub-plans) on an
        N-thread pool.  ``batched`` picks the join engine (default
        ``planner.batched``): packed frontier execution through the
        :class:`~repro.core.query.BatchedJoinExecutor` vs the per-hop join
        loop — results are bit-identical either way.

        ``trace=True`` returns ``(result, QueryTrace)`` instead: a span
        tree (plan / hop / kernel launch / exchange / cache probe / view
        race) with per-span wall time and instrument deltas.  Tracing
        never changes the answer.
        """
        form = self._parse_query_args(args)
        if form[0] == "path":
            _, path, cells, m_override = form
            if m_override is not None:
                merge = m_override
            res = self.prov_query_batch(
                path,
                [cells],
                merge=merge,
                parallel=parallel,
                batched=batched,
                trace=trace,
            )
            if trace:
                res, tr = res
                return res[0], tr
            return res[0]
        _, src, dst, cells = form
        res = self.prov_query_batch(
            src,
            dst,
            [cells],
            merge=merge,
            parallel=parallel,
            batched=batched,
            trace=trace,
        )
        tr = None
        if trace:
            res, tr = res
        if isinstance(res, dict):
            res = {name: boxes[0] for name, boxes in res.items()}
        else:
            res = res[0]
        return (res, tr) if trace else res

    def prov_query_batch(
        self,
        *args,
        merge: bool = True,
        parallel: int | None = None,
        batched: bool | None = None,
        trace: bool = False,
    ) -> "list[QueryBox] | dict[str, list[QueryBox]] | tuple":
        """Answer many independent queries in one pass (both call forms).

        The plan is computed once; each hop runs through the batched θ-join
        (shared index probes, deduplicated boxes across in-flight queries).
        ``trace=True`` returns ``(result, QueryTrace)``.
        """
        tr = QueryTrace(registry=self.metrics) if trace else None
        workers = parallel if parallel is not None else 0
        use_batched = (
            getattr(self.planner, "batched", True) if batched is None else batched
        )
        engine = (
            "parallel"
            if workers and workers > 1
            else ("batched" if use_batched else "serial")
        )
        prev = self._active_trace
        if tr is not None:
            self._active_trace = tr
        t0 = time.perf_counter()
        try:
            out, path_label = self._query_batch_impl(
                args, merge, parallel, batched, tr, engine
            )
        finally:
            if tr is not None:
                self._active_trace = prev
                tr.finish()
        # per-path query latency: cache hit / view shortcut / full plan,
        # split by execution engine
        self.metrics.observe(
            "query_seconds", time.perf_counter() - t0, path=path_label, engine=engine
        )
        self.metrics.inc("queries", path=path_label)
        return (out, tr) if trace else out

    def _query_batch_impl(
        self, args, merge, parallel, batched, tr, engine
    ) -> tuple:
        """Body of :meth:`prov_query_batch`; returns ``(result, path)``
        where ``path`` labels how the answer was produced (``"cache"`` /
        ``"view"`` / ``"planned"`` / explicit-``"path"`` form)."""
        form = self._parse_query_args(args)
        if form[0] == "path":
            _, path, queries, m_override = form
            if m_override is not None:
                merge = m_override
            if len(path) < 2:
                raise ValueError("path needs at least two arrays")
            if not queries:
                return [], "path"
            boxes = self._as_boxes(path[0], queries)
            with maybe_span(tr, "plan", kind="plan", form="path") as sp:
                plan = self.planner.plan_path(path, frontier=boxes, batched=batched)
                sp.attrs["est_cost"] = round(plan.est_cost, 3)
            with maybe_span(tr, "execute", kind="execute", engine=engine):
                out = self.planner.execute(
                    plan, boxes, merge=merge, parallel=parallel, batched=batched
                )[path[-1]]
            return out, "path"
        _, src, dst, queries = form
        multi = not isinstance(dst, str)
        targets = list(dst) if multi else [dst]
        if not queries:
            return ({t: [] for t in targets} if multi else []), "planned"
        boxes = self._as_boxes(src, queries)
        # answer cache first, planner second: an exact repeat (same source,
        # targets, and canonicalized cell boxes) never plans at all
        ckey = self.views.cache_key(src, targets, boxes, merge)
        hit = self.views.cache_get(ckey) if ckey is not None else None
        if tr is not None:
            tr.event(
                "cache_probe",
                kind="cache",
                cacheable=ckey is not None,
                hit=hit is not None,
            )
        if hit is not None:
            return (hit if multi else hit[dst]), "cache"
        if ckey is not None:
            self.views.note_route(src, targets)
        # plans are cell-independent: a hot route replans only after an
        # invalidation, admission, or demotion changes the shortcut race
        with maybe_span(tr, "plan", kind="plan", form="graph") as sp:
            plan = self.views.plan_get(src, targets, batched)
            sp.attrs["memo"] = plan is not None
            if plan is None:
                plan = self.planner.plan(
                    src, targets, frontier=boxes, batched=batched
                )
                self.views.plan_put(src, targets, batched, plan)
            sp.attrs["est_cost"] = round(plan.est_cost, 3)
        path_label = (
            "view"
            if any(
                c.lineage_id < 0
                for steps in plan.steps.values()
                for step in steps
                for c in step.choices
            )
            else "planned"
        )
        with maybe_span(tr, "execute", kind="execute", engine=engine):
            out = self.planner.execute(
                plan, boxes, merge=merge, parallel=parallel, batched=batched
            )
        if ckey is not None:
            self.views.cache_put(ckey, out, src, targets, plan)
        return (out if multi else out[dst]), path_label

    def _as_boxes(
        self, name: str, queries: Sequence["np.ndarray | QueryBox"]
    ) -> list[QueryBox]:
        shape = self.arrays[name].shape
        return [
            q if isinstance(q, QueryBox) else QueryBox.from_cells(shape, q)
            for q in queries
        ]

    @staticmethod
    def _parse_query_args(args: tuple) -> tuple:
        """Dispatch ``(path, q)`` vs ``(src, dst, q)`` positional forms.

        The pre-graph signature was ``(path, q, merge=True)`` with ``merge``
        accepted positionally; that form still works and comes back as the
        trailing merge override in the "path" tuple.
        """
        if len(args) == 2:
            path, q = args
            if isinstance(path, str):
                raise TypeError(
                    "prov_query(src, dst, cells) needs a dst argument; "
                    "the two-argument form takes a path list"
                )
            return ("path", list(path), q, None)
        if len(args) == 3:
            src, dst, q = args
            if not isinstance(src, str):
                if isinstance(q, (bool, np.bool_)):
                    return ("path", list(src), dst, bool(q))
                raise TypeError(
                    "graph-form prov_query takes a source array name; "
                    "for the path form pass merge as a keyword"
                )
            if not isinstance(dst, (str, list, tuple, set, frozenset)):
                raise TypeError("dst must be an array name or a sequence of names")
            return ("graph", src, dst, q)
        raise TypeError(
            f"prov_query takes (path, cells) or (src, dst, cells); got "
            f"{len(args)} positional arguments"
        )

    # ------------------------------------------------------------------ #
    # Persistence (manifest v2: lazy handles, dirty tracking, reuse state)
    # ------------------------------------------------------------------ #
    def save(self, checkpoint_wal: bool = True) -> None:
        """Write the catalog under ``root``, incrementally.

        Only entries added since the last ``save()``/``load()`` have their
        blobs (and index sidecars) written; already-persisted entries keep
        their files and manifest records verbatim — a lazily loaded entry is
        never even deserialized by a save.  The JSON manifest itself is
        always rewritten (it is small).

        With a WAL attached this is a checkpoint: the manifest records the
        log's end LSN and the log truncates afterwards.  ``checkpoint_wal=
        False`` defers the truncation (the sharded facade saves every shard
        manifest *and the root manifest* first, then truncates all logs —
        a crash between the two must leave the shard logs replayable, or
        the root manifest would silently lose the new topology).
        """
        if not self.root:
            raise ValueError("DSLog opened without a root directory")
        meta = {
            "version": _MANIFEST_VERSION,
            "arrays": {n: list(a.shape) for n, a in self.arrays.items()},
            "lineage": [],
            "next_id": self._next_id,
            "ops": [
                {
                    "op": op.op_name,
                    "in": list(op.in_arrs),
                    "out": list(op.out_arrs),
                    "args": _json_safe(op.op_args),
                    "lineage_ids": list(op.lineage_ids),
                    "reused": op.reused,
                }
                for op in self.ops
            ],
            "versions": dict(self._versions),
            "hops": {k: list(v) for k, v in self.hop_stats.items()},
            "hop_decay": self.hop_decay,
        }
        if self._wal is not None:
            # checkpoint: make every logged record durable, stamp the end
            # LSN into the manifest, and truncate the log afterwards —
            # a crash between the two replays nothing twice (LSN skip).
            self.commit()
            meta["wal_lsn"] = self._wal.end_lsn
        for e in self.lineage.values():
            rec = self._persisted.get(e.lineage_id)
            if rec is None or e.lineage_id in self._dirty:
                rec = self._write_entry(e)
                self._persisted[e.lineage_id] = rec
            meta["lineage"].append(rec)
        self._dirty.clear()

        if self._predictor_chunk is None or self.predictor.dirty:
            self._predictor_chunk = self._write_predictor()
        meta["predictor"] = self._predictor_chunk
        meta["views"] = self.views.manifest_chunk(self._write_view_blob)
        _atomic_write(
            os.path.join(self.root, "answers.json"),
            json.dumps(self.views.cache_chunk()),
        )
        _atomic_write(
            os.path.join(self.root, "autotune.json"),
            json.dumps(self.autotune.to_manifest()),
        )
        self.autotune.dirty = False
        # telemetry snapshot rides every checkpoint (write-only sidecar:
        # load() never restores it, counters restart from zero)
        _atomic_write(
            os.path.join(self.root, "telemetry.json"),
            json.dumps(telemetry_snapshot(self)),
        )

        payload = json.dumps(meta)
        _atomic_write(os.path.join(self.root, "catalog.json"), payload)
        self._bump("manifests_written")
        self._bump("bytes_written", len(payload))
        self._meta_dirty = False
        # Truncate only as the leased owner: a save() on a merely
        # load()-ed store (pre-WAL workflow) must not cut a log a live
        # writer may be appending to — its records stay, and replay skips
        # them via the wal_lsn just recorded.  (Facade shard saves defer
        # truncation to the root, which holds the root lock.)
        if self._wal is not None and checkpoint_wal and self._lease is not None:
            self._wal_lsn = self._wal.checkpoint()

    def _write_entry(self, e: LineageEntry) -> dict:
        fn = f"lineage_{e.lineage_id}.prvc"
        blob = e.backward.serialize(compress=self.gzip)
        _write_blob(os.path.join(self.root, fn), blob)
        self._bump("tables_written")
        self._bump("bytes_written", len(blob))
        rec = {
            "id": e.lineage_id,
            "src": e.src,
            "dst": e.dst,
            "file": fn,
            "op": e.op_name,
            "reused": e.reused_from,
            "rows": e.backward.n_rows,
            "fwd": None,
            "fwd_rows": None,
            "idx": self._save_index(e.backward, f"lineage_{e.lineage_id}.idx"),
            "fwd_idx": None,
        }
        if e.forward is not None:
            fwd_fn = f"lineage_{e.lineage_id}_fwd.prvc"
            blob = e.forward.serialize(compress=self.gzip)
            _write_blob(os.path.join(self.root, fwd_fn), blob)
            self._bump("tables_written")
            self._bump("bytes_written", len(blob))
            rec["fwd"] = fwd_fn
            rec["fwd_rows"] = e.forward.n_rows
            rec["fwd_idx"] = self._save_index(
                e.forward, f"lineage_{e.lineage_id}_fwd.idx"
            )
        return rec

    def _write_view_blob(self, fn: str, table: CompressedTable) -> None:
        blob = table.serialize(compress=self.gzip)
        _write_blob(os.path.join(self.root, fn), blob)
        self._bump("tables_written")
        self._bump("bytes_written", len(blob))

    def _view_lsns(self) -> dict[str, int]:
        """End LSN of every WAL a view's route could be invalidated
        through — for a single store, just its own log."""
        return {"": self._wal.end_lsn if self._wal is not None else 0}

    def _write_predictor(self) -> dict:
        assert self.root is not None
        root = self.root

        def save_table(key: str, label: str, tbl: CompressedTable) -> str:
            fn = _sig_blob_name(key, label)
            blob = tbl.serialize(compress=self.gzip)
            _write_blob(os.path.join(root, fn), blob)
            self._bump("sig_tables_written")
            self._bump("bytes_written", len(blob))
            return fn

        return self.predictor.state_manifest(save_table)

    def _save_index(self, table: CompressedTable, fn: str) -> str | None:
        """Persist the key index next to its table: already-built indexes are
        always written; large tables get one built eagerly so reloads start
        warm.  Small, index-less tables write nothing (dense is fine)."""
        assert self.root is not None
        cached = table.cached_key_index()
        if cached is None and table.n_rows < _INDEX_PERSIST_MIN_ROWS:
            return None
        idx = cached if cached is not None else table.key_index()
        blob = idx.to_bytes()
        _write_blob(os.path.join(self.root, fn), blob)
        self._bump("bytes_written", len(blob))
        return fn

    @staticmethod
    def _load_index(root: str, fn: str | None, table: CompressedTable) -> None:
        if not fn:
            return
        path = os.path.join(root, fn)
        if not os.path.exists(path):
            return
        try:
            with open(path, "rb") as f:
                table.attach_key_index(
                    IntervalIndex.from_bytes(f.read(), table.key_lo, table.key_hi)
                )
        except ValueError:
            pass  # stale sidecar: fall back to lazy rebuild

    def _make_handle(self, fn: str, idx_fn: str | None, rows) -> TableHandle:
        assert self.root is not None
        root = self.root

        def load() -> CompressedTable:
            with open(os.path.join(root, fn), "rb") as f:
                t = CompressedTable.deserialize(f.read())
            DSLog._load_index(root, idx_fn, t)
            return t

        def on_load() -> None:
            # fired from TableHandle.get under arbitrary threads (parallel
            # plan execution) — must take the stats lock like every meter
            self._bump("tables_loaded")

        return TableHandle(load, None if rows is None else int(rows), on_load)

    @staticmethod
    def load(root: str) -> "DSLog":
        """Reopen a catalog without deserializing any table blob.

        Arrays, the lineage DAG, op records, and the reuse-predictor state
        load eagerly (they are small JSON plus the few signature tables);
        every lineage table becomes a lazy handle that resolves on first
        touch — ``io_stats["tables_loaded"]`` counts those resolutions.
        Manifests from v1 (pre-graph) load too; they simply have no ops or
        predictor state to restore.

        **Crash recovery** happens here: when a write-ahead log is present
        (the store was opened with :meth:`open`), its tail past the
        manifest's checkpoint LSN is replayed — torn trailing records
        truncated — so a store whose writer died mid-ingest reopens equal
        to a synchronous-save oracle of every durably logged mutation.  A
        crash *before the first checkpoint* leaves a WAL with no manifest
        at all; that loads too, from an empty catalog plus replay.
        """
        log = DSLog(root=root)
        manifest = os.path.join(root, "catalog.json")
        if not os.path.exists(manifest) and os.path.exists(
            os.path.join(root, WAL_FILENAME)
        ):
            log._attach_wal()
            return log
        with open(manifest) as f:
            meta = json.load(f)
        if meta.get("sharded"):
            raise ValueError(
                f"{root!r} holds a sharded catalog root; open it with "
                "repro.core.shard.ShardedDSLog.load"
            )
        version = int(meta.get("version", 1))
        for n, shp in meta["arrays"].items():
            log.define_array(n, tuple(shp))
        for rec in meta["lineage"]:
            bwd = log._make_handle(rec["file"], rec.get("idx"), rec.get("rows"))
            fwd = None
            if rec["fwd"]:
                fwd = log._make_handle(
                    rec["fwd"], rec.get("fwd_idx"), rec.get("fwd_rows")
                )
            e = LineageEntry(
                rec["id"], rec["src"], rec["dst"], bwd, fwd, rec["op"], rec["reused"]
            )
            log.lineage[e.lineage_id] = e
            log.by_pair.setdefault((e.src, e.dst), []).append(e.lineage_id)
            log._persisted[e.lineage_id] = rec
        log.graph = LineageGraph.from_pairs(log.by_pair)
        log._next_id = meta["next_id"]
        if version >= 2:
            for op in meta.get("ops", []):
                log.ops.append(
                    _OpRecord(
                        op["op"],
                        tuple(op["in"]),
                        tuple(op["out"]),
                        op["args"],
                        list(op["lineage_ids"]),
                        op["reused"],
                    )
                )
            chunk = meta.get("predictor")
            if chunk is not None:

                def load_table(fn: str) -> CompressedTable:
                    with open(os.path.join(root, fn), "rb") as f:
                        return CompressedTable.deserialize(f.read())

                log.predictor = ReusePredictor.from_manifest(chunk, load_table)
                log._predictor_chunk = chunk
        log._versions = {
            k: int(v) for k, v in meta.get("versions", {}).items()
        }
        with log._stats_lock:
            log.hop_stats.update(
                {k: [float(x) for x in v] for k, v in meta.get("hops", {}).items()}
            )
        log.hop_decay = float(meta.get("hop_decay", log.hop_decay))
        log._meta_dirty = False
        log._wal_lsn = int(meta.get("wal_lsn", 0))
        # views + cached answers restore BEFORE WAL replay: replayed
        # entry/drop/dirty records then fire the same precise invalidation
        # they did live, so nothing stale survives recovery
        log.views.load_chunk(
            meta.get("views"),
            lambda fn, rows: log._make_handle(fn, None, rows),
        )
        answers = os.path.join(root, "answers.json")
        if os.path.exists(answers):
            try:
                with open(answers) as f:
                    log.views.load_cache_chunk(json.load(f))
            except (ValueError, KeyError):
                pass  # torn/stale sidecar: start with a cold cache
        autotune = os.path.join(root, "autotune.json")
        if os.path.exists(autotune):
            try:
                with open(autotune) as f:
                    log.autotune.load_manifest(json.load(f))
            except ValueError:
                pass  # torn sidecar: start with a cold geometry table
        if os.path.exists(os.path.join(root, WAL_FILENAME)):
            log._attach_wal()
        return log

    # ------------------------------------------------------------------ #
    # Garbage collection (persistence v2 vacuum)
    # ------------------------------------------------------------------ #
    def compact(self, save: bool = True) -> dict[str, int]:
        """Vacuum blobs no longer referenced by the catalog.

        Dirty-tracked saves never delete files, so dropped entries
        (:meth:`drop_lineage`) and re-saved/rejected predictor signatures
        leave stale ``lineage_*.prvc``/``.idx`` and ``sig_*.prvc`` blobs
        behind.  ``compact()`` saves first (unless ``save=False``, for
        callers that just synced), then deletes every catalog-owned file the
        current manifest does not reference.  Returns
        ``{"files_removed": n, "bytes_reclaimed": b}``.
        """
        if not self.root:
            raise ValueError("DSLog opened without a root directory")
        if save:
            self.save()
        for lid in list(self._persisted):
            if lid not in self.lineage:
                del self._persisted[lid]
        referenced = manifest_referenced_files(
            self._persisted.values(), self._predictor_chunk
        )
        referenced |= self.views.blob_files()
        return _vacuum_dir(self.root, referenced)

    # ------------------------------------------------------------------ #
    def storage_bytes(self) -> int:
        """Packed size of every stored table (forces lazy blobs to load)."""
        total = 0
        for e in self.lineage.values():
            total += e.backward.nbytes()
            if e.forward is not None:
                total += e.forward.nbytes()
        return total
