"""DSLog — the lineage storage manager (paper §III, §VI).

The catalog owns:

* named, shape-declared **Arrays** (§III.A ``Array``),
* **lineage entries** — ProvRC-compressed backward (+ optionally forward)
  tables between array pairs (§III.A ``Lineage``),
* **operation registrations** that bundle multiple lineage entries under an
  operation signature and drive automatic reuse prediction (§VI),
* **persistence** — each table is a packed binary blob (optionally
  zlib-compressed, i.e. ProvRC-GZip) under a root directory, with a JSON
  catalog index.

Multi-hop ``prov_query`` (§V) walks a path of array names, picking for each
hop the best stored materialization (forward table, backward table with
inverse join, or vice versa for backward queries).
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from .index import IntervalIndex
from .provrc import compress
from .query import (
    QueryBox,
    merge_boxes,
    theta_join_batch,
    theta_join_inverse,
)
from .relation import LineageRelation
from .reuse import (
    ReusePredictor,
    sig_key_base,
    sig_key_dim,
    sig_key_gen,
)
from .table import CompressedTable

__all__ = ["DSLog", "ArrayDef", "LineageEntry"]

# Tables at or above this row count get their key index built and persisted
# at save time, so a reloaded catalog serves its first selective query
# without paying the O(n log n) sort.
_INDEX_PERSIST_MIN_ROWS = 4096


@dataclass
class ArrayDef:
    name: str
    shape: tuple[int, ...]


@dataclass
class LineageEntry:
    """Compressed lineage between an op input (src) and op output (dst)."""

    lineage_id: int
    src: str  # input array name
    dst: str  # output array name
    backward: CompressedTable  # keys = dst axes
    forward: CompressedTable | None = None  # keys = src axes
    op_name: str | None = None
    reused_from: str | None = None


@dataclass
class _OpRecord:
    op_name: str
    in_arrs: tuple[str, ...]
    out_arrs: tuple[str, ...]
    op_args: Any
    lineage_ids: list[int] = field(default_factory=list)
    reused: str | None = None


class DSLog:
    """The lineage index service."""

    def __init__(
        self,
        root: str | None = None,
        store_forward: bool = True,
        compress_method: str = "auto",
        reuse_m: int = 1,
        gzip: bool = True,
    ):
        self.root = root
        self.store_forward = store_forward
        self.compress_method = compress_method
        self.gzip = gzip
        self.arrays: dict[str, ArrayDef] = {}
        self.lineage: dict[int, LineageEntry] = {}
        self.by_pair: dict[tuple[str, str], list[int]] = {}
        self.ops: list[_OpRecord] = []
        self.predictor = ReusePredictor(m=reuse_m)
        self._next_id = 0
        if root:
            os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------ #
    # Array / lineage definition (paper §III.A)
    # ------------------------------------------------------------------ #
    def define_array(self, name: str, shape: tuple[int, ...]) -> ArrayDef:
        arr = ArrayDef(name, tuple(int(d) for d in shape))
        self.arrays[name] = arr
        return arr

    def add_lineage(
        self,
        src: str,
        dst: str,
        relation: LineageRelation,
        op_name: str | None = None,
        tables: tuple[CompressedTable, CompressedTable | None] | None = None,
        reused_from: str | None = None,
    ) -> LineageEntry:
        """Ingest one captured relation (src = op input, dst = op output)."""
        self._check_shapes(src, dst, relation)
        if tables is not None:
            bwd, fwd = tables
        else:
            bwd = compress(relation, "backward", self.compress_method)
            fwd = (
                compress(relation, "forward", self.compress_method)
                if self.store_forward
                else None
            )
        entry = LineageEntry(
            self._next_id, src, dst, bwd, fwd, op_name, reused_from
        )
        self._next_id += 1
        self.lineage[entry.lineage_id] = entry
        self.by_pair.setdefault((src, dst), []).append(entry.lineage_id)
        return entry

    def _check_shapes(self, src: str, dst: str, rel: LineageRelation) -> None:
        if src in self.arrays and self.arrays[src].shape != rel.in_shape:
            raise ValueError(
                f"array {src} declared {self.arrays[src].shape}, lineage says {rel.in_shape}"
            )
        if dst in self.arrays and self.arrays[dst].shape != rel.out_shape:
            raise ValueError(
                f"array {dst} declared {self.arrays[dst].shape}, lineage says {rel.out_shape}"
            )
        self.arrays.setdefault(src, ArrayDef(src, rel.in_shape))
        self.arrays.setdefault(dst, ArrayDef(dst, rel.out_shape))

    # ------------------------------------------------------------------ #
    # Operation registration with automatic reuse (§III.A, §VI)
    # ------------------------------------------------------------------ #
    def register_operation(
        self,
        op_name: str,
        in_arrs: list[str],
        out_arrs: list[str],
        capture: Callable[[], dict[tuple[int, int], LineageRelation]] | None = None,
        op_args: Any = None,
        reuse: bool | None = None,
    ) -> _OpRecord:
        """Register one executed operation and its lineage.

        ``capture()`` returns ``{(out_pos, in_pos): relation}``.  When reuse
        is enabled (default) and a confirmed signature mapping exists, the
        capture callable is *not* invoked — the stored tables are linked
        instead (this is the paper's capture-bypass).
        """
        in_arrs, out_arrs = tuple(in_arrs), tuple(out_arrs)
        in_shapes = tuple(self.arrays[a].shape for a in in_arrs)
        out_shapes = tuple(self.arrays[a].shape for a in out_arrs)
        dim_key = sig_key_dim(op_name, in_shapes + out_shapes, op_args)
        gen_key = sig_key_gen(op_name, op_args)
        shapes_token = in_shapes + out_shapes
        rec = _OpRecord(op_name, in_arrs, out_arrs, op_args)
        use_reuse = reuse if reuse is not None else True

        pair_shapes = {}
        for oi, oname in enumerate(out_arrs):
            for ii, iname in enumerate(in_arrs):
                pair_shapes[f"{oi}:{ii}"] = (
                    self.arrays[oname].shape,
                    self.arrays[iname].shape,
                )

        if use_reuse:
            decision = self.predictor.lookup(
                dim_key, gen_key, shapes_token, pair_shapes
            )
            if decision.reused:
                assert decision.tables is not None
                for label, bwd in decision.tables.items():
                    oi, ii = (int(x) for x in label.split(":"))
                    entry = LineageEntry(
                        self._next_id,
                        in_arrs[ii],
                        out_arrs[oi],
                        bwd,
                        self._derive_forward(bwd) if self.store_forward else None,
                        op_name,
                        reused_from=decision.source,
                    )
                    self._next_id += 1
                    self.lineage[entry.lineage_id] = entry
                    self.by_pair.setdefault(
                        (entry.src, entry.dst), []
                    ).append(entry.lineage_id)
                    rec.lineage_ids.append(entry.lineage_id)
                rec.reused = decision.source
                self.ops.append(rec)
                return rec

        if capture is None:
            raise ValueError(
                f"no confirmed reuse mapping for {op_name} and no capture given"
            )
        rels = capture()
        captured_tables: dict[str, CompressedTable] = {}
        for (oi, ii), rel in rels.items():
            entry = self.add_lineage(
                in_arrs[ii], out_arrs[oi], rel, op_name=op_name
            )
            rec.lineage_ids.append(entry.lineage_id)
            captured_tables[f"{oi}:{ii}"] = entry.backward
        if use_reuse:
            self.predictor.observe(dim_key, gen_key, shapes_token, captured_tables)
        self.ops.append(rec)
        return rec

    def _derive_forward(self, bwd: CompressedTable) -> CompressedTable | None:
        """Forward table from a reused backward table (via decompress only
        when small; otherwise serve forward queries with the inverse join)."""
        if bwd.n_rows <= 4096:
            rel = bwd.decompress()
            return compress(rel, "forward", self.compress_method)
        return None

    # ------------------------------------------------------------------ #
    # Multi-hop queries (§V)
    # ------------------------------------------------------------------ #
    def prov_query(
        self,
        path: list[str],
        query_cells: "np.ndarray | QueryBox",
        merge: bool = True,
    ) -> QueryBox:
        """Lineage between cells of ``path[0]`` and cells of ``path[-1]``.

        Single-query form of :meth:`prov_query_batch` (one hop-dispatch
        implementation serves both).
        """
        return self.prov_query_batch(path, [query_cells], merge)[0]

    def prov_query_batch(
        self,
        path: list[str],
        queries: "list[np.ndarray | QueryBox]",
        merge: bool = True,
    ) -> list[QueryBox]:
        """Answer many independent queries over the same array path.

        Hops whose stored materialization matches the query direction are
        executed with :func:`theta_join_batch`, so identical boxes across the
        in-flight queries share one index probe and every hop's interval
        index is built (and cached on the table) at most once for the whole
        batch.  Hops that must run through the inverse join fall back to a
        per-query loop — still index-pruned, still cache-warm.
        """
        if len(path) < 2:
            raise ValueError("path needs at least two arrays")
        if not queries:
            return []
        first = self.arrays[path[0]]
        cur: list[QueryBox] = [
            q if isinstance(q, QueryBox) else QueryBox.from_cells(first.shape, q)
            for q in queries
        ]
        if merge:
            cur = [merge_boxes(q) for q in cur]
        for a, b in zip(path[:-1], path[1:]):
            cur = self._query_hop_batch(cur, a, b, merge)
        return cur

    def _query_hop_batch(
        self, qs: list[QueryBox], a: str, b: str, merge: bool
    ) -> list[QueryBox]:
        acc_lo: list[list[np.ndarray]] = [[] for _ in qs]
        acc_hi: list[list[np.ndarray]] = [[] for _ in qs]
        shape_out: tuple[int, ...] | None = None

        def fold(results: list[QueryBox]) -> None:
            nonlocal shape_out
            for k, r in enumerate(results):
                acc_lo[k].append(r.lo)
                acc_hi[k].append(r.hi)
                shape_out = r.shape

        # backward direction: a is an op OUTPUT, b the op input
        for lid in self.by_pair.get((b, a), []):
            fold(theta_join_batch(qs, self.lineage[lid].backward, merge=False))
        # forward direction: a is an op INPUT, b the op output
        for lid in self.by_pair.get((a, b), []):
            e = self.lineage[lid]
            if e.forward is not None:
                fold(theta_join_batch(qs, e.forward, merge=False))
            else:
                fold([theta_join_inverse(q, e.backward, merge=False) for q in qs])
        if shape_out is None:
            raise KeyError(f"no lineage stored between {a!r} and {b!r}")
        out = []
        for k in range(len(qs)):
            res = QueryBox(
                shape_out, np.concatenate(acc_lo[k]), np.concatenate(acc_hi[k])
            )
            out.append(merge_boxes(res) if merge else res)
        return out

    # ------------------------------------------------------------------ #
    # Persistence
    # ------------------------------------------------------------------ #
    def save(self) -> None:
        if not self.root:
            raise ValueError("DSLog opened without a root directory")
        meta = {
            "arrays": {n: list(a.shape) for n, a in self.arrays.items()},
            "lineage": [],
            "next_id": self._next_id,
        }
        for e in self.lineage.values():
            fn = f"lineage_{e.lineage_id}.prvc"
            with open(os.path.join(self.root, fn), "wb") as f:
                f.write(e.backward.serialize(compress=self.gzip))
            rec = {
                "id": e.lineage_id,
                "src": e.src,
                "dst": e.dst,
                "file": fn,
                "op": e.op_name,
                "reused": e.reused_from,
                "fwd": None,
                "idx": None,
                "fwd_idx": None,
            }
            rec["idx"] = self._save_index(e.backward, f"lineage_{e.lineage_id}.idx")
            if e.forward is not None:
                fwd_fn = f"lineage_{e.lineage_id}_fwd.prvc"
                with open(os.path.join(self.root, fwd_fn), "wb") as f:
                    f.write(e.forward.serialize(compress=self.gzip))
                rec["fwd"] = fwd_fn
                rec["fwd_idx"] = self._save_index(
                    e.forward, f"lineage_{e.lineage_id}_fwd.idx"
                )
            meta["lineage"].append(rec)
        with open(os.path.join(self.root, "catalog.json"), "w") as f:
            json.dump(meta, f)

    def _save_index(self, table: CompressedTable, fn: str) -> str | None:
        """Persist the key index next to its table: already-built indexes are
        always written; large tables get one built eagerly so reloads start
        warm.  Small, index-less tables write nothing (dense is fine)."""
        assert self.root is not None
        cached = table.cached_key_index()
        if cached is None and table.n_rows < _INDEX_PERSIST_MIN_ROWS:
            return None
        idx = cached if cached is not None else table.key_index()
        with open(os.path.join(self.root, fn), "wb") as f:
            f.write(idx.to_bytes())
        return fn

    @staticmethod
    def _load_index(root: str, fn: str | None, table: CompressedTable) -> None:
        if not fn:
            return
        path = os.path.join(root, fn)
        if not os.path.exists(path):
            return
        try:
            with open(path, "rb") as f:
                table.attach_key_index(
                    IntervalIndex.from_bytes(f.read(), table.key_lo, table.key_hi)
                )
        except ValueError:
            pass  # stale sidecar: fall back to lazy rebuild

    @staticmethod
    def load(root: str) -> "DSLog":
        log = DSLog(root=root)
        with open(os.path.join(root, "catalog.json")) as f:
            meta = json.load(f)
        for n, shp in meta["arrays"].items():
            log.define_array(n, tuple(shp))
        for rec in meta["lineage"]:
            with open(os.path.join(root, rec["file"]), "rb") as f:
                bwd = CompressedTable.deserialize(f.read())
            DSLog._load_index(root, rec.get("idx"), bwd)
            fwd = None
            if rec["fwd"]:
                with open(os.path.join(root, rec["fwd"]), "rb") as f:
                    fwd = CompressedTable.deserialize(f.read())
                DSLog._load_index(root, rec.get("fwd_idx"), fwd)
            e = LineageEntry(
                rec["id"], rec["src"], rec["dst"], bwd, fwd, rec["op"], rec["reused"]
            )
            log.lineage[e.lineage_id] = e
            log.by_pair.setdefault((e.src, e.dst), []).append(e.lineage_id)
        log._next_id = meta["next_id"]
        return log

    # ------------------------------------------------------------------ #
    def storage_bytes(self) -> int:
        total = 0
        for e in self.lineage.values():
            total += e.backward.nbytes()
            if e.forward is not None:
                total += e.forward.nbytes()
        return total
