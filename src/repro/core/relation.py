"""Relational data model for fine-grained array lineage (paper §III.B).

A :class:`LineageRelation` is the uncompressed relation
``R(b_1..b_l, a_1..a_m)`` between an *output* array ``B`` and an *input*
array ``A``: one row per contribution ``B[b...] <- A[a...]``.  Rows are
unique (set semantics), which is what makes the UCP argument of the paper's
correctness proof go through.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["LineageRelation", "axis_names"]


def axis_names(prefix: str, ndim: int) -> tuple[str, ...]:
    return tuple(f"{prefix}{i}" for i in range(ndim))


@dataclass
class LineageRelation:
    """Uncompressed lineage rows between one output and one input array."""

    out_shape: tuple[int, ...]
    in_shape: tuple[int, ...]
    # int64 [N, l] and [N, m]; row i means out_idx[i] <- in_idx[i].
    out_idx: np.ndarray = field(repr=False)
    in_idx: np.ndarray = field(repr=False)
    out_attrs: tuple[str, ...] = ()
    in_attrs: tuple[str, ...] = ()

    def __post_init__(self) -> None:
        self.out_idx = np.asarray(self.out_idx, dtype=np.int64).reshape(
            -1, len(self.out_shape)
        )
        self.in_idx = np.asarray(self.in_idx, dtype=np.int64).reshape(
            -1, len(self.in_shape)
        )
        if self.out_idx.shape[0] != self.in_idx.shape[0]:
            raise ValueError("out_idx and in_idx row counts differ")
        if not self.out_attrs:
            self.out_attrs = axis_names("b", len(self.out_shape))
        if not self.in_attrs:
            self.in_attrs = axis_names("a", len(self.in_shape))

    # ------------------------------------------------------------------ #
    @property
    def n_rows(self) -> int:
        return int(self.out_idx.shape[0])

    @property
    def ndim_out(self) -> int:
        return len(self.out_shape)

    @property
    def ndim_in(self) -> int:
        return len(self.in_shape)

    def rows(self) -> np.ndarray:
        """All columns side by side: ``[b_1..b_l, a_1..a_m]``."""
        return np.concatenate([self.out_idx, self.in_idx], axis=1)

    def nbytes_raw(self) -> int:
        """Size of the row-oriented int64 materialization (the Raw baseline)."""
        return self.rows().nbytes

    # ------------------------------------------------------------------ #
    def canonical(self) -> "LineageRelation":
        """Sorted + deduplicated copy (set semantics)."""
        rows = self.rows()
        rows = np.unique(rows, axis=0)
        l = self.ndim_out
        return LineageRelation(
            self.out_shape,
            self.in_shape,
            rows[:, :l],
            rows[:, l:],
            self.out_attrs,
            self.in_attrs,
        )

    def as_set(self) -> set[tuple[int, ...]]:
        return {tuple(int(v) for v in row) for row in self.rows()}

    def __eq__(self, other: object) -> bool:  # type: ignore[override]
        if not isinstance(other, LineageRelation):
            return NotImplemented
        if self.out_shape != other.out_shape or self.in_shape != other.in_shape:
            return False
        a = np.unique(self.rows(), axis=0)
        b = np.unique(other.rows(), axis=0)
        return a.shape == b.shape and bool(np.array_equal(a, b))

    # ------------------------------------------------------------------ #
    @staticmethod
    def from_pairs(
        out_shape: tuple[int, ...],
        in_shape: tuple[int, ...],
        pairs: "np.ndarray | list[tuple[tuple[int, ...], tuple[int, ...]]]",
    ) -> "LineageRelation":
        """Build from explicit ``(out_idx_tuple, in_idx_tuple)`` pairs."""
        if isinstance(pairs, np.ndarray):
            l = len(out_shape)
            return LineageRelation(out_shape, in_shape, pairs[:, :l], pairs[:, l:])
        out_rows = np.array([p[0] for p in pairs], dtype=np.int64).reshape(
            len(pairs), len(out_shape)
        )
        in_rows = np.array([p[1] for p in pairs], dtype=np.int64).reshape(
            len(pairs), len(in_shape)
        )
        return LineageRelation(out_shape, in_shape, out_rows, in_rows)

    @staticmethod
    def from_flat(
        out_shape: tuple[int, ...],
        in_shape: tuple[int, ...],
        out_flat: np.ndarray,
        in_flat: np.ndarray,
    ) -> "LineageRelation":
        """Build from flat (raveled) cell ids on each side."""
        out_idx = np.stack(
            np.unravel_index(np.asarray(out_flat, dtype=np.int64), out_shape), axis=1
        )
        in_idx = np.stack(
            np.unravel_index(np.asarray(in_flat, dtype=np.int64), in_shape), axis=1
        )
        return LineageRelation(out_shape, in_shape, out_idx, in_idx)
