"""Lineage DAG over arrays (paper §III at pipeline scale).

Real pipelines are DAGs with fan-out and fan-in, not the hand-spelled array
*paths* of the paper's multi-hop ``prov_query`` (§V).  :class:`LineageGraph`
is the structural layer under the catalog: nodes are array names, and every
:class:`~repro.core.catalog.LineageEntry` contributes a directed edge from
its op input (``src``) to its op output (``dst``) labelled by its lineage
id.  Multiple entries between the same pair (repeated ops, reuse links)
share one edge slot and keep registration order.

The graph is built incrementally by ``DSLog.add_lineage`` /
``register_operation`` and rebuilt from the manifest on ``DSLog.load``.  It
answers the questions the planner needs:

* forward/backward adjacency and reachability,
* enumeration of all simple dataflow paths between two endpoint *sets*,
* the sub-DAG induced by those paths plus a topological order over it,
* cycle rejection at insertion time — dataflow over arrays must stay
  acyclic, and catching the violation at ``add_edge`` time (rather than at
  query time, deep inside a non-terminating traversal) keeps the invariant
  local to the write path.
"""

from __future__ import annotations

from collections import deque
from typing import Iterable, Iterator

__all__ = ["CycleError", "LineageGraph"]


class CycleError(ValueError):
    """Adding this edge would create a dataflow cycle."""


class LineageGraph:
    """Directed multigraph of arrays; edges labelled with lineage ids."""

    def __init__(self) -> None:
        # src -> dst -> [lineage ids in registration order]
        self.fwd: dict[str, dict[str, list[int]]] = {}
        # dst -> src -> [lineage ids]
        self.bwd: dict[str, dict[str, list[int]]] = {}
        self._nodes: set[str] = set()
        # (src, dst) -> pseudo lineage id of a materialized view covering
        # the whole route.  An overlay, not part of the dataflow DAG: it
        # never participates in reachability, path enumeration, or cycle
        # checks — the planner consults it separately when costing a
        # single-source/single-target query.
        self.shortcuts: dict[tuple[str, str], int] = {}

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #
    def add_node(self, name: str) -> None:
        self._nodes.add(name)

    def add_edge(self, src: str, dst: str, lineage_id: int) -> None:
        """Record one lineage entry as a ``src → dst`` dataflow edge.

        Raises :class:`CycleError` (and leaves the graph untouched) when the
        edge would close a cycle, including the ``src == dst`` self-loop.
        Parallel entries between an existing pair are always safe.
        """
        if src == dst:
            raise CycleError(
                f"self-lineage {src!r} → {dst!r} is not a DAG edge "
                "(log in-place updates under versioned array names instead)"
            )
        if dst not in self.fwd.get(src, ()) and self.has_path(dst, src):
            raise CycleError(
                f"lineage {src!r} → {dst!r} would close a cycle "
                f"({dst!r} already flows into {src!r})"
            )
        self._nodes.update((src, dst))
        self.fwd.setdefault(src, {}).setdefault(dst, []).append(lineage_id)
        self.bwd.setdefault(dst, {}).setdefault(src, []).append(lineage_id)

    def remove_edge(self, src: str, dst: str, lineage_id: int) -> None:
        """Remove one entry from an edge (multi-entry rollback support).

        Nodes are kept even when their last edge goes — they still name
        declared arrays.
        """
        for adj, a, b in ((self.fwd, src, dst), (self.bwd, dst, src)):
            ids = adj.get(a, {}).get(b)
            if ids is None or lineage_id not in ids:
                return
            ids.remove(lineage_id)
            if not ids:
                del adj[a][b]
                if not adj[a]:
                    del adj[a]

    def add_shortcut(self, src: str, dst: str, pseudo_id: int) -> None:
        """Overlay a materialized-view shortcut on the ``src → dst`` route.

        ``pseudo_id`` is the view's negative pseudo lineage id (see
        ``repro.core.views``).  At most one shortcut per route.
        """
        self.shortcuts[(src, dst)] = pseudo_id

    def remove_shortcut(self, src: str, dst: str) -> None:
        self.shortcuts.pop((src, dst), None)

    def shortcut_id(self, src: str, dst: str) -> int | None:
        return self.shortcuts.get((src, dst))

    @staticmethod
    def from_pairs(by_pair: dict[tuple[str, str], list[int]]) -> "LineageGraph":
        """Rebuild from a catalog's ``(src, dst) -> [lineage ids]`` map."""
        g = LineageGraph()
        for (src, dst), ids in by_pair.items():
            for lid in ids:
                g.add_edge(src, dst, lid)
        return g

    # ------------------------------------------------------------------ #
    # adjacency
    # ------------------------------------------------------------------ #
    @property
    def nodes(self) -> set[str]:
        return set(self._nodes)

    def successors(self, name: str) -> list[str]:
        return list(self.fwd.get(name, ()))

    def predecessors(self, name: str) -> list[str]:
        return list(self.bwd.get(name, ()))

    def edge_ids(self, src: str, dst: str) -> list[int]:
        """Lineage ids of all entries on the ``src → dst`` edge."""
        return list(self.fwd.get(src, {}).get(dst, ()))

    def n_edges(self) -> int:
        return sum(len(ids) for dsts in self.fwd.values() for ids in dsts.values())

    # ------------------------------------------------------------------ #
    # reachability
    # ------------------------------------------------------------------ #
    def reachable(
        self, starts: Iterable[str] | str, direction: str = "forward"
    ) -> set[str]:
        """Every node reachable from ``starts`` (the starts themselves
        included) walking dataflow edges ``forward`` or ``backward``."""
        if direction not in ("forward", "backward"):
            raise ValueError(f"bad direction {direction!r}")
        adj = self.fwd if direction == "forward" else self.bwd
        frontier = deque([starts] if isinstance(starts, str) else starts)
        seen = set(frontier)
        while frontier:
            for nxt in adj.get(frontier.popleft(), ()):
                if nxt not in seen:
                    seen.add(nxt)
                    frontier.append(nxt)
        return seen

    def has_path(self, src: str, dst: str) -> bool:
        return dst in self.reachable(src, "forward")

    # ------------------------------------------------------------------ #
    # path / sub-DAG enumeration
    # ------------------------------------------------------------------ #
    def simple_paths(
        self,
        sources: Iterable[str] | str,
        targets: Iterable[str] | str,
        max_paths: int | None = None,
    ) -> list[list[str]]:
        """All simple dataflow paths from any source to any target.

        Endpoints are *sets*: a path starts at one source and ends at the
        first-class target it reaches (it may pass through another target on
        the way — those longer paths are enumerated too).  Since edges are
        acyclic every dataflow path is simple; the explicit visited set only
        guards against source/target overlap.  ``max_paths`` caps the
        enumeration (diamond stacks grow exponentially many paths — the
        planner never needs the explicit list, see :meth:`induced_subdag`).
        """
        src_set = {sources} if isinstance(sources, str) else set(sources)
        dst_set = {targets} if isinstance(targets, str) else set(targets)
        # prune to nodes that can reach a target at all
        alive = self.reachable(dst_set, "backward")
        out: list[list[str]] = []

        def dfs(node: str, path: list[str]) -> bool:
            if node in dst_set:
                out.append(list(path))
                if max_paths is not None and len(out) >= max_paths:
                    return False
            for nxt in self.fwd.get(node, ()):
                if nxt in alive and nxt not in path:
                    path.append(nxt)
                    if not dfs(nxt, path):
                        return False
                    path.pop()
            return True

        for s in sorted(src_set):
            if s in alive and not dfs(s, [s]):
                break
        return out

    def induced_subdag(
        self,
        sources: Iterable[str] | str,
        targets: Iterable[str] | str,
    ) -> tuple[set[str], list[tuple[str, str]]]:
        """Nodes and edges lying on at least one source→target path.

        In a DAG a node is on such a path iff it is reachable from a source
        *and* a target is reachable from it, so this is two BFS passes — no
        exponential path enumeration.
        """
        down = self.reachable(sources, "forward")
        up = self.reachable(targets, "backward")
        nodes = down & up
        edges = [
            (u, v)
            for u in nodes
            for v in self.fwd.get(u, ())
            if v in nodes
        ]
        return nodes, edges

    def topo_order(self, nodes: Iterable[str] | None = None) -> list[str]:
        """Kahn topological order over ``nodes`` (default: whole graph).

        Ties broken by name so plans are deterministic across runs.
        """
        pool = self._nodes if nodes is None else set(nodes)
        indeg = {
            n: sum(1 for p in self.bwd.get(n, ()) if p in pool) for n in pool
        }
        ready = sorted(n for n, d in indeg.items() if d == 0)
        order: list[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            inserted = False
            for s in self.fwd.get(n, ()):
                if s in pool:
                    indeg[s] -= 1
                    if indeg[s] == 0:
                        ready.append(s)
                        inserted = True
            if inserted:
                ready.sort()
        if len(order) != len(pool):
            # unreachable by construction (add_edge rejects cycles); kept as
            # a hard failure rather than a silent truncated order
            raise CycleError("graph contains a cycle")
        return order

    # ------------------------------------------------------------------ #
    def __contains__(self, name: str) -> bool:
        return name in self._nodes

    def __iter__(self) -> Iterator[str]:
        return iter(self._nodes)

    def __len__(self) -> int:
        return len(self._nodes)
