"""In-situ query processing over compressed lineage tables (paper §V).

Queries never decompress.  A query is a :class:`QueryBox` — a union of
multidimensional closed intervals over one array's axes — and each hop of a
lineage path is a θ-join against a compressed table:

1. **Range join** (§V.B.1): keep (query row, table row) pairs whose key
   intervals overlap on *every* key attribute; the result keys are the
   intersections (the all-to-all insight makes this lossless for the queried
   cells).
2. **De-relativize** (§V.B.2): convert relative value attributes back to
   absolute intervals.  With our ``delta = val − key`` convention,
   ``rel_back`` is interval addition:  ``[ilo + dlo, ihi + dhi]`` where
   ``[ilo, ihi]`` is the key intersection — exact because the union of
   ``k + [dlo, dhi]`` over a contiguous ``k`` interval is itself contiguous.

Between hops the planner applies the paper's two optimizations (§V.B.3):
projection onto the next hop's attributes and adjacent-interval row merging
(``merge=False`` reproduces the DSLog-NoMerge ablation).

``theta_join_inverse`` additionally answers a query against a table
materialized in the *opposite* direction (the paper's ``rel_for``), so a
deployment that stores only backward tables can still serve forward queries.

Join execution (``path`` parameter, default ``"auto"``)
-------------------------------------------------------
* ``"index"`` — sorted candidate pruning via the per-table
  :class:`~repro.core.index.IntervalIndex` (lazily built, cached on the
  table, persisted by the catalog).  Work is proportional to the most
  selective attribute's candidate window, not ``nq × nr``.
* ``"dense"`` — the all-pairs overlap matrix, evaluated in blocks (numpy),
  or on TPU via the Pallas ``range_join_mask`` kernel.  Right for small
  tables and unselective queries, where index probes buy nothing.
* ``"auto"`` — dense for tables under ``_INDEX_MIN_ROWS`` rows; otherwise
  probe the index for a candidate estimate and fall back to dense when the
  estimated candidate fraction exceeds ``_DENSE_FRACTION`` (the probe work
  is two binary searches per query row per attribute — negligible).

``theta_join_batch`` answers many :class:`QueryBox`es against one table in a
single pass: the union of all query rows is deduplicated, each distinct box
probes the index exactly once, and the per-pair outputs are scattered back to
their owning queries.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from .index import IntervalIndex, ragged_ranges
from .intervals import coalesce_1d, lexsort_rows
from .provrc import _group_ids
from .table import CompressedTable

__all__ = [
    "QueryBox",
    "theta_join",
    "theta_join_inverse",
    "theta_join_batch",
    "theta_join_inverse_batch",
    "query_path",
    "merge_boxes",
    "INDEX_MIN_ROWS",
    "DENSE_FRACTION",
]

# Routing thresholds for path="auto"; the cost-based planner
# (repro/core/planner.py) shares them when picking a route per hop.
INDEX_MIN_ROWS = 1024
DENSE_FRACTION = 0.25
# back-compat aliases (pre-planner private names)
_INDEX_MIN_ROWS = INDEX_MIN_ROWS
_DENSE_FRACTION = DENSE_FRACTION
# Hand the dense path to the Pallas kernel only when a real accelerator is
# attached; in interpret mode the blocked numpy evaluation is faster.
_KERNEL_MIN_PAIRS = 1 << 20


@dataclass
class QueryBox:
    """Union of boxes over one array's axes: ``lo/hi`` are ``[N, ndim]``."""

    shape: tuple[int, ...]
    lo: np.ndarray = field(repr=False)
    hi: np.ndarray = field(repr=False)

    def __post_init__(self) -> None:
        nd = len(self.shape)
        self.lo = np.asarray(self.lo, np.int64).reshape(-1, nd)
        self.hi = np.asarray(self.hi, np.int64).reshape(-1, nd)

    # ------------------------------------------------------------------ #
    @property
    def n_rows(self) -> int:
        return int(self.lo.shape[0])

    @staticmethod
    def from_cells(shape: tuple[int, ...], cells: np.ndarray) -> "QueryBox":
        cells = np.asarray(cells, np.int64).reshape(-1, len(shape))
        return QueryBox(shape, cells.copy(), cells.copy())

    @staticmethod
    def from_range(
        shape: tuple[int, ...], lo: tuple[int, ...], hi: tuple[int, ...]
    ) -> "QueryBox":
        return QueryBox(shape, np.array([lo]), np.array([hi]))

    @staticmethod
    def full(shape: tuple[int, ...]) -> "QueryBox":
        nd = len(shape)
        return QueryBox(
            shape,
            np.zeros((1, nd), np.int64),
            np.array([[d - 1 for d in shape]], np.int64),
        )

    def cells(self) -> np.ndarray:
        """Expand to explicit cell indices (testing only)."""
        out = []
        for r in range(self.n_rows):
            ranges = [
                np.arange(self.lo[r, d], self.hi[r, d] + 1)
                for d in range(len(self.shape))
            ]
            grid = np.meshgrid(*ranges, indexing="ij") if ranges else []
            out.append(
                np.stack([g.ravel() for g in grid], axis=1)
                if grid
                else np.zeros((1, 0), np.int64)
            )
        if not out:
            return np.zeros((0, len(self.shape)), np.int64)
        return np.unique(np.concatenate(out, axis=0), axis=0)

    def cell_set(self) -> set[tuple[int, ...]]:
        return {tuple(int(v) for v in c) for c in self.cells()}

    def n_cells(self) -> int:
        """Number of distinct cells covered (exact despite overlaps)."""
        return int(self.cells().shape[0]) if self.n_rows else 0

    def volume_upper(self) -> int:
        """Sum of box volumes (upper bound; fast, no expansion)."""
        if not self.n_rows:
            return 0
        return int(np.prod(self.hi - self.lo + 1, axis=1).sum())


# --------------------------------------------------------------------------- #
# Range-join pair enumeration (indexed / dense routing)
# --------------------------------------------------------------------------- #
def _dense_pairs(
    q_lo: np.ndarray,
    q_hi: np.ndarray,
    r_lo: np.ndarray,
    r_hi: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """All-pairs overlap join, blocked to bound the pair matrix."""
    nq, l = q_lo.shape
    nr = r_lo.shape[0]
    if nq * nr >= _KERNEL_MIN_PAIRS:
        pairs = _kernel_pairs(q_lo, q_hi, r_lo, r_hi)
        if pairs is not None:
            return pairs
    qi_list, ri_list = [], []
    block = max(1, int(4_000_000 // max(nr, 1)))
    for s in range(0, nq, block):
        e = min(nq, s + block)
        ov = np.ones((e - s, nr), dtype=bool)
        for j in range(l):
            ov &= (q_lo[s:e, j : j + 1] <= r_hi[None, :, j]) & (
                r_lo[None, :, j] <= q_hi[s:e, j : j + 1]
            )
        qi, ri = np.nonzero(ov)
        qi_list.append(qi + s)
        ri_list.append(ri)
    qi = np.concatenate(qi_list) if qi_list else np.zeros(0, np.int64)
    ri = np.concatenate(ri_list) if ri_list else np.zeros(0, np.int64)
    return qi, ri


def _kernel_pairs(q_lo, q_hi, r_lo, r_hi):
    """Pallas ``range_join_mask`` dense fallback — only off interpret mode.

    Returns ``None`` when the kernel path is unavailable or not worthwhile
    (no accelerator, too many attributes for one tile, jax missing), so the
    caller falls through to blocked numpy.  Genuine kernel failures on an
    accelerator propagate — silently degrading to numpy would hide them.
    """
    try:
        from repro.kernels.ops import LANES, default_interpret, range_join_pairs
    except ImportError:
        return None
    if default_interpret() or 2 * q_lo.shape[1] > LANES:
        return None
    return range_join_pairs(q_lo, q_hi, r_lo, r_hi)


def _route_pairs(
    q_lo: np.ndarray,
    q_hi: np.ndarray,
    r_lo: np.ndarray,
    r_hi: np.ndarray,
    index_get,
    path: str,
) -> tuple[np.ndarray, np.ndarray]:
    """Pick indexed vs dense execution for one range join.

    ``index_get`` is a zero-arg callable returning the (cached)
    :class:`IntervalIndex` — deferred so the dense route never builds one.
    """
    if path not in ("auto", "index", "dense"):
        raise ValueError(f"unknown join path {path!r}")
    nq, nr = q_lo.shape[0], r_lo.shape[0]
    if nq == 0 or nr == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    if path == "dense":
        return _dense_pairs(q_lo, q_hi, r_lo, r_hi)
    if path == "auto" and nr < _INDEX_MIN_ROWS:
        return _dense_pairs(q_lo, q_hi, r_lo, r_hi)
    index: IntervalIndex = index_get()
    windows = None
    if path == "auto" and index.n_attrs:
        windows = index.probe_windows(q_lo, q_hi)  # one probe pass, reused below
        est = index.estimate_candidates(q_lo, q_hi, windows)
        if est > _DENSE_FRACTION * nq * nr:
            return _dense_pairs(q_lo, q_hi, r_lo, r_hi)
    return index.candidate_pairs(q_lo, q_hi, windows)


def _derelativize(
    table: CompressedTable,
    qi: np.ndarray,
    ri: np.ndarray,
    inter_lo: np.ndarray,
    inter_hi: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """Step 2 of the θ-join (§V.B.2) over an explicit pair list."""
    out_lo = table.val_lo[ri].copy()  # [P, m]
    out_hi = table.val_hi[ri].copy()
    ref = table.val_ref[ri]
    for j in range(table.n_key):
        sel = ref == j  # [P, m] mask of attrs relative to key j
        if sel.any():
            out_lo[sel] += np.broadcast_to(inter_lo[:, j : j + 1], sel.shape)[sel]
            out_hi[sel] += np.broadcast_to(inter_hi[:, j : j + 1], sel.shape)[sel]
    return out_lo, out_hi


# --------------------------------------------------------------------------- #
# θ-join
# --------------------------------------------------------------------------- #
def theta_join(
    q: QueryBox,
    table: CompressedTable,
    merge: bool = True,
    max_rows: int | None = None,
    path: str = "auto",
) -> QueryBox:
    """One hop: query over the table's *key* side, returning value-side boxes."""
    if q.shape != table.key_shape:
        raise ValueError(
            f"query shape {q.shape} does not match table key shape {table.key_shape}"
        )
    if table.is_symbolic:
        raise ValueError("instantiate symbolic table before querying")
    m = table.n_val
    nq, nr = q.n_rows, table.n_rows
    if nq == 0 or nr == 0:
        return QueryBox(table.val_shape, np.zeros((0, m)), np.zeros((0, m)))

    # ---- Step 1: range join --------------------------------------------- #
    qi, ri = _route_pairs(
        q.lo, q.hi, table.key_lo, table.key_hi, table.key_index, path
    )
    if max_rows is not None and qi.size > max_rows:
        raise RuntimeError(f"θ-join intermediate exceeded max_rows={max_rows}")
    if qi.size == 0:
        return QueryBox(table.val_shape, np.zeros((0, m)), np.zeros((0, m)))

    inter_lo = np.maximum(q.lo[qi], table.key_lo[ri])  # [P, l]
    inter_hi = np.minimum(q.hi[qi], table.key_hi[ri])

    # ---- Step 2: de-relativize ------------------------------------------ #
    out_lo, out_hi = _derelativize(table, qi, ri, inter_lo, inter_hi)
    res = QueryBox(table.val_shape, out_lo, out_hi)
    return merge_boxes(res) if merge else res


def _inverse_key_boxes(
    q: QueryBox, table: CompressedTable, qi: np.ndarray, ri: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-pair key intervals for the inverse join, plus the validity mask.

    The per-attribute overlap that produced the candidate pairs is necessary
    but not sufficient: two value attrs referencing the *same* key attribute
    constrain it jointly, so the intersection must be re-checked per pair.
    """
    l, m = table.n_key, table.n_val
    key_lo = table.key_lo[ri].astype(np.int64)  # [P, l]
    key_hi = table.key_hi[ri].astype(np.int64)
    for i in range(m):
        refs = table.val_ref[ri, i]  # [P]
        for j in range(l):
            jm = refs == j
            if not jm.any():
                continue
            cand_lo = q.lo[qi[jm], i] - table.val_hi[ri[jm], i]
            cand_hi = q.hi[qi[jm], i] - table.val_lo[ri[jm], i]
            key_lo[jm, j] = np.maximum(key_lo[jm, j], cand_lo)
            key_hi[jm, j] = np.minimum(key_hi[jm, j], cand_hi)
    valid = np.all(key_lo <= key_hi, axis=1)
    return key_lo, key_hi, valid


def theta_join_inverse(
    q: QueryBox, table: CompressedTable, merge: bool = True, path: str = "auto"
) -> QueryBox:
    """Query over the table's *value* side, returning key-side boxes.

    This is the paper's ``rel_for`` path: for a value attr relative to key
    ``j`` the constraint ``val = key_j + δ, δ ∈ [dlo, dhi]`` inverts to
    ``key_j ∈ [q_lo − dhi, q_hi − dlo]``, clamped by the stored key interval
    (the ``r.x`` term in the paper's formula).

    Candidate pruning runs over the table's *achievable value bounds*
    (``[key_lo_j + dlo, key_hi_j + dhi]`` for relative attrs, the stored
    interval for absolute ones): a row can contribute iff the query box
    overlaps those bounds on every value attribute, which is exactly the
    range-join predicate — so the same index machinery applies.
    """
    if q.shape != table.val_shape:
        raise ValueError(
            f"query shape {q.shape} does not match table val shape {table.val_shape}"
        )
    if table.is_symbolic:
        raise ValueError("instantiate symbolic table before querying")
    l = table.n_key
    nq, nr = q.n_rows, table.n_rows
    if nq == 0 or nr == 0:
        return QueryBox(table.key_shape, np.zeros((0, l)), np.zeros((0, l)))

    vb_lo, vb_hi = table.value_bounds()
    qi, ri = _route_pairs(q.lo, q.hi, vb_lo, vb_hi, table.val_index, path)
    if qi.size == 0:
        return QueryBox(table.key_shape, np.zeros((0, l)), np.zeros((0, l)))
    key_lo, key_hi, valid = _inverse_key_boxes(q, table, qi, ri)
    res = QueryBox(table.key_shape, key_lo[valid], key_hi[valid])
    return merge_boxes(res) if merge else res


# --------------------------------------------------------------------------- #
# Batched multi-query θ-join
# --------------------------------------------------------------------------- #
def _pool_boxes(
    queries: Sequence[QueryBox],
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Dedup the union of all query rows: ``(u_lo, u_hi, inv)`` where ``inv``
    maps each original row (queries concatenated) to its distinct box."""
    all_lo = np.concatenate([q.lo for q in queries], axis=0)
    all_hi = np.concatenate([q.hi for q in queries], axis=0)
    uniq, inv = np.unique(
        np.concatenate([all_lo, all_hi], axis=1), axis=0, return_inverse=True
    )
    inv = inv.reshape(-1)  # numpy 2.1 returned keepdims-shaped inverse
    nd = all_lo.shape[1]
    return uniq[:, :nd], uniq[:, nd:], inv


def _scatter_to_owners(
    queries: Sequence[QueryBox],
    inv: np.ndarray,
    ui: np.ndarray,
    n_uniq: int,
    out_lo: np.ndarray,
    out_hi: np.ndarray,
    shape: tuple[int, ...],
    merge: bool,
) -> list[QueryBox]:
    """Group per-pair outputs by distinct query row, scatter to owners."""
    perm = np.argsort(ui, kind="stable")
    pair_counts = np.bincount(ui, minlength=n_uniq).astype(np.int64)
    pair_offsets = np.cumsum(pair_counts) - pair_counts
    results: list[QueryBox] = []
    row_off = 0
    for q in queries:
        ids = inv[row_off : row_off + q.n_rows]
        row_off += q.n_rows
        _, pos = ragged_ranges(pair_offsets[ids], pair_offsets[ids] + pair_counts[ids])
        sel = perm[pos]
        res = QueryBox(shape, out_lo[sel], out_hi[sel])
        results.append(merge_boxes(res) if merge else res)
    return results


def theta_join_batch(
    queries: Sequence[QueryBox],
    table: CompressedTable,
    merge: bool = True,
    path: str = "auto",
) -> list[QueryBox]:
    """Answer many queries against one table in a single pass.

    All query rows are pooled and deduplicated, so a box shared by several
    queries probes the index (or the dense matrix) exactly once; the pair
    outputs are computed once per *distinct* (box, table row) pair and then
    scattered back to the owning queries.
    """
    if table.is_symbolic:
        raise ValueError("instantiate symbolic table before querying")
    for q in queries:
        if q.shape != table.key_shape:
            raise ValueError(
                f"query shape {q.shape} does not match table key shape "
                f"{table.key_shape}"
            )
    m = table.n_val
    empty = lambda: QueryBox(table.val_shape, np.zeros((0, m)), np.zeros((0, m)))
    if not queries:
        return []
    if sum(q.n_rows for q in queries) == 0 or table.n_rows == 0:
        return [empty() for _ in queries]

    u_lo, u_hi, inv = _pool_boxes(queries)
    ui, ri = _route_pairs(
        u_lo, u_hi, table.key_lo, table.key_hi, table.key_index, path
    )
    inter_lo = np.maximum(u_lo[ui], table.key_lo[ri])
    inter_hi = np.minimum(u_hi[ui], table.key_hi[ri])
    out_lo, out_hi = _derelativize(table, ui, ri, inter_lo, inter_hi)
    return _scatter_to_owners(
        queries, inv, ui, u_lo.shape[0], out_lo, out_hi, table.val_shape, merge
    )


def theta_join_inverse_batch(
    queries: Sequence[QueryBox],
    table: CompressedTable,
    merge: bool = True,
    path: str = "auto",
) -> list[QueryBox]:
    """Batched :func:`theta_join_inverse`: many value-side queries, one pass.

    Same pooling/dedup/scatter machinery as :func:`theta_join_batch`, with
    the candidate pruning running over the table's achievable value bounds
    and the per-pair key-interval inversion (plus its joint-validity check)
    done once per *distinct* (box, row) pair.
    """
    if table.is_symbolic:
        raise ValueError("instantiate symbolic table before querying")
    for q in queries:
        if q.shape != table.val_shape:
            raise ValueError(
                f"query shape {q.shape} does not match table val shape "
                f"{table.val_shape}"
            )
    l = table.n_key
    empty = lambda: QueryBox(table.key_shape, np.zeros((0, l)), np.zeros((0, l)))
    if not queries:
        return []
    if sum(q.n_rows for q in queries) == 0 or table.n_rows == 0:
        return [empty() for _ in queries]

    u_lo, u_hi, inv = _pool_boxes(queries)
    vb_lo, vb_hi = table.value_bounds()
    ui, ri = _route_pairs(u_lo, u_hi, vb_lo, vb_hi, table.val_index, path)
    pooled = QueryBox(table.val_shape, u_lo, u_hi)
    key_lo, key_hi, valid = _inverse_key_boxes(pooled, table, ui, ri)
    return _scatter_to_owners(
        queries,
        inv,
        ui[valid],
        u_lo.shape[0],
        key_lo[valid],
        key_hi[valid],
        table.key_shape,
        merge,
    )


# --------------------------------------------------------------------------- #
# Row reduction between hops (paper §V.B.3)
# --------------------------------------------------------------------------- #
def merge_boxes(q: QueryBox) -> QueryBox:
    """Dedup + merge boxes that are adjacent/overlapping on one axis.

    Same machinery as one multi-attribute range-encoding pass per axis,
    iterated to fixpoint.
    """
    lo, hi = q.lo, q.hi
    if lo.shape[0] <= 1:
        return q
    # exact duplicate removal first
    both = np.concatenate([lo, hi], axis=1)
    both = np.unique(both, axis=0)
    nd = len(q.shape)
    lo, hi = both[:, :nd], both[:, nd:]
    changed = True
    while changed and lo.shape[0] > 1:
        changed = False
        for d in range(nd):
            others = []
            for k in range(nd):
                if k != d:
                    others += [lo[:, k], hi[:, k]]
            order = lexsort_rows(others + [lo[:, d]])
            group = _group_ids([c[order] for c in others], lo.shape[0])
            starts, mlo, mhi = coalesce_1d(group, lo[order, d], hi[order, d])
            if starts.size != lo.shape[0]:
                sel = order[starts]
                lo, hi = lo[sel].copy(), hi[sel].copy()
                lo[:, d], hi[:, d] = mlo, mhi
                changed = True
    return QueryBox(q.shape, lo, hi)


# --------------------------------------------------------------------------- #
# Multi-hop planner
# --------------------------------------------------------------------------- #
def query_path(
    q: QueryBox,
    hops: list[tuple[CompressedTable, bool]],
    merge: bool = True,
    path: str = "auto",
) -> QueryBox:
    """Left-to-right plan over ``(table, inverse)`` hops (paper §V.B.3).

    ``inverse=False`` means the query side matches the table's keys
    (the natural direction for that materialization); ``inverse=True``
    uses ``theta_join_inverse``.

    Each hop's interval index is cached on its table, so a multi-hop plan
    (and any later plan revisiting the same tables) pays the index build at
    most once per table, not once per hop execution.
    """
    # Q' is encoded in the same compressed format as the tables (§V.B):
    # merging the query cells into boxes up front is what keeps the first
    # range join proportional to |boxes|, not |cells|.
    cur = merge_boxes(q) if merge else q
    for table, inverse in hops:
        cur = (
            theta_join_inverse(cur, table, merge=merge, path=path)
            if inverse
            else theta_join(cur, table, merge=merge, path=path)
        )
    return cur
