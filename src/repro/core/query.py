"""In-situ query processing over compressed lineage tables (paper §V).

Queries never decompress.  A query is a :class:`QueryBox` — a union of
multidimensional closed intervals over one array's axes — and each hop of a
lineage path is a θ-join against a compressed table:

1. **Range join** (§V.B.1): keep (query row, table row) pairs whose key
   intervals overlap on *every* key attribute; the result keys are the
   intersections (the all-to-all insight makes this lossless for the queried
   cells).
2. **De-relativize** (§V.B.2): convert relative value attributes back to
   absolute intervals.  With our ``delta = val − key`` convention,
   ``rel_back`` is interval addition:  ``[ilo + dlo, ihi + dhi]`` where
   ``[ilo, ihi]`` is the key intersection — exact because the union of
   ``k + [dlo, dhi]`` over a contiguous ``k`` interval is itself contiguous.

Between hops the planner applies the paper's two optimizations (§V.B.3):
projection onto the next hop's attributes and adjacent-interval row merging
(``merge=False`` reproduces the DSLog-NoMerge ablation).

``theta_join_inverse`` additionally answers a query against a table
materialized in the *opposite* direction (the paper's ``rel_for``), so a
deployment that stores only backward tables can still serve forward queries.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .intervals import coalesce_1d, lexsort_rows
from .provrc import _group_ids
from .table import CompressedTable

__all__ = ["QueryBox", "theta_join", "theta_join_inverse", "query_path", "merge_boxes"]


@dataclass
class QueryBox:
    """Union of boxes over one array's axes: ``lo/hi`` are ``[N, ndim]``."""

    shape: tuple[int, ...]
    lo: np.ndarray = field(repr=False)
    hi: np.ndarray = field(repr=False)

    def __post_init__(self) -> None:
        nd = len(self.shape)
        self.lo = np.asarray(self.lo, np.int64).reshape(-1, nd)
        self.hi = np.asarray(self.hi, np.int64).reshape(-1, nd)

    # ------------------------------------------------------------------ #
    @property
    def n_rows(self) -> int:
        return int(self.lo.shape[0])

    @staticmethod
    def from_cells(shape: tuple[int, ...], cells: np.ndarray) -> "QueryBox":
        cells = np.asarray(cells, np.int64).reshape(-1, len(shape))
        return QueryBox(shape, cells.copy(), cells.copy())

    @staticmethod
    def from_range(
        shape: tuple[int, ...], lo: tuple[int, ...], hi: tuple[int, ...]
    ) -> "QueryBox":
        return QueryBox(shape, np.array([lo]), np.array([hi]))

    @staticmethod
    def full(shape: tuple[int, ...]) -> "QueryBox":
        nd = len(shape)
        return QueryBox(
            shape,
            np.zeros((1, nd), np.int64),
            np.array([[d - 1 for d in shape]], np.int64),
        )

    def cells(self) -> np.ndarray:
        """Expand to explicit cell indices (testing only)."""
        out = []
        for r in range(self.n_rows):
            ranges = [
                np.arange(self.lo[r, d], self.hi[r, d] + 1)
                for d in range(len(self.shape))
            ]
            grid = np.meshgrid(*ranges, indexing="ij") if ranges else []
            out.append(
                np.stack([g.ravel() for g in grid], axis=1)
                if grid
                else np.zeros((1, 0), np.int64)
            )
        if not out:
            return np.zeros((0, len(self.shape)), np.int64)
        return np.unique(np.concatenate(out, axis=0), axis=0)

    def cell_set(self) -> set[tuple[int, ...]]:
        return {tuple(int(v) for v in c) for c in self.cells()}

    def n_cells(self) -> int:
        """Number of distinct cells covered (exact despite overlaps)."""
        return int(self.cells().shape[0]) if self.n_rows else 0

    def volume_upper(self) -> int:
        """Sum of box volumes (upper bound; fast, no expansion)."""
        if not self.n_rows:
            return 0
        return int(np.prod(self.hi - self.lo + 1, axis=1).sum())


# --------------------------------------------------------------------------- #
# θ-join
# --------------------------------------------------------------------------- #
def theta_join(
    q: QueryBox,
    table: CompressedTable,
    merge: bool = True,
    max_rows: int | None = None,
) -> QueryBox:
    """One hop: query over the table's *key* side, returning value-side boxes."""
    if q.shape != table.key_shape:
        raise ValueError(
            f"query shape {q.shape} does not match table key shape {table.key_shape}"
        )
    if table.is_symbolic:
        raise ValueError("instantiate symbolic table before querying")
    l, m = table.n_key, table.n_val
    nq, nr = q.n_rows, table.n_rows
    if nq == 0 or nr == 0:
        return QueryBox(table.val_shape, np.zeros((0, m)), np.zeros((0, m)))

    # ---- Step 1: range join (blocked to bound the pair matrix) ---------- #
    qi_list, ri_list = [], []
    block = max(1, int(4_000_000 // max(nr, 1)))
    for s in range(0, nq, block):
        e = min(nq, s + block)
        ov = np.ones((e - s, nr), dtype=bool)
        for j in range(l):
            ov &= (q.lo[s:e, j : j + 1] <= table.key_hi[None, :, j]) & (
                table.key_lo[None, :, j] <= q.hi[s:e, j : j + 1]
            )
        qi, ri = np.nonzero(ov)
        qi_list.append(qi + s)
        ri_list.append(ri)
    qi = np.concatenate(qi_list) if qi_list else np.zeros(0, np.int64)
    ri = np.concatenate(ri_list) if ri_list else np.zeros(0, np.int64)
    if max_rows is not None and qi.size > max_rows:
        raise RuntimeError(f"θ-join intermediate exceeded max_rows={max_rows}")
    if qi.size == 0:
        return QueryBox(table.val_shape, np.zeros((0, m)), np.zeros((0, m)))

    inter_lo = np.maximum(q.lo[qi], table.key_lo[ri])  # [P, l]
    inter_hi = np.minimum(q.hi[qi], table.key_hi[ri])

    # ---- Step 2: de-relativize ------------------------------------------ #
    out_lo = table.val_lo[ri].copy()  # [P, m]
    out_hi = table.val_hi[ri].copy()
    ref = table.val_ref[ri]
    for j in range(l):
        sel = ref == j  # [P, m] mask of attrs relative to key j
        if sel.any():
            out_lo[sel] += np.broadcast_to(inter_lo[:, j : j + 1], sel.shape)[sel]
            out_hi[sel] += np.broadcast_to(inter_hi[:, j : j + 1], sel.shape)[sel]

    res = QueryBox(table.val_shape, out_lo, out_hi)
    return merge_boxes(res) if merge else res


def theta_join_inverse(
    q: QueryBox, table: CompressedTable, merge: bool = True
) -> QueryBox:
    """Query over the table's *value* side, returning key-side boxes.

    This is the paper's ``rel_for`` path: for a value attr relative to key
    ``j`` the constraint ``val = key_j + δ, δ ∈ [dlo, dhi]`` inverts to
    ``key_j ∈ [q_lo − dhi, q_hi − dlo]``, clamped by the stored key interval
    (the ``r.x`` term in the paper's formula).
    """
    if q.shape != table.val_shape:
        raise ValueError(
            f"query shape {q.shape} does not match table val shape {table.val_shape}"
        )
    l, m = table.n_key, table.n_val
    nq, nr = q.n_rows, table.n_rows
    if nq == 0 or nr == 0:
        return QueryBox(table.key_shape, np.zeros((0, l)), np.zeros((0, l)))

    # Candidate key intervals per (query row, table row), then prune empties.
    key_lo = np.broadcast_to(table.key_lo[None, :, :], (nq, nr, l)).copy()
    key_hi = np.broadcast_to(table.key_hi[None, :, :], (nq, nr, l)).copy()
    valid = np.ones((nq, nr), dtype=bool)
    for i in range(m):
        refs = table.val_ref[:, i]  # [nr]
        vlo, vhi = table.val_lo[:, i], table.val_hi[:, i]
        qlo, qhi = q.lo[:, i : i + 1], q.hi[:, i : i + 1]  # [nq,1]
        abs_mask = refs == -1
        if abs_mask.any():
            ov = (qlo <= vhi[None, :]) & (vlo[None, :] <= qhi)
            valid &= np.where(abs_mask[None, :], ov, True)
        for j in range(l):
            jm = refs == j
            if not jm.any():
                continue
            cand_lo = qlo - vhi[None, :]  # [nq, nr]
            cand_hi = qhi - vlo[None, :]
            key_lo[:, :, j] = np.where(
                jm[None, :], np.maximum(key_lo[:, :, j], cand_lo), key_lo[:, :, j]
            )
            key_hi[:, :, j] = np.where(
                jm[None, :], np.minimum(key_hi[:, :, j], cand_hi), key_hi[:, :, j]
            )
    valid &= np.all(key_lo <= key_hi, axis=2)
    qi, ri = np.nonzero(valid)
    res = QueryBox(table.key_shape, key_lo[qi, ri], key_hi[qi, ri])
    return merge_boxes(res) if merge else res


# --------------------------------------------------------------------------- #
# Row reduction between hops (paper §V.B.3)
# --------------------------------------------------------------------------- #
def merge_boxes(q: QueryBox) -> QueryBox:
    """Dedup + merge boxes that are adjacent/overlapping on one axis.

    Same machinery as one multi-attribute range-encoding pass per axis,
    iterated to fixpoint.
    """
    lo, hi = q.lo, q.hi
    if lo.shape[0] <= 1:
        return q
    # exact duplicate removal first
    both = np.concatenate([lo, hi], axis=1)
    both = np.unique(both, axis=0)
    nd = len(q.shape)
    lo, hi = both[:, :nd], both[:, nd:]
    changed = True
    while changed and lo.shape[0] > 1:
        changed = False
        for d in range(nd):
            others = []
            for k in range(nd):
                if k != d:
                    others += [lo[:, k], hi[:, k]]
            order = lexsort_rows(others + [lo[:, d]])
            group = _group_ids([c[order] for c in others], lo.shape[0])
            starts, mlo, mhi = coalesce_1d(group, lo[order, d], hi[order, d])
            if starts.size != lo.shape[0]:
                sel = order[starts]
                lo, hi = lo[sel].copy(), hi[sel].copy()
                lo[:, d], hi[:, d] = mlo, mhi
                changed = True
    return QueryBox(q.shape, lo, hi)


# --------------------------------------------------------------------------- #
# Multi-hop planner
# --------------------------------------------------------------------------- #
def query_path(
    q: QueryBox,
    hops: list[tuple[CompressedTable, bool]],
    merge: bool = True,
) -> QueryBox:
    """Left-to-right plan over ``(table, inverse)`` hops (paper §V.B.3).

    ``inverse=False`` means the query side matches the table's keys
    (the natural direction for that materialization); ``inverse=True``
    uses ``theta_join_inverse``.
    """
    # Q' is encoded in the same compressed format as the tables (§V.B):
    # merging the query cells into boxes up front is what keeps the first
    # range join proportional to |boxes|, not |cells|.
    cur = merge_boxes(q) if merge else q
    for table, inverse in hops:
        cur = (
            theta_join_inverse(cur, table, merge=merge)
            if inverse
            else theta_join(cur, table, merge=merge)
        )
    return cur
