"""In-situ query processing over compressed lineage tables (paper §V).

Queries never decompress.  A query is a :class:`QueryBox` — a union of
multidimensional closed intervals over one array's axes — and each hop of a
lineage path is a θ-join against a compressed table:

1. **Range join** (§V.B.1): keep (query row, table row) pairs whose key
   intervals overlap on *every* key attribute; the result keys are the
   intersections (the all-to-all insight makes this lossless for the queried
   cells).
2. **De-relativize** (§V.B.2): convert relative value attributes back to
   absolute intervals.  With our ``delta = val − key`` convention,
   ``rel_back`` is interval addition:  ``[ilo + dlo, ihi + dhi]`` where
   ``[ilo, ihi]`` is the key intersection — exact because the union of
   ``k + [dlo, dhi]`` over a contiguous ``k`` interval is itself contiguous.

Between hops the planner applies the paper's two optimizations (§V.B.3):
projection onto the next hop's attributes and adjacent-interval row merging
(``merge=False`` reproduces the DSLog-NoMerge ablation).

``theta_join_inverse`` additionally answers a query against a table
materialized in the *opposite* direction (the paper's ``rel_for``), so a
deployment that stores only backward tables can still serve forward queries.

Join execution (``path`` parameter, default ``"auto"``)
-------------------------------------------------------
* ``"index"`` — sorted candidate pruning via the per-table
  :class:`~repro.core.index.IntervalIndex` (lazily built, cached on the
  table, persisted by the catalog).  Work is proportional to the most
  selective attribute's candidate window, not ``nq × nr``.
* ``"dense"`` — the all-pairs overlap matrix, evaluated in blocks (numpy),
  or on TPU via the Pallas ``range_join_mask`` kernel.  Right for small
  tables and unselective queries, where index probes buy nothing.
* ``"auto"`` — dense for tables under ``_INDEX_MIN_ROWS`` rows; otherwise
  probe the index for a candidate estimate and fall back to dense when the
  estimated candidate fraction exceeds ``_DENSE_FRACTION`` (the probe work
  is two binary searches per query row per attribute — negligible).

``theta_join_batch`` answers many :class:`QueryBox`es against one table in a
single pass: the union of all query rows is deduplicated, each distinct box
probes the index exactly once, and the per-pair outputs are scattered back to
their owning queries.
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.kernels.autotune import (  # jax-free: geometry table + buckets
    CANDIDATE_TWIN_CELLS,
    DEFAULT_GEOMETRY,
    DEFAULT_TWIN_CELLS,
    GeometryTuner,
    shape_bucket,
)

from .index import IntervalIndex, ragged_ranges
from .intervals import coalesce_1d, lexsort_rows
from .provrc import _group_ids
from .table import CompressedTable

__all__ = [
    "QueryBox",
    "JoinRequest",
    "BatchedJoinExecutor",
    "theta_join",
    "theta_join_inverse",
    "theta_join_batch",
    "theta_join_inverse_batch",
    "query_path",
    "merge_boxes",
    "canonical_boxes",
    "dense_backend",
    "INDEX_MIN_ROWS",
    "DENSE_FRACTION",
]

# Routing thresholds for path="auto"; the cost-based planner
# (repro/core/planner.py) shares them when picking a route per hop.
INDEX_MIN_ROWS = 1024
DENSE_FRACTION = 0.25
# back-compat aliases (pre-planner private names)
_INDEX_MIN_ROWS = INDEX_MIN_ROWS
_DENSE_FRACTION = DENSE_FRACTION
# Hand the dense path to the Pallas kernel only when a real accelerator is
# attached; in interpret mode the blocked numpy evaluation is faster.
_KERNEL_MIN_PAIRS = 1 << 20


@dataclass
class QueryBox:
    """Union of boxes over one array's axes: ``lo/hi`` are ``[N, ndim]``."""

    shape: tuple[int, ...]
    lo: np.ndarray = field(repr=False)
    hi: np.ndarray = field(repr=False)

    def __post_init__(self) -> None:
        nd = len(self.shape)
        self.lo = np.asarray(self.lo, np.int64).reshape(-1, nd)
        self.hi = np.asarray(self.hi, np.int64).reshape(-1, nd)

    # ------------------------------------------------------------------ #
    @property
    def n_rows(self) -> int:
        return int(self.lo.shape[0])

    @staticmethod
    def from_cells(shape: tuple[int, ...], cells: np.ndarray) -> "QueryBox":
        cells = np.asarray(cells, np.int64).reshape(-1, len(shape))
        return QueryBox(shape, cells.copy(), cells.copy())

    @staticmethod
    def from_range(
        shape: tuple[int, ...], lo: tuple[int, ...], hi: tuple[int, ...]
    ) -> "QueryBox":
        return QueryBox(shape, np.array([lo]), np.array([hi]))

    @staticmethod
    def full(shape: tuple[int, ...]) -> "QueryBox":
        nd = len(shape)
        return QueryBox(
            shape,
            np.zeros((1, nd), np.int64),
            np.array([[d - 1 for d in shape]], np.int64),
        )

    def cells(self) -> np.ndarray:
        """Expand to explicit cell indices (testing only)."""
        out = []
        for r in range(self.n_rows):
            ranges = [
                np.arange(self.lo[r, d], self.hi[r, d] + 1)
                for d in range(len(self.shape))
            ]
            grid = np.meshgrid(*ranges, indexing="ij") if ranges else []
            out.append(
                np.stack([g.ravel() for g in grid], axis=1)
                if grid
                else np.zeros((1, 0), np.int64)
            )
        if not out:
            return np.zeros((0, len(self.shape)), np.int64)
        return np.unique(np.concatenate(out, axis=0), axis=0)

    def cell_set(self) -> set[tuple[int, ...]]:
        return {tuple(int(v) for v in c) for c in self.cells()}

    def n_cells(self) -> int:
        """Number of distinct cells covered (exact despite overlaps)."""
        return int(self.cells().shape[0]) if self.n_rows else 0

    def volume_upper(self) -> int:
        """Sum of box volumes (upper bound; fast, no expansion)."""
        if not self.n_rows:
            return 0
        return int(np.prod(self.hi - self.lo + 1, axis=1).sum())


# --------------------------------------------------------------------------- #
# Range-join pair enumeration (indexed / dense routing)
# --------------------------------------------------------------------------- #
def _dense_pairs(
    q_lo: np.ndarray,
    q_hi: np.ndarray,
    r_lo: np.ndarray,
    r_hi: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """All-pairs overlap join, blocked to bound the pair matrix."""
    nq, l = q_lo.shape
    nr = r_lo.shape[0]
    if nq * nr >= _KERNEL_MIN_PAIRS:
        pairs = _kernel_pairs(q_lo, q_hi, r_lo, r_hi)
        if pairs is not None:
            return pairs
    qi_list, ri_list = [], []
    block = max(1, int(4_000_000 // max(nr, 1)))
    for s in range(0, nq, block):
        e = min(nq, s + block)
        ov = np.ones((e - s, nr), dtype=bool)
        for j in range(l):
            ov &= (q_lo[s:e, j : j + 1] <= r_hi[None, :, j]) & (
                r_lo[None, :, j] <= q_hi[s:e, j : j + 1]
            )
        qi, ri = np.nonzero(ov)
        qi_list.append(qi + s)
        ri_list.append(ri)
    qi = np.concatenate(qi_list) if qi_list else np.zeros(0, np.int64)
    ri = np.concatenate(ri_list) if ri_list else np.zeros(0, np.int64)
    return qi, ri


def _kernel_pairs(q_lo, q_hi, r_lo, r_hi):
    """Pallas ``range_join_mask`` dense fallback — only off interpret mode.

    Returns ``None`` when the kernel path is unavailable or cannot express
    the join faithfully (no accelerator, too many attributes for one tile,
    coordinates outside the int32 pack range — they would silently wrap —
    or jax missing), so the caller falls through to blocked numpy.  Genuine
    kernel failures on an accelerator propagate — silently degrading to
    numpy would hide them.
    """
    try:
        from repro.kernels.ops import (
            LANES,
            default_interpret,
            fits_int32,
            range_join_pairs,
        )
    except ImportError:
        return None
    if default_interpret() or 2 * q_lo.shape[1] > LANES:
        return None
    if not fits_int32(q_lo, q_hi, r_lo, r_hi):
        return None
    return range_join_pairs(q_lo, q_hi, r_lo, r_hi)


def dense_backend(
    n_attrs: int, int32_ok: bool = True, segmented: bool = True
) -> str:
    """Which engine a dense join of ``n_attrs`` attributes would run on.

    ``"tpu"`` when the Pallas kernel applies, else a ``"np:*"`` reason
    (``np:cpu`` interpret mode, ``np:wide`` lane capacity — for
    ``segmented`` joins the batched pack's segment lane counts too,
    ``np:i64`` int32 overflow, ``np:nojax``).  Rendered into
    ``plan.describe()`` so dense-route fallbacks are visible instead of
    silent.
    """
    try:
        from repro.kernels.ops import LANES, default_interpret
    except ImportError:
        return "np:nojax"
    if 2 * (n_attrs + (1 if segmented else 0)) > LANES:
        return "np:wide"
    if not int32_ok:
        return "np:i64"
    if default_interpret():
        return "np:cpu"
    return "tpu"


def _route_decision(
    q_lo: np.ndarray,
    q_hi: np.ndarray,
    r_lo: np.ndarray,
    r_hi: np.ndarray,
    index_get,
    path: str,
):
    """Shared indexed-vs-dense routing: ``("dense", None)`` or
    ``("index", windows)``.

    ``path="batched"`` is the planner's batched-dense route: the same dense
    decision, executed through the packed :class:`BatchedJoinExecutor`
    engine when one is driving the joins.  ``index_get`` is a zero-arg
    callable returning the (cached) :class:`IntervalIndex` — deferred so
    the dense route never builds one.
    """
    if path not in ("auto", "index", "dense", "batched"):
        raise ValueError(f"unknown join path {path!r}")
    nq, nr = q_lo.shape[0], r_lo.shape[0]
    if path in ("dense", "batched"):
        return "dense", None
    if path == "auto" and nr < _INDEX_MIN_ROWS:
        return "dense", None
    index: IntervalIndex = index_get()
    windows = None
    if path == "auto" and index.n_attrs:
        windows = index.probe_windows(q_lo, q_hi)  # one probe pass, reused below
        est = index.estimate_candidates(q_lo, q_hi, windows)
        if est > _DENSE_FRACTION * nq * nr:
            return "dense", None
    return "index", windows


def _route_pairs(
    q_lo: np.ndarray,
    q_hi: np.ndarray,
    r_lo: np.ndarray,
    r_hi: np.ndarray,
    index_get,
    path: str,
) -> tuple[np.ndarray, np.ndarray]:
    """Pick indexed vs dense execution for one range join."""
    nq, nr = q_lo.shape[0], r_lo.shape[0]
    if nq == 0 or nr == 0:
        if path not in ("auto", "index", "dense", "batched"):
            raise ValueError(f"unknown join path {path!r}")
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    route, windows = _route_decision(q_lo, q_hi, r_lo, r_hi, index_get, path)
    if route == "dense":
        return _dense_pairs(q_lo, q_hi, r_lo, r_hi)
    return index_get().candidate_pairs(q_lo, q_hi, windows)


def _derelativize(
    table: CompressedTable,
    qi: np.ndarray,
    ri: np.ndarray,
    inter_lo: np.ndarray,
    inter_hi: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """Step 2 of the θ-join (§V.B.2) over an explicit pair list."""
    out_lo = table.val_lo[ri].copy()  # [P, m]
    out_hi = table.val_hi[ri].copy()
    ref = table.val_ref[ri]
    for j in range(table.n_key):
        sel = ref == j  # [P, m] mask of attrs relative to key j
        if sel.any():
            out_lo[sel] += np.broadcast_to(inter_lo[:, j : j + 1], sel.shape)[sel]
            out_hi[sel] += np.broadcast_to(inter_hi[:, j : j + 1], sel.shape)[sel]
    return out_lo, out_hi


# --------------------------------------------------------------------------- #
# θ-join
# --------------------------------------------------------------------------- #
def theta_join(
    q: QueryBox,
    table: CompressedTable,
    merge: bool = True,
    max_rows: int | None = None,
    path: str = "auto",
) -> QueryBox:
    """One hop: query over the table's *key* side, returning value-side boxes."""
    if q.shape != table.key_shape:
        raise ValueError(
            f"query shape {q.shape} does not match table key shape {table.key_shape}"
        )
    if table.is_symbolic:
        raise ValueError("instantiate symbolic table before querying")
    m = table.n_val
    nq, nr = q.n_rows, table.n_rows
    if nq == 0 or nr == 0:
        return QueryBox(table.val_shape, np.zeros((0, m)), np.zeros((0, m)))

    # ---- Step 1: range join --------------------------------------------- #
    qi, ri = _route_pairs(
        q.lo, q.hi, table.key_lo, table.key_hi, table.key_index, path
    )
    if max_rows is not None and qi.size > max_rows:
        raise RuntimeError(f"θ-join intermediate exceeded max_rows={max_rows}")
    if qi.size == 0:
        return QueryBox(table.val_shape, np.zeros((0, m)), np.zeros((0, m)))

    inter_lo = np.maximum(q.lo[qi], table.key_lo[ri])  # [P, l]
    inter_hi = np.minimum(q.hi[qi], table.key_hi[ri])

    # ---- Step 2: de-relativize ------------------------------------------ #
    out_lo, out_hi = _derelativize(table, qi, ri, inter_lo, inter_hi)
    res = QueryBox(table.val_shape, out_lo, out_hi)
    return merge_boxes(res) if merge else res


def _inverse_key_boxes(
    q: QueryBox, table: CompressedTable, qi: np.ndarray, ri: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-pair key intervals for the inverse join, plus the validity mask.

    The per-attribute overlap that produced the candidate pairs is necessary
    but not sufficient: two value attrs referencing the *same* key attribute
    constrain it jointly, so the intersection must be re-checked per pair.
    """
    l, m = table.n_key, table.n_val
    key_lo = table.key_lo[ri].astype(np.int64)  # [P, l]
    key_hi = table.key_hi[ri].astype(np.int64)
    for i in range(m):
        refs = table.val_ref[ri, i]  # [P]
        for j in range(l):
            jm = refs == j
            if not jm.any():
                continue
            cand_lo = q.lo[qi[jm], i] - table.val_hi[ri[jm], i]
            cand_hi = q.hi[qi[jm], i] - table.val_lo[ri[jm], i]
            key_lo[jm, j] = np.maximum(key_lo[jm, j], cand_lo)
            key_hi[jm, j] = np.minimum(key_hi[jm, j], cand_hi)
    valid = np.all(key_lo <= key_hi, axis=1)
    return key_lo, key_hi, valid


def theta_join_inverse(
    q: QueryBox, table: CompressedTable, merge: bool = True, path: str = "auto"
) -> QueryBox:
    """Query over the table's *value* side, returning key-side boxes.

    This is the paper's ``rel_for`` path: for a value attr relative to key
    ``j`` the constraint ``val = key_j + δ, δ ∈ [dlo, dhi]`` inverts to
    ``key_j ∈ [q_lo − dhi, q_hi − dlo]``, clamped by the stored key interval
    (the ``r.x`` term in the paper's formula).

    Candidate pruning runs over the table's *achievable value bounds*
    (``[key_lo_j + dlo, key_hi_j + dhi]`` for relative attrs, the stored
    interval for absolute ones): a row can contribute iff the query box
    overlaps those bounds on every value attribute, which is exactly the
    range-join predicate — so the same index machinery applies.
    """
    if q.shape != table.val_shape:
        raise ValueError(
            f"query shape {q.shape} does not match table val shape {table.val_shape}"
        )
    if table.is_symbolic:
        raise ValueError("instantiate symbolic table before querying")
    l = table.n_key
    nq, nr = q.n_rows, table.n_rows
    if nq == 0 or nr == 0:
        return QueryBox(table.key_shape, np.zeros((0, l)), np.zeros((0, l)))

    vb_lo, vb_hi = table.value_bounds()
    qi, ri = _route_pairs(q.lo, q.hi, vb_lo, vb_hi, table.val_index, path)
    if qi.size == 0:
        return QueryBox(table.key_shape, np.zeros((0, l)), np.zeros((0, l)))
    key_lo, key_hi, valid = _inverse_key_boxes(q, table, qi, ri)
    res = QueryBox(table.key_shape, key_lo[valid], key_hi[valid])
    return merge_boxes(res) if merge else res


# --------------------------------------------------------------------------- #
# Batched multi-query θ-join
# --------------------------------------------------------------------------- #
def _unique_rows(
    a: np.ndarray, return_inverse: bool = False
) -> "np.ndarray | tuple[np.ndarray, np.ndarray]":
    """``np.unique(a, axis=0[, return_inverse])`` for 2-D integer arrays.

    Bit-identical output (same lexicographic row order, same inverse), but
    via ``lexsort`` over the integer columns — ``np.unique(axis=0)`` pays
    ~4x more for its void-dtype view sort, and these row dedups run on
    every hop of every query.
    """
    n = a.shape[0]
    if n == 0:
        return (a, np.zeros(0, np.int64)) if return_inverse else a
    order = np.lexsort(a.T[::-1])  # first column most significant
    s = a[order]
    flag = np.empty(n, bool)
    flag[0] = True
    np.any(s[1:] != s[:-1], axis=1, out=flag[1:])
    uniq = s[flag]
    if not return_inverse:
        return uniq
    inv = np.empty(n, np.int64)
    inv[order] = np.cumsum(flag) - 1
    return uniq, inv


def _pool_boxes(
    queries: Sequence[QueryBox],
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Dedup the union of all query rows: ``(u_lo, u_hi, inv)`` where ``inv``
    maps each original row (queries concatenated) to its distinct box."""
    all_lo = np.concatenate([q.lo for q in queries], axis=0)
    all_hi = np.concatenate([q.hi for q in queries], axis=0)
    uniq, inv = _unique_rows(
        np.concatenate([all_lo, all_hi], axis=1), return_inverse=True
    )
    nd = all_lo.shape[1]
    return uniq[:, :nd], uniq[:, nd:], inv


def _scatter_to_owners(
    queries: Sequence[QueryBox],
    inv: np.ndarray,
    ui: np.ndarray,
    n_uniq: int,
    out_lo: np.ndarray,
    out_hi: np.ndarray,
    shape: tuple[int, ...],
    merge: bool,
) -> list[QueryBox]:
    """Group per-pair outputs by distinct query row, scatter to owners."""
    perm = np.argsort(ui, kind="stable")
    pair_counts = np.bincount(ui, minlength=n_uniq).astype(np.int64)
    pair_offsets = np.cumsum(pair_counts) - pair_counts
    results: list[QueryBox] = []
    row_off = 0
    for q in queries:
        ids = inv[row_off : row_off + q.n_rows]
        row_off += q.n_rows
        _, pos = ragged_ranges(pair_offsets[ids], pair_offsets[ids] + pair_counts[ids])
        sel = perm[pos]
        res = QueryBox(shape, out_lo[sel], out_hi[sel])
        results.append(merge_boxes(res) if merge else res)
    return results


def _prepare_batch(
    queries: Sequence[QueryBox], table: CompressedTable, inverse: bool
):
    """Validate + pool one batched join; shared with the batched executor.

    Returns ``("done", results)`` for trivially-empty joins, else
    ``("join", u_lo, u_hi, inv, r_lo, r_hi, index_get)`` where the ``r``
    side is the table's key intervals (natural join) or its achievable
    value bounds (inverse join).
    """
    if table.is_symbolic:
        raise ValueError("instantiate symbolic table before querying")
    q_side = table.val_shape if inverse else table.key_shape
    side_name = "val" if inverse else "key"
    for q in queries:
        if q.shape != q_side:
            raise ValueError(
                f"query shape {q.shape} does not match table {side_name} "
                f"shape {q_side}"
            )
    n_out = table.n_key if inverse else table.n_val
    out_shape = table.key_shape if inverse else table.val_shape
    empty = lambda: QueryBox(
        out_shape, np.zeros((0, n_out)), np.zeros((0, n_out))
    )
    if not queries:
        return ("done", [])
    if sum(q.n_rows for q in queries) == 0 or table.n_rows == 0:
        return ("done", [empty() for _ in queries])
    u_lo, u_hi, inv = _pool_boxes(queries)
    if inverse:
        r_lo, r_hi = table.value_bounds()
        index_get = table.val_index
    else:
        r_lo, r_hi = table.key_lo, table.key_hi
        index_get = table.key_index
    return ("join", u_lo, u_hi, inv, r_lo, r_hi, index_get)


def _finalize_batch(
    queries: Sequence[QueryBox],
    table: CompressedTable,
    inverse: bool,
    u_lo: np.ndarray,
    u_hi: np.ndarray,
    inv: np.ndarray,
    ui: np.ndarray,
    ri: np.ndarray,
    merge: bool,
) -> list[QueryBox]:
    """Steps 2+ of a batched join over an enumerated pair list."""
    if inverse:
        pooled = QueryBox(table.val_shape, u_lo, u_hi)
        key_lo, key_hi, valid = _inverse_key_boxes(pooled, table, ui, ri)
        return _scatter_to_owners(
            queries,
            inv,
            ui[valid],
            u_lo.shape[0],
            key_lo[valid],
            key_hi[valid],
            table.key_shape,
            merge,
        )
    inter_lo = np.maximum(u_lo[ui], table.key_lo[ri])
    inter_hi = np.minimum(u_hi[ui], table.key_hi[ri])
    out_lo, out_hi = _derelativize(table, ui, ri, inter_lo, inter_hi)
    return _scatter_to_owners(
        queries, inv, ui, u_lo.shape[0], out_lo, out_hi, table.val_shape, merge
    )


def theta_join_batch(
    queries: Sequence[QueryBox],
    table: CompressedTable,
    merge: bool = True,
    path: str = "auto",
) -> list[QueryBox]:
    """Answer many queries against one table in a single pass.

    All query rows are pooled and deduplicated, so a box shared by several
    queries probes the index (or the dense matrix) exactly once; the pair
    outputs are computed once per *distinct* (box, table row) pair and then
    scattered back to the owning queries.
    """
    pre = _prepare_batch(queries, table, inverse=False)
    if pre[0] == "done":
        return pre[1]
    _, u_lo, u_hi, inv, r_lo, r_hi, index_get = pre
    ui, ri = _route_pairs(u_lo, u_hi, r_lo, r_hi, index_get, path)
    return _finalize_batch(queries, table, False, u_lo, u_hi, inv, ui, ri, merge)


def theta_join_inverse_batch(
    queries: Sequence[QueryBox],
    table: CompressedTable,
    merge: bool = True,
    path: str = "auto",
) -> list[QueryBox]:
    """Batched :func:`theta_join_inverse`: many value-side queries, one pass.

    Same pooling/dedup/scatter machinery as :func:`theta_join_batch`, with
    the candidate pruning running over the table's achievable value bounds
    and the per-pair key-interval inversion (plus its joint-validity check)
    done once per *distinct* (box, row) pair.
    """
    pre = _prepare_batch(queries, table, inverse=True)
    if pre[0] == "done":
        return pre[1]
    _, u_lo, u_hi, inv, r_lo, r_hi, index_get = pre
    ui, ri = _route_pairs(u_lo, u_hi, r_lo, r_hi, index_get, path)
    return _finalize_batch(queries, table, True, u_lo, u_hi, inv, ui, ri, merge)


# --------------------------------------------------------------------------- #
# Batched accelerator execution of plan steps
# --------------------------------------------------------------------------- #
@dataclass
class JoinRequest:
    """One batched θ-join a plan step wants executed.

    ``path`` follows :func:`_route_decision` (``"batched"`` is the
    planner's batched-dense route).  Requests are what the planner hands a
    :class:`BatchedJoinExecutor` — one per (step, lineage entry) pair in a
    ready plan frontier.
    """

    queries: Sequence[QueryBox]
    table: CompressedTable
    inverse: bool = False
    merge: bool = True
    path: str = "auto"


# autotuning thresholds: frontiers below these run the default geometry —
# measuring candidates costs extra dispatches, which only amortize when the
# workload itself is big enough to show a geometry's effect
_TUNE_MIN_ROWS = 2048  # kernel path: packed q+r rows across the frontier
_TWIN_TUNE_MIN_CELLS = 1 << 22  # twin: mask cells of the largest segment


def _twin_pairs(
    q_lo: np.ndarray,
    q_hi: np.ndarray,
    rl: np.ndarray,
    rh: np.ndarray,
    scratch: dict | None = None,
    block_cells: int = DEFAULT_TWIN_CELLS[0],
) -> tuple[np.ndarray, np.ndarray]:
    """Blocked dense overlap pairs over packed table columns.

    The GIL-releasing numpy twin of the segmented kernel: ``rl``/``rh`` are
    the table's cached contiguous ``[l, N]`` columns (int32 when safe —
    see :meth:`CompressedTable.dense_join_cols`), the query side is packed
    per call, and the conjunction is evaluated with reusable buffers
    (``scratch``, shared across one packed dispatch's segments) and
    in-place ufuncs.  Pair extraction runs on the raveled mask
    (``flatnonzero`` + divmod — numpy's 2-D nonzero pays an order of
    magnitude more on sparse masks).  All heavy work happens inside numpy
    inner loops, which drop the GIL — this is what lets thread-pool plan
    execution actually overlap on CPU.  Pair order is row-major, identical
    to :func:`_dense_pairs`.
    """
    nq, l = q_lo.shape
    nr = rl.shape[1]
    if rl.dtype == np.int32:
        i32 = np.iinfo(np.int32)
        small = (
            q_lo.min() >= i32.min and q_hi.max() <= i32.max
            if q_lo.size
            else True
        )
        qdt = np.int32 if small else np.int64
    else:
        qdt = np.int64
    qlt = np.ascontiguousarray(q_lo.T, dtype=qdt)  # [l, nq]
    qht = np.ascontiguousarray(q_hi.T, dtype=qdt)
    # block_cells is the twin's launch geometry (mask cells per row block);
    # the executor's GeometryTuner picks it per frontier-shape bucket
    block = max(1, int(block_cells // max(nr, 1)))
    rows = min(block, nq)
    if scratch is None:
        scratch = {}
    cells = rows * nr
    if scratch.get("n", 0) < cells:
        scratch["ov"] = np.empty(cells, np.bool_)
        scratch["tmp"] = np.empty(cells, np.bool_)
        scratch["n"] = cells
    qi_list, ri_list = [], []
    for s in range(0, nq, block):
        e = min(nq, s + block)
        o = scratch["ov"][: (e - s) * nr].reshape(e - s, nr)
        t = scratch["tmp"][: (e - s) * nr].reshape(e - s, nr)
        np.less_equal(qlt[0, s:e, None], rh[0][None, :], out=o)
        np.less_equal(rl[0][None, :], qht[0, s:e, None], out=t)
        np.logical_and(o, t, out=o)
        for j in range(1, l):
            np.less_equal(qlt[j, s:e, None], rh[j][None, :], out=t)
            np.logical_and(o, t, out=o)
            np.less_equal(rl[j][None, :], qht[j, s:e, None], out=t)
            np.logical_and(o, t, out=o)
        flat = np.flatnonzero(o.ravel())
        qi, ri = np.divmod(flat, nr)
        qi_list.append(qi + s)
        ri_list.append(ri)
    if len(qi_list) == 1:
        return (
            qi_list[0].astype(np.int64, copy=False),
            ri_list[0].astype(np.int64, copy=False),
        )
    return (
        np.concatenate(qi_list).astype(np.int64, copy=False),
        np.concatenate(ri_list).astype(np.int64, copy=False),
    )


class BatchedJoinExecutor:
    """Pack a plan frontier's dense θ-joins into one blocked evaluation.

    The planner hands every :class:`JoinRequest` ready in a frontier —
    across plan branches and, on sharded stores, across exchange-free
    sub-plans — to :meth:`run`.  Index-routed requests execute through the
    per-table :class:`IntervalIndex` as before; every dense-routed request
    becomes one *segment* of a single packed ``[NQ, 128] × [NR, 128]``
    evaluation:

    * on an accelerator, one :func:`repro.kernels.ops.segmented_range_join_pairs`
      launch — segment ids in the spare lanes keep per-step masks separable,
      so the whole frontier costs one kernel dispatch instead of one per hop;
    * in interpret/CPU mode, the GIL-releasing blocked-numpy twin
      (:func:`_twin_pairs`) over the tables' cached contiguous int32
      columns — same pair lists bit-for-bit, and thread-pool workers in
      ``planner._execute_parallel`` finally overlap because the hot loops
      run outside the GIL.

    Segments the kernel cannot express faithfully (lane capacity, int32
    overflow — see the ``np:*`` notes in ``plan.describe()``) route to the
    twin automatically.  Results are bit-identical to the serial per-hop
    loop; ``stats`` (an ``io_stats`` bump callable) meters launches, batch
    occupancy, and the tile schedule (``batch_tiles_visited`` vs the
    cross-product tiles the block-diagonal layout ``batch_tiles_skipped``).

    Launch geometry comes from a :class:`~repro.kernels.autotune.
    GeometryTuner` (``tuner``; the store's persisted table when the planner
    creates the executor): on the first big frontier of a new (backend,
    shape-bucket) combination the candidates are measured in place and the
    winner cached — ``(block_q, block_r)`` tiles for the kernel path, the
    mask-block cell budget for the twin.  ``engine`` pins the dense engine
    for tests/benchmarks: ``"kernel"`` forces the segmented Pallas path
    (interpreted when no TPU is attached), ``"twin"`` the numpy path,
    ``None`` picks by backend as before.
    """

    def __init__(
        self,
        stats=None,
        interpret: bool | None = None,
        tuner: "GeometryTuner | None" = None,
        engine: str | None = None,
        metrics=None,
        trace_source=None,
    ):
        if engine not in (None, "kernel", "twin"):
            raise ValueError(f"unknown dense engine {engine!r}")
        self._stats = stats if stats is not None else (lambda key, n=1: None)
        self._interpret = interpret
        self._tuner = tuner if tuner is not None else GeometryTuner()
        self._engine = engine
        # optional registry (labeled autotune-decision counters) and a
        # callable yielding the owning store's active QueryTrace (or None)
        self._metrics = metrics
        self._trace_source = trace_source
        self._pool = None  # lazy worker pool for twin-segment fan-out
        self._pool_width = 0
        # measured tile occupancy: EMA of (scheduled tile cells / useful
        # pair cells) over dense dispatches — the planner's batched-route
        # discount scales by this instead of assuming perfect packing
        self._tile_waste = 1.0
        # most recent launch geometry per engine family, for plan notes
        self._last_geometry: dict[str, tuple[int, ...]] = {}

    @property
    def measured_waste(self) -> float:
        """EMA of scheduled-tile cells over useful pair cells (≥ 1)."""
        return self._tile_waste

    def _observe_occupancy(self, tile_cells: float, useful_cells: float) -> None:
        if useful_cells <= 0:
            return
        waste = max(1.0, tile_cells / useful_cells)
        self._tile_waste = 0.8 * self._tile_waste + 0.2 * waste

    def geometry_label(self, backend: str) -> str:
        """Launch-geometry annotation for ``plan.describe()`` hop notes.

        ``256x256``-style tile shapes for the kernel path (``backend ==
        "tpu"``), the twin's mask-block budget (``4m`` cells) otherwise —
        the most recently used geometry, or the default before any dispatch.
        """
        if backend == "tpu":
            bq, br = self._last_geometry.get("kernel", DEFAULT_GEOMETRY)
            return f"{bq}x{br}"
        (cells,) = self._last_geometry.get("np", DEFAULT_TWIN_CELLS)
        return f"{cells >> 20}m" if cells >= 1 << 20 else f"{cells >> 10}k"

    def _workers(self, width: int):
        """A reusable thread pool for splitting twin segments (CPU mode)."""
        import concurrent.futures as cf

        if self._pool is None or self._pool_width < width:
            if self._pool is not None:
                self._pool.shutdown(wait=False)
            self._pool = cf.ThreadPoolExecutor(
                max_workers=width, thread_name_prefix="dslog-join"
            )
            self._pool_width = width
        return self._pool

    # ------------------------------------------------------------------ #
    def run(
        self, requests: Sequence[JoinRequest], workers: int | None = None
    ) -> list[list[QueryBox]]:
        """Execute one frontier's requests; returns per-request results.

        ``workers=N`` splits the packed dense segments across an N-thread
        pool — each worker's share is almost entirely GIL-releasing numpy
        (the twin's blocked mask passes), so the segments genuinely
        overlap on CPU while preparation, index probes, and result
        assembly stay on the calling thread.  Results are bit-identical
        for any worker count.
        """
        results: list[list[QueryBox] | None] = [None] * len(requests)
        dense: list[tuple] = []
        for i, req in enumerate(requests):
            pre = _prepare_batch(req.queries, req.table, req.inverse)
            if pre[0] == "done":
                results[i] = pre[1]
                continue
            _, u_lo, u_hi, inv, r_lo, r_hi, index_get = pre
            route, windows = _route_decision(
                u_lo, u_hi, r_lo, r_hi, index_get, req.path
            )
            if route == "index":
                ui, ri = index_get().candidate_pairs(u_lo, u_hi, windows)
                results[i] = _finalize_batch(
                    req.queries, req.table, req.inverse,
                    u_lo, u_hi, inv, ui, ri, req.merge,
                )
            else:
                dense.append((i, req, u_lo, u_hi, inv, r_lo, r_hi))
        if dense:
            self._run_dense(dense, results, workers)
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------ #
    def _run_dense(
        self,
        items: list[tuple],
        results: list,
        workers: int | None = None,
    ) -> None:
        """Evaluate and finalize every dense segment, one packed dispatch."""
        kernel_idx: list[int] = []
        try:
            from repro.kernels.ops import LANES, default_interpret, fits_int32
        except ImportError:
            LANES = default_interpret = fits_int32 = None  # type: ignore
        interpret = (
            self._interpret
            if self._interpret is not None
            else (default_interpret() if default_interpret else True)
        )
        use_kernel = self._engine == "kernel" or (
            self._engine is None and not interpret
        )
        if use_kernel and LANES is not None:
            # eligibility is per segment: one over-wide or int64 join must
            # not demote the rest of the frontier off the kernel path (and
            # over-wide segments never inflate the shared pack width).  The
            # lane slack keeps the dense-layout fallback — which spends one
            # spare lane on the segment id when packing several segments —
            # expressible for any eligible subset.
            lane_slack = 1 if len(items) > 1 else 0
            kernel_idx = [
                k
                for k, it in enumerate(items)
                if 2 * (it[3].shape[1] + lane_slack) <= LANES
                and fits_int32(it[2], it[3], it[5], it[6])
            ]

        def finalize(k: int, ui: np.ndarray, ri: np.ndarray) -> None:
            i, req, u_lo, u_hi, inv, _r_lo, _r_hi = items[k]
            results[i] = _finalize_batch(
                req.queries, req.table, req.inverse,
                u_lo, u_hi, inv, ui, ri, req.merge,
            )

        tr = self._trace_source() if self._trace_source is not None else None
        if kernel_idx:
            from repro.kernels.ops import segmented_range_join_pairs

            t0 = time.perf_counter()
            segs = [
                (items[k][2], items[k][3], items[k][5], items[k][6])
                for k in kernel_idx
            ]
            shapes = [(s[0].shape[0], s[2].shape[0], s[0].shape[1]) for s in segs]
            backend = "tpu" if not interpret else "interpret"
            bucket = shape_bucket(shapes)
            geom = self._tuner.lookup(backend, bucket)
            result = None
            if geom is None:
                if sum(nq + nr for nq, nr, _ in shapes) >= _TUNE_MIN_ROWS:
                    # first big frontier of this shape: measure the
                    # candidates on it (the winner's run is kept, so the
                    # tuning dispatch does the real work) and persist the
                    # geometry via the store's autotune table
                    geom, result = self._tuner.pick(
                        backend,
                        bucket,
                        runner=lambda g: segmented_range_join_pairs(
                            segs, block_q=g[0], block_r=g[1],
                            interpret=interpret,
                        ),
                    )
                    if self._metrics is not None:
                        self._metrics.inc(
                            "autotune_decisions",
                            backend=backend,
                            bucket=str(bucket),
                        )
                else:
                    geom = DEFAULT_GEOMETRY
            if result is None:
                result = segmented_range_join_pairs(
                    segs, block_q=geom[0], block_r=geom[1], interpret=interpret
                )
            seg_pairs, info = result
            for k, (ui, ri) in zip(kernel_idx, seg_pairs):
                finalize(k, ui, ri)
            self._last_geometry["kernel"] = tuple(geom)
            self._stats("kernel_launches", info["launches"])
            self._stats("joins_packed", len(kernel_idx))
            self._stats("batch_rows", info["rows"])
            self._stats("batch_rows_padded", info["rows_padded"])
            self._stats("batch_tiles_visited", info["tiles_visited"])
            self._stats("batch_tiles_skipped", info["tiles_skipped"])
            self._observe_occupancy(
                float(info["tiles_visited"]) * geom[0] * geom[1],
                float(sum(nq * nr for nq, nr, _ in shapes)),
            )
            if tr is not None:
                tr.event(
                    "kernel_launch",
                    kind="kernel",
                    backend=backend,
                    segments=len(kernel_idx),
                    geometry=f"{geom[0]}x{geom[1]}",
                    launches=info["launches"],
                    rows=info["rows"],
                    duration=time.perf_counter() - t0,
                )
        done = set(kernel_idx)
        rest = [k for k in range(len(items)) if k not in done]
        if not rest:
            return
        t0 = time.perf_counter()
        rows = sum(items[k][2].shape[0] + items[k][5].shape[0] for k in rest)
        pairs: dict[int, tuple[np.ndarray, np.ndarray]] = {}

        # The twin evaluates each segment independently — exactly the
        # block-diagonal schedule — so meter its tile bill (in units of the
        # default kernel geometry, for comparability) against the
        # cross-product launch it avoids.
        bq, br = DEFAULT_GEOMETRY
        seg_qb = [-(-items[k][2].shape[0] // bq) for k in rest]
        seg_rb = [-(-items[k][5].shape[0] // br) for k in rest]
        visited = sum(q * r for q, r in zip(seg_qb, seg_rb))
        skipped = max(0, sum(seg_qb) * sum(seg_rb) - visited)

        # twin launch geometry (mask cells per row block): cached per
        # frontier-shape bucket; an unseen bucket with a big enough lead
        # segment measures the candidates on that segment and keeps the
        # winner's pairs
        block_cells = DEFAULT_TWIN_CELLS[0]
        twin_shapes = [
            (items[k][2].shape[0], items[k][5].shape[0], items[k][2].shape[1])
            for k in rest
        ]
        twin_bucket = shape_bucket(twin_shapes)
        twin_geom = self._tuner.lookup("np", twin_bucket)
        if twin_geom is None:
            k_big = max(
                rest, key=lambda k: items[k][2].shape[0] * items[k][5].shape[0]
            )
            big_cells = items[k_big][2].shape[0] * items[k_big][5].shape[0]
            if big_cells >= _TWIN_TUNE_MIN_CELLS:
                _i, req, u_lo, u_hi, _inv, _r_lo, _r_hi = items[k_big]
                rl_b, rh_b = req.table.dense_join_cols(
                    "value" if req.inverse else "key"
                )
                twin_geom, res = self._tuner.pick(
                    "np",
                    twin_bucket,
                    runner=lambda g: _twin_pairs(
                        u_lo, u_hi, rl_b, rh_b, None, block_cells=g[0]
                    ),
                    candidates=CANDIDATE_TWIN_CELLS,
                    default=DEFAULT_TWIN_CELLS,
                    warmup=False,  # pure numpy: nothing to compile
                )
                if self._metrics is not None:
                    self._metrics.inc(
                        "autotune_decisions",
                        backend="np",
                        bucket=str(twin_bucket),
                    )
                if res is not None:
                    pairs[k_big] = res
            else:
                twin_geom = DEFAULT_TWIN_CELLS
        block_cells = twin_geom[0]
        self._last_geometry["np"] = tuple(twin_geom)
        todo = [k for k in rest if k not in pairs]

        def eval_segments(chunk: list[int]) -> None:
            scratch: dict = {}  # mask buffers shared within the chunk
            for k in chunk:
                _i, req, u_lo, u_hi, _inv, _r_lo, _r_hi = items[k]
                rl, rh = req.table.dense_join_cols(
                    "value" if req.inverse else "key"
                )
                pairs[k] = _twin_pairs(
                    u_lo, u_hi, rl, rh, scratch, block_cells=block_cells
                )

        # clamp fan-out to real cores: the chunks only overlap while they
        # hold no GIL, and oversubscribing 2 cores with 4 GIL-trading
        # threads costs more in hand-offs than it buys
        width = min(workers or 1, len(todo), os.cpu_count() or 1)
        if width > 1:
            # fan only the *mask evaluations* out — the twin's blocked
            # passes are almost pure released-GIL numpy, so they overlap on
            # real cores, while finalize (intersect/de-relativize/scatter:
            # many small Python-held steps that would thrash the GIL across
            # threads) stays on the calling thread.  Chunks are balanced by
            # mask size, largest-first onto the lightest chunk; the calling
            # thread chews chunk 0 instead of idling.  Each pair list lands
            # in its own slot, so any worker count is bit-identical.
            chunks: list[list[int]] = [[] for _ in range(width)]
            loads = [0] * width
            for k in sorted(
                todo,
                key=lambda k: -items[k][2].shape[0] * items[k][5].shape[0],
            ):
                w = loads.index(min(loads))
                chunks[w].append(k)
                loads[w] += items[k][2].shape[0] * items[k][5].shape[0]
            futs = [
                self._workers(width - 1).submit(eval_segments, c)
                for c in chunks[1:]
            ]
            eval_segments(chunks[0])
            for f in futs:
                f.result()
        else:
            eval_segments(todo)
        for k in rest:
            finalize(k, *pairs[k])
        # the twin is one fused dispatch per frontier: count it like a
        # launch so CPU runs meter batching the same way TPU runs do
        self._stats("kernel_launches", 1)
        self._stats("joins_packed", len(rest))
        self._stats("batch_rows", rows)
        self._stats("batch_rows_padded", rows)
        self._stats("batch_tiles_visited", visited)
        self._stats("batch_tiles_skipped", skipped)
        # per-segment evaluation has no tile padding: cells-exact occupancy
        useful = float(sum(nq * nr for nq, nr, _ in twin_shapes))
        self._observe_occupancy(useful, useful)
        if tr is not None:
            tr.event(
                "twin",
                kind="kernel",
                backend="np",
                segments=len(rest),
                rows=rows,
                block_cells=block_cells,
                workers=width,
                duration=time.perf_counter() - t0,
            )


# --------------------------------------------------------------------------- #
# Row reduction between hops (paper §V.B.3)
# --------------------------------------------------------------------------- #
def merge_boxes(q: QueryBox) -> QueryBox:
    """Dedup + merge boxes that are adjacent/overlapping on one axis.

    Same machinery as one multi-attribute range-encoding pass per axis,
    iterated to fixpoint.
    """
    lo, hi = q.lo, q.hi
    if lo.shape[0] <= 1:
        return q
    # exact duplicate removal first
    both = np.concatenate([lo, hi], axis=1)
    both = _unique_rows(both)
    nd = len(q.shape)
    lo, hi = both[:, :nd], both[:, nd:]
    changed = True
    while changed and lo.shape[0] > 1:
        changed = False
        for d in range(nd):
            others = []
            for k in range(nd):
                if k != d:
                    others += [lo[:, k], hi[:, k]]
            order = lexsort_rows(others + [lo[:, d]])
            group = _group_ids([c[order] for c in others], lo.shape[0])
            starts, mlo, mhi = coalesce_1d(group, lo[order, d], hi[order, d])
            if starts.size != lo.shape[0]:
                sel = order[starts]
                lo, hi = lo[sel].copy(), hi[sel].copy()
                lo[:, d], hi[:, d] = mlo, mhi
                changed = True
    return QueryBox(q.shape, lo, hi)


def canonical_boxes(q: QueryBox) -> QueryBox:
    """Canonical decomposition: a function of the *cell set* alone.

    ``merge_boxes`` reaches a fixpoint but the fixpoint depends on the
    input decomposition, so two plans covering the same cells (per-hop
    chain vs a composed view, unsharded vs sharded) can return different —
    equally valid — box lists.  This computes the axis-ordered slab
    decomposition instead: cut axis 0 wherever the canonical
    (d-1)-dimensional cross-section changes, recurse, then merge adjacent
    slabs with identical cross-sections.  Boundaries survive only where
    the cross-section actually changes, which is intrinsic to the cell
    set, so every decomposition of the same cells maps to identical
    bytes.  Used as the final normal form on merged query answers.
    """
    if q.lo.shape[0] <= 1:
        return q
    nd = len(q.shape)
    if nd == 0:
        return QueryBox(q.shape, q.lo[:1], q.hi[:1])

    def merge_1d(lo: np.ndarray, hi: np.ndarray):
        order = np.argsort(lo[:, 0], kind="stable")
        l, h = lo[order, 0], hi[order, 0]
        out_l, out_h = [], []
        cl, ch = l[0], h[0]
        for i in range(1, l.size):
            if l[i] <= ch + 1:
                ch = max(ch, h[i])
            else:
                out_l.append(cl)
                out_h.append(ch)
                cl, ch = l[i], h[i]
        out_l.append(cl)
        out_h.append(ch)
        return (
            np.asarray(out_l, np.int64)[:, None],
            np.asarray(out_h, np.int64)[:, None],
        )

    def rec(lo: np.ndarray, hi: np.ndarray):
        if lo.shape[1] == 1:
            return merge_1d(lo, hi)
        cuts = np.unique(np.concatenate([lo[:, 0], hi[:, 0] + 1]))
        memo: dict[tuple, tuple] = {}
        slabs = []  # (start, end_exclusive, cross-section key, sub lo/hi)
        for a, b in zip(cuts[:-1], cuts[1:]):
            active = np.nonzero((lo[:, 0] <= a) & (hi[:, 0] >= a))[0]
            if active.size == 0:
                slabs.append((a, b, None, None, None))
                continue
            mk = tuple(active.tolist())
            if mk not in memo:
                sl, sh = rec(lo[active, 1:], hi[active, 1:])
                memo[mk] = (sl.tobytes() + b"|" + sh.tobytes(), sl, sh)
            slabs.append((a, b) + memo[mk])
        out_lo, out_hi = [], []
        i = 0
        while i < len(slabs):
            a, b, key, sl, sh = slabs[i]
            if key is None:  # gap: no cells in this slab
                i += 1
                continue
            j = i + 1
            while j < len(slabs) and slabs[j][2] == key:
                b = slabs[j][1]
                j += 1
            m = sl.shape[0]
            out_lo.append(
                np.concatenate([np.full((m, 1), a, np.int64), sl], axis=1)
            )
            out_hi.append(
                np.concatenate([np.full((m, 1), b - 1, np.int64), sh], axis=1)
            )
            i = j
        return np.concatenate(out_lo), np.concatenate(out_hi)

    lo, hi = rec(np.asarray(q.lo, np.int64), np.asarray(q.hi, np.int64))
    return QueryBox(q.shape, lo, hi)


# --------------------------------------------------------------------------- #
# Multi-hop planner
# --------------------------------------------------------------------------- #
def query_path(
    q: QueryBox,
    hops: list[tuple[CompressedTable, bool]],
    merge: bool = True,
    path: str = "auto",
) -> QueryBox:
    """Left-to-right plan over ``(table, inverse)`` hops (paper §V.B.3).

    ``inverse=False`` means the query side matches the table's keys
    (the natural direction for that materialization); ``inverse=True``
    uses ``theta_join_inverse``.

    Each hop's interval index is cached on its table, so a multi-hop plan
    (and any later plan revisiting the same tables) pays the index build at
    most once per table, not once per hop execution.
    """
    # Q' is encoded in the same compressed format as the tables (§V.B):
    # merging the query cells into boxes up front is what keeps the first
    # range join proportional to |boxes|, not |cells|.
    cur = merge_boxes(q) if merge else q
    for table, inverse in hops:
        cur = (
            theta_join_inverse(cur, table, merge=merge, path=path)
            if inverse
            else theta_join(cur, table, merge=merge, path=path)
        )
    return cur
