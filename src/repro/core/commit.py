"""Group commit and writer leases for the write-ahead lineage log.

Two pieces sit between the catalog and :mod:`~repro.core.wal`:

* :class:`CommitPipeline` — batches WAL durability.  Appends are buffered
  writes; the pipeline decides *when* the expensive ``fsync`` happens:

  - ``"sync"``     — every record is fsynced immediately (the per-entry
    synchronous baseline of the ingest ablation),
  - ``"group"``    — records accumulate and one fsync covers the whole
    batch, fired when ``max_batch`` records are pending or ``flush_interval``
    seconds elapse (a lazily started background flusher), whichever first,
  - ``"manual"``   — durability only at explicit :meth:`commit` /
    checkpoint (useful for tests and bulk loads).

  ``commit()`` is the durability barrier: it returns once every record
  appended so far is on disk.

* :class:`WriterLease` — one-writer-per-directory mutual exclusion via an
  atomically created lock file recording ``{pid, host, uuid}``.  A second
  acquire raises :class:`LeaseHeldError` while the holder is alive and
  steals the lease when the holding process is gone (crashed writers never
  wedge the store).  The sharded store hands out one lease per shard plus a
  root lock, so one writer *per shard* can ingest concurrently.

Leases are same-host advisory locks (pid liveness + lock-file atomicity),
matching the repo's single-node store layout; a multi-node deployment would
swap this class for a distributed lock without touching the catalog.
"""

from __future__ import annotations

import json
import os
import socket
import threading
import time
import uuid

from repro.obs.metrics import MetricsRegistry, StatsView

from . import _locks
from .wal import WriteAheadLog

__all__ = ["CommitPipeline", "WriterLease", "LeaseHeldError"]


class LeaseHeldError(RuntimeError):
    """Another live writer holds the lease (double-open is an error)."""


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:  # pragma: no cover - exists, owned by another uid
        return True
    return True


class WriterLease:
    """Exclusive writer lock over one store (or shard) directory.

    The lock file is created with ``O_CREAT | O_EXCL`` (atomic on POSIX);
    its JSON body names the holder.  Staleness: a same-host lease whose pid
    is dead is stolen; a different-host lease falls back to ``ttl`` seconds
    since the last :meth:`refresh` (mtime).
    """

    FILENAME = "writer.lock"

    def __init__(self, path: str, owner: dict, token: str):
        self.path = path
        self.owner = owner
        self.token = token
        self._released = False

    # ------------------------------------------------------------------ #
    @classmethod
    def acquire(
        cls, directory: str, ttl: float = 300.0, what: str = "store"
    ) -> "WriterLease":
        """Take the directory's writer lease or raise :class:`LeaseHeldError`."""
        os.makedirs(directory, exist_ok=True)
        path = os.path.join(directory, cls.FILENAME)
        token = uuid.uuid4().hex
        owner = {"pid": os.getpid(), "host": socket.gethostname(), "token": token}
        body = json.dumps(owner).encode()
        for _ in range(2):  # second pass after stealing a stale lease
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                holder = cls._read_holder(path)
                if holder is not None and not cls._is_stale(path, holder, ttl):
                    raise LeaseHeldError(
                        f"{what} {directory!r} already has a live writer "
                        f"(pid {holder.get('pid')} on {holder.get('host')}); "
                        f"close it before opening another"
                    )
                # Stale (crashed writer / unreadable file): steal by atomic
                # rename to a name only we know — two concurrent stealers
                # cannot both succeed, and neither can delete a lease a
                # third process just acquired (plain remove would).
                grave = f"{path}.stale.{token}"
                try:
                    os.rename(path, grave)
                    os.remove(grave)
                except FileNotFoundError:
                    pass  # another stealer won the rename; retry the create
                continue
            with os.fdopen(fd, "wb") as f:
                f.write(body)
            return cls(path, owner, token)
        raise LeaseHeldError(f"could not acquire writer lease in {directory!r}")

    @staticmethod
    def holder(directory: str) -> dict | None:
        """The recorded holder of a directory's lease file, or None."""
        return WriterLease._read_holder(
            os.path.join(directory, WriterLease.FILENAME)
        )

    @classmethod
    def held(cls, directory: str, ttl: float = 300.0) -> bool:
        """Whether a *live* writer currently holds the directory's lease."""
        path = os.path.join(directory, cls.FILENAME)
        holder = cls._read_holder(path)
        return holder is not None and not cls._is_stale(path, holder, ttl)

    @staticmethod
    def _read_holder(path: str) -> dict | None:
        try:
            with open(path) as f:
                return json.load(f)
        except FileNotFoundError:
            return None
        except (json.JSONDecodeError, OSError):
            return {}  # unreadable body: decided by staleness below

    @staticmethod
    def _is_stale(path: str, holder: dict, ttl: float) -> bool:
        if holder.get("host") == socket.gethostname() and "pid" in holder:
            return not _pid_alive(int(holder["pid"]))
        try:
            return time.time() - os.path.getmtime(path) > ttl
        except OSError:
            return True

    # ------------------------------------------------------------------ #
    def refresh(self) -> None:
        """Bump the lease mtime (cross-host ttl keep-alive)."""
        try:
            os.utime(self.path)
        except OSError:  # pragma: no cover - lease dir vanished
            pass

    def release(self) -> None:
        """Drop the lease if we still hold it (idempotent)."""
        if self._released:
            return
        self._released = True
        holder = self._read_holder(self.path)
        if holder and holder.get("token") == self.token:
            try:
                os.remove(self.path)
            except FileNotFoundError:
                pass

    def __enter__(self) -> "WriterLease":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class CommitPipeline:
    """Schedules WAL fsyncs: per-record, per-batch (group commit), or manual.

    One pipeline serves every log of one store (the root log plus any shard
    logs): a single flush pass makes all of them durable together, so a
    batch spanning shards costs one fsync per *touched* log, not per
    record.  The background flusher thread starts lazily on the first
    grouped append and stops at :meth:`close`.
    """

    def __init__(
        self,
        mode: str = "group",
        flush_interval: float = 0.005,
        max_batch: int = 256,
        metrics=None,
    ):
        if mode not in ("sync", "group", "manual"):
            raise ValueError(f"unknown durability mode {mode!r}")
        self.mode = mode
        self.flush_interval = float(flush_interval)
        self.max_batch = int(max_batch)
        self._wals: list[WriteAheadLog] = []
        self._dirty: set[int] = set()  # indexes into _wals with pending bytes
        self._pending = 0
        self._lock = _locks.new_lock("commit._lock")
        # serializes whole flush passes: commit() must wait out a flush the
        # background thread already snapshotted (its fsync may still be in
        # flight after _dirty was cleared) before honoring the barrier
        self._flush_mutex = _locks.new_lock("commit._flush_mutex")
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.metrics = None
        self.stats = None
        self.bind_metrics(metrics or MetricsRegistry("commit"))

    # ------------------------------------------------------------------ #
    def bind_metrics(self, registry) -> None:
        """(Re)target the pipeline's instruments at ``registry``.

        Both ``open()`` paths build the pipeline before the store object
        exists, so the store registry is bound post-hoc; counts recorded
        under the interim private registry carry over.
        """
        registry.seed_counters(
            ("commit_records", "commit_group_flushes", "commit_synced_records")
        )
        if self.metrics is not None and self.metrics is not registry:
            for key, val in self.metrics.counters_flat().items():
                if val:
                    registry.inc(key, val)
        self.metrics = registry
        self.stats = StatsView(
            registry,
            {
                "records": "commit_records",
                "group_flushes": "commit_group_flushes",
                "synced_records": "commit_synced_records",
            },
        )

    def attach(self, wal: WriteAheadLog) -> WriteAheadLog:
        with self._lock:
            if wal not in self._wals:
                self._wals.append(wal)
        return wal

    def notify(self, wal: WriteAheadLog) -> None:
        """One record was appended to ``wal``; schedule its durability."""
        with self._lock:
            if wal not in self._wals:
                self._wals.append(wal)
            self._dirty.add(self._wals.index(wal))
            self._pending += 1
            pending = self._pending
        self.metrics.inc("commit_records")
        if self.mode == "sync":
            self._flush_dirty()
        elif self.mode == "group":
            if pending >= self.max_batch:
                self._flush_dirty()
            else:
                self._ensure_thread()
                self._wake.set()

    def commit(self) -> None:
        """Durability barrier: every appended record is on disk on return."""
        self._flush_dirty(force=True)

    # ------------------------------------------------------------------ #
    def _flush_dirty(self, force: bool = False) -> None:
        # every append reaches us through notify(), so _dirty names exactly
        # the logs with unsynced records — the barrier never has to fsync a
        # clean log (force only means "flush even a below-batch remainder").
        # _flush_mutex makes the pass atomic from a barrier's perspective:
        # a commit() arriving while the background flusher is mid-fsync
        # (dirty set already cleared) blocks here until that fsync lands.
        with self._flush_mutex:
            with self._lock:
                if not self._dirty and not force:
                    return
                targets = [self._wals[i] for i in sorted(self._dirty)]
                flushed = self._pending
                self._dirty.clear()
                self._pending = 0
            for wal in targets:
                t0 = time.perf_counter()
                wal.flush(sync=True)
                # group-commit visibility latency: one sample per touched
                # log per pass (the WAL itself meters the raw fsync)
                self.metrics.observe(
                    "commit_flush_seconds", time.perf_counter() - t0
                )
            if flushed:
                self.metrics.inc("commit_group_flushes")
                self.metrics.inc("commit_synced_records", flushed)
                self.metrics.observe("commit_batch_records", float(flushed))

    def _ensure_thread(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._run, name="dslog-group-commit", daemon=True
            )
            self._thread.start()

    def _run(self) -> None:
        while not self._stop.is_set():
            self._wake.wait()
            if self._stop.is_set():
                return
            self._wake.clear()
            # collect a batch window, then flush whatever accumulated
            self._stop.wait(self.flush_interval)
            self._flush_dirty()

    def close(self) -> None:
        """Flush everything and stop the flusher (idempotent)."""
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
        self._flush_dirty(force=True)

    def __repr__(self) -> str:
        return (
            f"CommitPipeline(mode={self.mode!r}, "
            f"interval={self.flush_interval}, max_batch={self.max_batch}, "
            f"records={self.stats['records']})"
        )
