"""Compressed lineage table produced by ProvRC (paper §IV).

Layout
------
A table stores ``N`` compressed rows over ``l`` *key* attributes and ``m``
*value* attributes.  For the canonical **backward** materialization the keys
are the output-array axes and the values the input-array axes; the
**forward** materialization swaps the roles (paper §IV.C — "a version where
output attributes can have relative indices, but input attributes are
absolute").  The query engine only ever sees (key, value) so one θ-join
implementation serves both directions.

Per row:

* ``key_lo/key_hi``  — absolute closed intervals, one per key attribute.
* ``val_lo/val_hi``  — closed intervals, one per value attribute.
* ``val_ref``        — ``-1`` ⇒ the value interval is absolute;
  ``j >= 0`` ⇒ it is a *delta* relative to key attribute ``j``
  (stored value = ``val − key_j``, so de-relativization is pure addition —
  see DESIGN.md for why we flip the paper's ``b−a`` sign convention).
* ``key_sym/val_sym`` — ``-1`` or the axis id whose *full extent* this
  interval spans; used by index reshaping for ``gen_sig`` reuse (paper §VI.B).

Row semantics (the all-to-all insight of §V.B): a row denotes the set

    { (k, v) :  k ∈ ∏_j [key_lo_j, key_hi_j],
                v_i ∈ [val_lo_i, val_hi_i]                  if ref_i == -1
                v_i − k_{ref_i} ∈ [val_lo_i, val_hi_i]      otherwise }
"""

from __future__ import annotations

import io
import json
import zlib
from dataclasses import dataclass, field, replace

import numpy as np

from typing import Callable

from . import _locks

from .index import IntervalIndex, interval_stats
from .relation import LineageRelation

__all__ = ["CompressedTable", "TableHandle"]

_MAGIC = b"PRVC1\n"

# Reassigning any of these drops the cached interval indexes (see
# ``CompressedTable.__setattr__``); for *in-place* ndarray mutation call
# ``invalidate_index()`` explicitly.
_ARRAY_FIELDS = frozenset(
    {"key_lo", "key_hi", "val_lo", "val_hi", "val_ref", "key_sym", "val_sym"}
)


def _pack_array(a: np.ndarray) -> np.ndarray:
    """Downcast to the narrowest signed integer dtype that holds the data."""
    if a.size == 0:
        return a.astype(np.int8)
    lo, hi = int(a.min()), int(a.max())
    for dt in (np.int8, np.int16, np.int32):
        info = np.iinfo(dt)
        if info.min <= lo and hi <= info.max:
            return a.astype(dt)
    return a.astype(np.int64)


@dataclass
class CompressedTable:
    key_shape: tuple[int, ...]
    val_shape: tuple[int, ...]
    key_lo: np.ndarray = field(repr=False)
    key_hi: np.ndarray = field(repr=False)
    val_lo: np.ndarray = field(repr=False)
    val_hi: np.ndarray = field(repr=False)
    val_ref: np.ndarray = field(repr=False)
    direction: str = "backward"  # keys are op outputs (backward) or inputs
    key_sym: np.ndarray | None = field(default=None, repr=False)
    val_sym: np.ndarray | None = field(default=None, repr=False)

    def __post_init__(self) -> None:
        l, m = len(self.key_shape), len(self.val_shape)
        self.key_lo = np.asarray(self.key_lo, np.int64).reshape(-1, l)
        self.key_hi = np.asarray(self.key_hi, np.int64).reshape(-1, l)
        self.val_lo = np.asarray(self.val_lo, np.int64).reshape(-1, m)
        self.val_hi = np.asarray(self.val_hi, np.int64).reshape(-1, m)
        self.val_ref = np.asarray(self.val_ref, np.int8).reshape(-1, m)
        if self.key_sym is None:
            self.key_sym = np.full((self.n_rows, l), -1, np.int8)
        if self.val_sym is None:
            self.val_sym = np.full((self.n_rows, m), -1, np.int8)
        if self.direction not in ("backward", "forward"):
            raise ValueError(f"bad direction {self.direction!r}")

    def __setattr__(self, name: str, value) -> None:
        if name in _ARRAY_FIELDS:
            self.__dict__.pop("_index_cache", None)
        object.__setattr__(self, name, value)

    # ------------------------------------------------------------------ #
    @property
    def n_rows(self) -> int:
        return int(self.key_lo.shape[0])

    @property
    def n_key(self) -> int:
        return len(self.key_shape)

    @property
    def n_val(self) -> int:
        return len(self.val_shape)

    @property
    def is_symbolic(self) -> bool:
        assert self.key_sym is not None and self.val_sym is not None
        return bool((self.key_sym >= 0).any() or (self.val_sym >= 0).any())

    def select(self, rows: np.ndarray) -> "CompressedTable":
        assert self.key_sym is not None and self.val_sym is not None
        return replace(
            self,
            key_lo=self.key_lo[rows],
            key_hi=self.key_hi[rows],
            val_lo=self.val_lo[rows],
            val_hi=self.val_hi[rows],
            val_ref=self.val_ref[rows],
            key_sym=self.key_sym[rows],
            val_sym=self.val_sym[rows],
        )

    # --------------------------- indexing ----------------------------- #
    def _cache(self) -> dict:
        return self.__dict__.setdefault("_index_cache", {})

    def key_index(self) -> IntervalIndex:
        """Cached interval index over the key-side intervals (lazily built)."""
        cache = self._cache()
        idx = cache.get("key")
        if idx is None:
            idx = IntervalIndex(self.key_lo, self.key_hi)
            cache["key"] = idx
        return idx

    def value_bounds(self) -> tuple[np.ndarray, np.ndarray]:
        """Achievable absolute bounds of each value attribute, per row.

        Absolute attrs keep their stored interval; an attr relative to key
        ``j`` can reach ``[key_lo_j + dlo, key_hi_j + dhi]``.  These bounds
        turn the inverse join's candidate test into a plain range join.
        """
        cache = self._cache()
        vb = cache.get("vbounds")
        if vb is None:
            vb_lo = self.val_lo.astype(np.int64)
            vb_hi = self.val_hi.astype(np.int64)
            for j in range(self.n_key):
                sel = self.val_ref == j  # [N, m]
                if sel.any():
                    vb_lo[sel] += np.broadcast_to(
                        self.key_lo[:, j : j + 1], sel.shape
                    )[sel]
                    vb_hi[sel] += np.broadcast_to(
                        self.key_hi[:, j : j + 1], sel.shape
                    )[sel]
            vb = (vb_lo, vb_hi)
            cache["vbounds"] = vb
        return vb

    def val_index(self) -> IntervalIndex:
        """Cached interval index over the achievable value bounds."""
        cache = self._cache()
        idx = cache.get("val")
        if idx is None:
            idx = IntervalIndex(*self.value_bounds())
            cache["val"] = idx
        return idx

    def key_stats(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-key-attribute ``(mean interval length, span)``, cached.

        Fed to the planner's closed-form cost model; invalidated together
        with the interval indexes when the interval columns change.
        """
        cache = self._cache()
        st = cache.get("key_stats")
        if st is None:
            st = interval_stats(self.key_lo, self.key_hi)
            cache["key_stats"] = st
        return st

    def val_stats(self) -> tuple[np.ndarray, np.ndarray]:
        """Like :meth:`key_stats` over the achievable value bounds."""
        cache = self._cache()
        st = cache.get("val_stats")
        if st is None:
            st = interval_stats(*self.value_bounds())
            cache["val_stats"] = st
        return st

    def int32_safe(self, side: str) -> bool:
        """Whether one join side's bounds survive an int32 pack, cached.

        ``side`` is ``"key"`` (stored key intervals) or ``"value"``
        (achievable value bounds).  Gates the accelerator kernel pack and
        the int32 fast path of the blocked dense twin: out-of-range
        coordinates must take the int64 numpy route or they would silently
        wrap (the overflow bug this check exists to prevent).
        """
        cache = self._cache()
        k = f"i32_{side}"
        v = cache.get(k)
        if v is None:
            lo, hi = (
                (self.key_lo, self.key_hi)
                if side == "key"
                else self.value_bounds()
            )
            info = np.iinfo(np.int32)
            v = bool(
                lo.size == 0
                or (lo.min() >= info.min and hi.max() <= info.max)
            )
            cache[k] = v
        return v

    def dense_join_cols(self, side: str) -> tuple[np.ndarray, np.ndarray]:
        """Contiguous transposed ``[l, N]`` (lo, hi) columns for the dense
        join, downcast to int32 when :meth:`int32_safe` — cached, and
        invalidated together with the indexes on mutation.

        The blocked dense evaluation broadcasts one attribute column at a
        time; the stored ``[N, l]`` layout makes those columns strided,
        which dominates the mask cost.  One cached transpose amortizes the
        fix across every hop and every query touching the table.
        """
        cache = self._cache()
        k = f"dense_{side}"
        cols = cache.get(k)
        if cols is None:
            lo, hi = (
                (self.key_lo, self.key_hi)
                if side == "key"
                else self.value_bounds()
            )
            dt = np.int32 if self.int32_safe(side) else np.int64
            cols = (
                np.ascontiguousarray(lo.T, dtype=dt),
                np.ascontiguousarray(hi.T, dtype=dt),
            )
            cache[k] = cols
        return cols

    def cached_key_index(self) -> IntervalIndex | None:
        """The key index if one is already built/attached, without building."""
        return self._cache().get("key")

    def cached_val_index(self) -> IntervalIndex | None:
        """The value-bounds index if already built, without building."""
        return self._cache().get("val")

    def invalidate_index(self) -> None:
        """Drop cached indexes.  Reassigning an array field does this
        automatically; call this after mutating an array *in place*."""
        self.__dict__.pop("_index_cache", None)

    def attach_key_index(self, index: IntervalIndex) -> None:
        """Install a prebuilt/persisted key index (catalog reload path)."""
        if index.lo.shape != self.key_lo.shape:
            raise ValueError(
                f"index over {index.lo.shape} cannot serve table "
                f"{self.key_lo.shape}"
            )
        self._cache()["key"] = index

    # ---------------------------- size ------------------------------- #
    def nbytes(self) -> int:
        """In-memory packed size (what we report as the ProvRC storage cost)."""
        return len(self.serialize(compress=False))

    def nbytes_gzip(self) -> int:
        """ProvRC-GZip size (paper: zlib over the serialized table)."""
        return len(self.serialize(compress=True))

    # ------------------------- serialization ------------------------- #
    def serialize(self, compress: bool = False) -> bytes:
        header = {
            "key_shape": list(self.key_shape),
            "val_shape": list(self.val_shape),
            "direction": self.direction,
            "n_rows": self.n_rows,
        }
        buf = io.BytesIO()
        arrays = [
            _pack_array(self.key_lo),
            _pack_array(self.key_hi),
            _pack_array(self.val_lo),
            _pack_array(self.val_hi),
            self.val_ref,
            self.key_sym,
            self.val_sym,
        ]
        header["dtypes"] = [a.dtype.str for a in arrays]
        hdr = json.dumps(header).encode()
        buf.write(_MAGIC)
        buf.write(len(hdr).to_bytes(4, "little"))
        buf.write(hdr)
        for a in arrays:
            buf.write(np.ascontiguousarray(a).tobytes())
        payload = buf.getvalue()
        if compress:
            payload = _MAGIC + b"Z" + zlib.compress(payload, level=6)
        return payload

    @staticmethod
    def deserialize(data: bytes) -> "CompressedTable":
        if data[: len(_MAGIC) + 1] == _MAGIC + b"Z":
            data = zlib.decompress(data[len(_MAGIC) + 1 :])
        if data[: len(_MAGIC)] != _MAGIC:
            raise ValueError("not a ProvRC table blob")
        off = len(_MAGIC)
        hlen = int.from_bytes(data[off : off + 4], "little")
        off += 4
        header = json.loads(data[off : off + hlen])
        off += hlen
        key_shape = tuple(header["key_shape"])
        val_shape = tuple(header["val_shape"])
        n, l, m = header["n_rows"], len(key_shape), len(val_shape)
        shapes = [(n, l), (n, l), (n, m), (n, m), (n, m), (n, l), (n, m)]
        arrays = []
        for dt_str, shp in zip(header["dtypes"], shapes):
            dt = np.dtype(dt_str)
            cnt = shp[0] * shp[1]
            a = np.frombuffer(data, dtype=dt, count=cnt, offset=off).reshape(shp)
            off += cnt * dt.itemsize
            arrays.append(a.astype(np.int64) if a.dtype != np.int8 else a.copy())
        kl, kh, vl, vh, ref, ks, vs = arrays
        return CompressedTable(
            key_shape,
            val_shape,
            kl.astype(np.int64),
            kh.astype(np.int64),
            vl.astype(np.int64),
            vh.astype(np.int64),
            ref,
            header["direction"],
            ks.astype(np.int8),
            vs.astype(np.int8),
        )

    # -------------------------- decompression ------------------------ #
    def decompress(self) -> LineageRelation:
        """Expand back to the uncompressed relation (losslessness check).

        Only intended for testing / small tables — production queries never
        call this (that is the whole point of in-situ processing).
        """
        if self.is_symbolic:
            raise ValueError("instantiate symbolic table before decompressing")
        out_rows: list[np.ndarray] = []
        in_rows: list[np.ndarray] = []
        l, m = self.n_key, self.n_val
        for r in range(self.n_rows):
            key_ranges = [
                np.arange(self.key_lo[r, j], self.key_hi[r, j] + 1) for j in range(l)
            ]
            key_grid = np.stack(
                [g.ravel() for g in np.meshgrid(*key_ranges, indexing="ij")], axis=1
            ) if l else np.zeros((1, 0), np.int64)
            # Per key tuple, values are a product of (possibly shifted) ranges.
            val_ranges_static = []
            for i in range(m):
                val_ranges_static.append(
                    np.arange(self.val_lo[r, i], self.val_hi[r, i] + 1)
                )
            for k_row in key_grid:
                vranges = []
                for i in range(m):
                    ref = int(self.val_ref[r, i])
                    base = 0 if ref < 0 else int(k_row[ref])
                    vranges.append(val_ranges_static[i] + base)
                vgrid = np.stack(
                    [g.ravel() for g in np.meshgrid(*vranges, indexing="ij")], axis=1
                ) if m else np.zeros((1, 0), np.int64)
                out_rows.append(np.broadcast_to(k_row, (vgrid.shape[0], l)).copy())
                in_rows.append(vgrid)
        if not out_rows:
            out = np.zeros((0, l), np.int64)
            inn = np.zeros((0, m), np.int64)
        else:
            out = np.concatenate(out_rows, axis=0)
            inn = np.concatenate(in_rows, axis=0)
        if self.direction == "backward":
            rel = LineageRelation(self.key_shape, self.val_shape, out, inn)
        else:  # forward: keys are the *input* axes
            rel = LineageRelation(self.val_shape, self.key_shape, inn, out)
        return rel.canonical()


class TableHandle:
    """Lazy handle to a persisted :class:`CompressedTable` blob.

    The catalog's manifest records row counts and blob file names; the blob
    itself stays on disk until something actually needs the intervals.
    ``get()`` resolves (and memoizes) the table via the supplied loader,
    firing ``on_load`` exactly once — the catalog uses that callback for its
    lazy-I/O counters, and tests assert on them to prove a reload touched
    only the tables a query needed.

    ``n_rows`` may be ``None`` for pre-v2 manifests that did not record row
    counts; reading :attr:`rows` then forces the load.
    """

    __slots__ = ("_loader", "_table", "_on_load", "_lock", "n_rows")

    def __init__(
        self,
        loader: "Callable[[], CompressedTable]",
        n_rows: int | None = None,
        on_load: "Callable[[], None] | None" = None,
    ):
        self._loader = loader
        self._table: CompressedTable | None = None
        self._on_load = on_load
        self._lock = _locks.new_lock("table._lock")
        self.n_rows = n_rows

    @property
    def loaded(self) -> bool:
        return self._table is not None

    @property
    def rows(self) -> int:
        """Row count without loading when the manifest recorded it."""
        if self.n_rows is not None:
            return int(self.n_rows)
        return self.get().n_rows

    def get(self) -> CompressedTable:
        if self._table is None:
            # parallel plan execution may race two threads onto one lazy
            # blob; the lock keeps the load (and its counter) single-fire
            with self._lock:
                if self._table is None:
                    table = self._loader()
                    self.n_rows = table.n_rows
                    if self._on_load is not None:
                        self._on_load()
                    self._table = table
        return self._table
