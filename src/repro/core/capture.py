"""Lineage capture adapters (paper §II.A, §VII.A).

DSLog is agnostic to capture methodology; this module supplies the three
families the paper evaluates, adapted to the JAX ecosystem:

1. **Symbolic captures** — for data-*independent* array ops (elementwise,
   reduce, matmul, conv, reshape, slice, …) the lineage is a pure function of
   shapes/op-args, so we generate the relation directly from the op spec.
   This is the JAX-native analog of the paper's ``tracked_cell`` taint
   tracking (jaxprs make op semantics explicit, no taint needed).
2. **Value-dependent captures** — sort/gather/group-by/inner-join lineage is
   computed from the actual values (the paper's custom tracking functions).
3. **Oracle capture** — jacobian-sparsity probing of an arbitrary jittable
   function; used as ground truth in property tests and for ops without a
   symbolic adapter (the role the paper's LIME/D-RISE captures play).

All generators are vectorized numpy — they routinely emit 10⁶+ row
relations for the compression benchmarks.
"""

from __future__ import annotations

import numpy as np

from .relation import LineageRelation

__all__ = [
    "all_indices",
    "identity_lineage",
    "broadcast_lineage",
    "reduce_lineage",
    "softmax_lineage",
    "matmul_lineage",
    "outer_lineage",
    "transpose_lineage",
    "reshape_lineage",
    "slice_lineage",
    "concat_lineage",
    "pad_lineage",
    "tile_lineage",
    "repeat_lineage",
    "roll_lineage",
    "flip_lineage",
    "take_lineage",
    "conv1d_lineage",
    "conv2d_lineage",
    "cumulative_lineage",
    "triangular_lineage",
    "sort_lineage",
    "group_by_lineage",
    "inner_join_lineage",
    "xai_bipartite_lineage",
    "capture_jacobian",
]


def all_indices(shape: tuple[int, ...]) -> np.ndarray:
    """All cell indices of an array, shape ``[prod(shape), ndim]``."""
    if not shape:
        return np.zeros((1, 0), np.int64)
    n = int(np.prod(shape))
    return np.stack(
        np.unravel_index(np.arange(n, dtype=np.int64), shape), axis=1
    )


# --------------------------------------------------------------------------- #
# Data-independent (symbolic) captures
# --------------------------------------------------------------------------- #
def identity_lineage(shape) -> LineageRelation:
    """Elementwise unary op: out[i] <- in[i]."""
    shape = tuple(shape)
    idx = all_indices(shape)
    return LineageRelation(shape, shape, idx, idx)


def broadcast_lineage(in_shape, out_shape) -> LineageRelation:
    """out[b] <- in[broadcast⁻¹(b)] with numpy right-aligned broadcasting."""
    in_shape, out_shape = tuple(in_shape), tuple(out_shape)
    out = all_indices(out_shape)
    nd_in, nd_out = len(in_shape), len(out_shape)
    cols = []
    for ax_in in range(nd_in):
        ax_out = ax_in + (nd_out - nd_in)
        c = out[:, ax_out]
        if in_shape[ax_in] == 1 and out_shape[ax_out] != 1:
            c = np.zeros_like(c)
        cols.append(c)
    inn = np.stack(cols, axis=1) if cols else np.zeros((out.shape[0], 0), np.int64)
    return LineageRelation(out_shape, in_shape, out, inn)


def reduce_lineage(in_shape, axes, keepdims: bool = False) -> LineageRelation:
    """sum/mean/max/… over ``axes``: every input cell feeds its slot."""
    in_shape = tuple(in_shape)
    axes = tuple(sorted(a % len(in_shape) for a in (axes if hasattr(axes, "__len__") else [axes])))
    inn = all_indices(in_shape)
    keep = [a for a in range(len(in_shape)) if a not in axes]
    if keepdims:
        out_shape = tuple(1 if a in axes else d for a, d in enumerate(in_shape))
        out = inn.copy()
        out[:, list(axes)] = 0
    else:
        out_shape = tuple(in_shape[a] for a in keep) or (1,)
        out = inn[:, keep] if keep else np.zeros((inn.shape[0], 1), np.int64)
    return LineageRelation(out_shape, in_shape, out, inn)


def softmax_lineage(shape, axis: int) -> LineageRelation:
    """out[.., i, ..] <- in[.., i', ..] for every i' along ``axis``."""
    shape = tuple(shape)
    axis = axis % len(shape)
    base = all_indices(shape)
    n_axis = shape[axis]
    out = np.repeat(base, n_axis, axis=0)
    inn = out.copy()
    inn[:, axis] = np.tile(np.arange(n_axis, dtype=np.int64), base.shape[0])
    return LineageRelation(shape, shape, out, inn)


def matmul_lineage(M: int, K: int, N: int) -> tuple[LineageRelation, LineageRelation]:
    """C = A @ B:  C[i,j] <- A[i,k] ∀k  and  C[i,j] <- B[k,j] ∀k."""
    grid = all_indices((M, N, K))
    i, j, k = grid[:, 0], grid[:, 1], grid[:, 2]
    out = np.stack([i, j], axis=1)
    rel_a = LineageRelation((M, N), (M, K), out, np.stack([i, k], axis=1))
    rel_b = LineageRelation((M, N), (K, N), out, np.stack([k, j], axis=1))
    return rel_a, rel_b


def outer_lineage(M: int, N: int) -> tuple[LineageRelation, LineageRelation]:
    grid = all_indices((M, N))
    rel_a = LineageRelation((M, N), (M,), grid, grid[:, :1])
    rel_b = LineageRelation((M, N), (N,), grid, grid[:, 1:])
    return rel_a, rel_b


def transpose_lineage(in_shape, perm) -> LineageRelation:
    in_shape = tuple(in_shape)
    perm = tuple(p % len(in_shape) for p in perm)
    out_shape = tuple(in_shape[p] for p in perm)
    out = all_indices(out_shape)
    inn = np.empty_like(out)
    for o_ax, i_ax in enumerate(perm):
        inn[:, i_ax] = out[:, o_ax]
    return LineageRelation(out_shape, in_shape, out, inn)


def reshape_lineage(in_shape, out_shape) -> LineageRelation:
    in_shape, out_shape = tuple(in_shape), tuple(out_shape)
    n = int(np.prod(in_shape))
    flat = np.arange(n, dtype=np.int64)
    out = np.stack(np.unravel_index(flat, out_shape), axis=1)
    inn = np.stack(np.unravel_index(flat, in_shape), axis=1)
    return LineageRelation(out_shape, in_shape, out, inn)


def slice_lineage(in_shape, starts, stops, steps=None) -> LineageRelation:
    in_shape = tuple(in_shape)
    nd = len(in_shape)
    steps = steps or (1,) * nd
    out_shape = tuple(
        max(0, (stop - start + step - 1) // step)
        for start, stop, step in zip(starts, stops, steps)
    )
    out = all_indices(out_shape)
    inn = out * np.array(steps, np.int64) + np.array(starts, np.int64)
    return LineageRelation(out_shape, in_shape, out, inn)


def concat_lineage(shapes, axis: int) -> list[LineageRelation]:
    shapes = [tuple(s) for s in shapes]
    axis = axis % len(shapes[0])
    total = sum(s[axis] for s in shapes)
    out_shape = list(shapes[0])
    out_shape[axis] = total
    out_shape = tuple(out_shape)
    rels, off = [], 0
    for s in shapes:
        inn = all_indices(s)
        out = inn.copy()
        out[:, axis] += off
        rels.append(LineageRelation(out_shape, s, out, inn))
        off += s[axis]
    return rels


def pad_lineage(in_shape, pad_width) -> LineageRelation:
    in_shape = tuple(in_shape)
    out_shape = tuple(
        d + lo + hi for d, (lo, hi) in zip(in_shape, pad_width)
    )
    inn = all_indices(in_shape)
    out = inn + np.array([lo for lo, _ in pad_width], np.int64)
    return LineageRelation(out_shape, in_shape, out, inn)


def tile_lineage(in_shape, reps) -> LineageRelation:
    in_shape = tuple(in_shape)
    reps = tuple(reps)
    out_shape = tuple(d * r for d, r in zip(in_shape, reps))
    out = all_indices(out_shape)
    inn = out % np.array(in_shape, np.int64)
    return LineageRelation(out_shape, in_shape, out, inn)


def repeat_lineage(in_shape, repeats: int, axis: int) -> LineageRelation:
    in_shape = tuple(in_shape)
    axis = axis % len(in_shape)
    out_shape = list(in_shape)
    out_shape[axis] *= repeats
    out_shape = tuple(out_shape)
    out = all_indices(out_shape)
    inn = out.copy()
    inn[:, axis] //= repeats
    return LineageRelation(out_shape, in_shape, out, inn)


def roll_lineage(in_shape, shift: int, axis: int) -> LineageRelation:
    in_shape = tuple(in_shape)
    axis = axis % len(in_shape)
    out = all_indices(in_shape)
    inn = out.copy()
    inn[:, axis] = (inn[:, axis] - shift) % in_shape[axis]
    return LineageRelation(in_shape, in_shape, out, inn)


def flip_lineage(in_shape, axis: int) -> LineageRelation:
    in_shape = tuple(in_shape)
    axis = axis % len(in_shape)
    out = all_indices(in_shape)
    inn = out.copy()
    inn[:, axis] = in_shape[axis] - 1 - inn[:, axis]
    return LineageRelation(in_shape, in_shape, out, inn)


def take_lineage(in_shape, indices: np.ndarray, axis: int) -> LineageRelation:
    """Value-dependent gather along ``axis``."""
    in_shape = tuple(in_shape)
    axis = axis % len(in_shape)
    indices = np.asarray(indices, np.int64).ravel()
    out_shape = list(in_shape)
    out_shape[axis] = indices.size
    out_shape = tuple(out_shape)
    out = all_indices(out_shape)
    inn = out.copy()
    inn[:, axis] = indices[out[:, axis]]
    return LineageRelation(out_shape, in_shape, out, inn)


def conv1d_lineage(n: int, k: int, stride: int = 1) -> LineageRelation:
    """Valid 1-D convolution: out[i] <- in[i·s + d], d ∈ [0, k-1]."""
    n_out = (n - k) // stride + 1
    grid = all_indices((n_out, k))
    out = grid[:, :1]
    inn = (grid[:, :1] * stride + grid[:, 1:2])
    return LineageRelation((n_out,), (n,), out, inn)


def conv2d_lineage(h: int, w: int, kh: int, kw: int, stride: int = 1) -> LineageRelation:
    h_out = (h - kh) // stride + 1
    w_out = (w - kw) // stride + 1
    grid = all_indices((h_out, w_out, kh, kw))
    out = grid[:, :2]
    inn = np.stack(
        [grid[:, 0] * stride + grid[:, 2], grid[:, 1] * stride + grid[:, 3]], axis=1
    )
    return LineageRelation((h_out, w_out), (h, w), out, inn)


def cumulative_lineage(n: int) -> LineageRelation:
    """cumsum/cumprod: out[i] <- in[j], j <= i (triangular)."""
    i, j = np.tril_indices(n)
    return LineageRelation((n,), (n,), i[:, None], j[:, None])


def triangular_lineage(b: int, s: int) -> LineageRelation:
    """Causal attention mixing: out[b, t] <- in[b, t'], t' <= t."""
    t, tp = np.tril_indices(s)
    nb = np.repeat(np.arange(b, dtype=np.int64), t.size)
    t = np.tile(t, b)
    tp = np.tile(tp, b)
    return LineageRelation(
        (b, s), (b, s), np.stack([nb, t], 1), np.stack([nb, tp], 1)
    )


# --------------------------------------------------------------------------- #
# Value-dependent captures
# --------------------------------------------------------------------------- #
def sort_lineage(values: np.ndarray, axis: int = -1) -> LineageRelation:
    """out[.., r, ..] <- in[.., argsort(values)[r], ..]."""
    values = np.asarray(values)
    axis = axis % values.ndim
    perm = np.argsort(values, axis=axis, kind="stable")
    out = all_indices(values.shape)
    inn = out.copy()
    # perm laid out in C order matches the all_indices enumeration directly
    inn[:, axis] = perm.reshape(-1)
    return LineageRelation(values.shape, values.shape, out, inn)


def group_by_lineage(keys: np.ndarray, n_cols: int) -> LineageRelation:
    """Group-by aggregate over a 2-D table: out[g, c] <- in[r, c], key[r]=g-th key."""
    keys = np.asarray(keys)
    uniq, inv = np.unique(keys, return_inverse=True)
    n = keys.size
    rows = np.arange(n, dtype=np.int64)
    out_g = inv.astype(np.int64)
    col = np.arange(n_cols, dtype=np.int64)
    out = np.stack(
        [np.repeat(out_g, n_cols), np.tile(col, n)], axis=1
    )
    inn = np.stack([np.repeat(rows, n_cols), np.tile(col, n)], axis=1)
    return LineageRelation((uniq.size, n_cols), (n, n_cols), out, inn)


def inner_join_lineage(
    left_keys: np.ndarray,
    right_keys: np.ndarray,
    left_cols: int,
    right_cols: int,
) -> tuple[LineageRelation, LineageRelation]:
    """Inner equi-join of two 2-D tables on key columns.

    Output row t = (left row i ⨝ right row j); columns are
    [left cols..., right cols...].
    """
    left_keys = np.asarray(left_keys)
    right_keys = np.asarray(right_keys)
    # sorted-merge join, vectorized
    lo = np.argsort(left_keys, kind="stable")
    ro = np.argsort(right_keys, kind="stable")
    lk, rk = left_keys[lo], right_keys[ro]
    # match counts per left row via searchsorted
    starts = np.searchsorted(rk, lk, side="left")
    ends = np.searchsorted(rk, lk, side="right")
    counts = ends - starts
    li = np.repeat(np.arange(lk.size), counts)
    offsets = np.repeat(starts, counts) + (
        np.arange(counts.sum()) - np.repeat(np.cumsum(counts) - counts, counts)
    )
    ri = offsets
    left_rows = lo[li]
    right_rows = ro[ri]
    n_out = left_rows.size
    out_cols_total = left_cols + right_cols
    t = np.arange(n_out, dtype=np.int64)

    # lineage vs LEFT table: out[t, c] <- left[left_rows[t], c] for c < left_cols
    lc = np.arange(left_cols, dtype=np.int64)
    out_l = np.stack([np.repeat(t, left_cols), np.tile(lc, n_out)], axis=1)
    in_l = np.stack(
        [np.repeat(left_rows, left_cols), np.tile(lc, n_out)], axis=1
    )
    rel_l = LineageRelation(
        (n_out, out_cols_total), (left_keys.size, left_cols), out_l, in_l
    )
    rc = np.arange(right_cols, dtype=np.int64)
    out_r = np.stack(
        [np.repeat(t, right_cols), np.tile(rc, n_out) + left_cols], axis=1
    )
    in_r = np.stack(
        [np.repeat(right_rows, right_cols), np.tile(rc, n_out)], axis=1
    )
    rel_r = LineageRelation(
        (n_out, out_cols_total), (right_keys.size, right_cols), out_r, in_r
    )
    return rel_l, rel_r


def xai_bipartite_lineage(
    in_shape: tuple[int, ...],
    n_out: int,
    n_patches: int,
    patch: int,
    seed: int = 0,
) -> LineageRelation:
    """LIME/D-RISE-style capture: each output label cell is attributed to a
    set of contiguous 2-D patches of the input (superpixels above the
    significance threshold).  Statistically matches the paper's XAI captures:
    block-structured and therefore range-compressible."""
    rng = np.random.default_rng(seed)
    h, w = in_shape
    outs, inns = [], []
    for o in range(n_out):
        for _ in range(n_patches):
            i0 = int(rng.integers(0, max(1, h - patch)))
            j0 = int(rng.integers(0, max(1, w - patch)))
            ii, jj = np.meshgrid(
                np.arange(i0, min(h, i0 + patch)),
                np.arange(j0, min(w, j0 + patch)),
                indexing="ij",
            )
            cells = np.stack([ii.ravel(), jj.ravel()], axis=1)
            outs.append(np.full((cells.shape[0], 1), o, np.int64))
            inns.append(cells)
    return LineageRelation(
        (n_out,), in_shape, np.concatenate(outs), np.concatenate(inns)
    ).canonical()


# --------------------------------------------------------------------------- #
# Oracle capture (jacobian sparsity)
# --------------------------------------------------------------------------- #
def capture_jacobian(f, *in_arrays, eps: float = 0.0) -> list[LineageRelation]:
    """Ground-truth lineage of ``f(*in_arrays)`` via jacobian sparsity.

    Returns one relation per input.  Inputs should be generic (random,
    tie-free) so that structurally-present dependencies have nonzero
    derivatives.  Used as the property-test oracle.
    """
    import jax

    in_arrays = [np.asarray(a, np.float64) for a in in_arrays]
    out = np.asarray(f(*[a for a in in_arrays]))
    out_shape = out.shape if out.shape else (1,)
    rels = []
    for pos, a in enumerate(in_arrays):
        def fi(x, _pos=pos):
            args = list(in_arrays)
            args[_pos] = x
            r = f(*args)
            return r.reshape(-1) if hasattr(r, "reshape") else r

        jac = jax.jacfwd(fi)(a)
        jac = np.asarray(jac).reshape(int(np.prod(out_shape)), int(np.prod(a.shape)))
        oflat, iflat = np.nonzero(np.abs(jac) > eps)
        rels.append(
            LineageRelation.from_flat(
                out_shape, a.shape if a.shape else (1,), oflat, iflat
            )
        )
    return rels
