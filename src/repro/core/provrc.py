"""ProvRC — the paper's lossless lineage-compression algorithm (§IV).

Two passes over the relation:

* **Step 1 — multi-attribute range encoding over value attributes**: for each
  value attribute (last to first), merge runs of rows that agree on every
  other attribute and are contiguous on this one, replacing them with a
  single interval row.

* **Step 2 — relative value transformation + range encoding over key
  attributes**: value attributes may be re-expressed as deltas against the
  key attribute currently being merged (``val − key_j``), which turns
  element-wise / convolution / matmul-style lineage into constant columns and
  unlocks the same range encoding over the key side.

Two implementations are provided:

* ``method="paper"`` — the paper's sequential greedy scan (one global sort,
  per-run representation-subset tracking).  Exact transliteration; O(N·m)
  Python loop, used for small tables and as a fidelity reference.
* ``method="vector"`` — a fully vectorized formulation: per key attribute we
  run one all-absolute pass plus one single-attr-delta pass per value attr,
  each to fixpoint.  Each pass is a lexsort + boundary detection + segment
  reduce, i.e. exactly the shape of work the Pallas ``provrc_encode``
  kernel performs on TPU.  This path is strictly stronger than the paper's
  greedy (the greedy's single sort order can hide delta-mergeable runs) and
  is the production default (``method="auto"``).

Both encoders maintain the *delta-uniqueness invariant* — at most one value
attribute per row may be relative to any given key attribute — which is
what makes the θ-join's independent de-relativization exact (see
``_rep_combos``).  Both are lossless (property-tested against
decompression) and in-situ-query-exact (tested against the
uncompressed-row oracle).
"""

from __future__ import annotations

import itertools

import numpy as np

from .intervals import coalesce_1d, lexsort_rows
from .relation import LineageRelation
from .table import CompressedTable

__all__ = ["compress", "compress_both", "CompressStats"]


class CompressStats(dict):
    """Small diagnostics bag: rows in/out, passes run."""


# --------------------------------------------------------------------------- #
# Entry points
# --------------------------------------------------------------------------- #
def compress(
    rel: LineageRelation,
    direction: str = "backward",
    method: str = "auto",
    exact_threshold: int = 4096,
    stats: CompressStats | None = None,
) -> CompressedTable:
    """Compress an uncompressed relation into a :class:`CompressedTable`."""
    rel = rel.canonical()
    if direction == "backward":
        keys, vals = rel.out_idx, rel.in_idx
        key_shape, val_shape = rel.out_shape, rel.in_shape
    elif direction == "forward":
        keys, vals = rel.in_idx, rel.out_idx
        key_shape, val_shape = rel.in_shape, rel.out_shape
    else:
        raise ValueError(direction)

    if method == "auto":
        # the vectorized formulation dominates the paper greedy in both
        # compression quality (multi-combo sort orders expose delta runs the
        # greedy's single sort hides — e.g. np.cross) and throughput, so it
        # is the production path at every size; "paper" remains available as
        # the fidelity reference.
        method = "vector"

    n, l = keys.shape
    m = vals.shape[1]
    key_lo, key_hi = keys.copy(), keys.copy()
    val_lo, val_hi = vals.copy(), vals.copy()
    val_ref = np.full((n, m), -1, np.int8)

    if stats is not None:
        stats["rows_in"] = n

    # ---- Step 1: range encoding over value attributes ------------------- #
    for i in range(m - 1, -1, -1):
        key_lo, key_hi, val_lo, val_hi, val_ref = _step1_pass(
            key_lo, key_hi, val_lo, val_hi, val_ref, i
        )

    # ---- Step 2: relative transform + range encoding over keys ---------- #
    if method == "paper":
        key_lo, key_hi, val_lo, val_hi, val_ref = _step2_paper(
            key_lo, key_hi, val_lo, val_hi, val_ref
        )
    elif method == "vector":
        key_lo, key_hi, val_lo, val_hi, val_ref = _step2_vector(
            key_lo, key_hi, val_lo, val_hi, val_ref
        )
    else:
        raise ValueError(method)

    if stats is not None:
        stats["rows_out"] = key_lo.shape[0]
        stats["method"] = method

    return CompressedTable(
        key_shape, val_shape, key_lo, key_hi, val_lo, val_hi, val_ref, direction
    )


def compress_both(
    rel: LineageRelation, method: str = "auto"
) -> tuple[CompressedTable, CompressedTable]:
    """Backward + forward materializations (paper §IV.C)."""
    return (
        compress(rel, "backward", method),
        compress(rel, "forward", method),
    )


# --------------------------------------------------------------------------- #
# Step 1
# --------------------------------------------------------------------------- #
def _step1_pass(key_lo, key_hi, val_lo, val_hi, val_ref, i):
    """Range-encode value attribute ``i``; all other columns must match."""
    n, m = val_lo.shape
    if n == 0:
        return key_lo, key_hi, val_lo, val_hi, val_ref
    others = [key_lo[:, j] for j in range(key_lo.shape[1])]
    for k in range(m):
        if k == i:
            continue
        others += [val_lo[:, k], val_hi[:, k]]
    order = lexsort_rows(others + [val_lo[:, i]])
    group = _group_ids([c[order] for c in others], n)
    starts, lo, hi = coalesce_1d(group, val_lo[order, i], val_hi[order, i])
    sel = order[starts]
    key_lo, key_hi = key_lo[sel], key_hi[sel]
    val_lo, val_hi, val_ref = val_lo[sel].copy(), val_hi[sel].copy(), val_ref[sel]
    val_lo[:, i], val_hi[:, i] = lo, hi
    return key_lo, key_hi, val_lo, val_hi, val_ref


def _group_ids(cols: list[np.ndarray], n: int | None = None) -> np.ndarray:
    """Dense group ids for rows *already sorted* by ``cols``."""
    if not cols:
        return np.zeros(0 if n is None else n, np.int64)
    n = cols[0].size
    if n == 0:
        return np.zeros(0, np.int64)
    change = np.zeros(n, dtype=bool)
    for c in cols:
        change[1:] |= c[1:] != c[:-1]
    return np.cumsum(change)


# --------------------------------------------------------------------------- #
# Step 2 — vectorized combo passes
# --------------------------------------------------------------------------- #
def _step2_vector(key_lo, key_hi, val_lo, val_hi, val_ref):
    l = key_lo.shape[1]
    m = val_lo.shape[1]
    for j in range(l - 1, -1, -1):
        for combo in _rep_combos(m):
            prev = -1
            # iterate this combo to fixpoint (merges can cascade)
            while key_lo.shape[0] != prev:
                prev = key_lo.shape[0]
                key_lo, key_hi, val_lo, val_hi, val_ref = _step2_pass(
                    key_lo, key_hi, val_lo, val_hi, val_ref, j, combo
                )
    return key_lo, key_hi, val_lo, val_hi, val_ref


def _rep_combos(m: int) -> list[tuple[bool, ...]]:
    """Representation combos: ``True`` ⇒ try delta for that value attr.

    INVARIANT (correctness of in-situ queries): at most one value attr may
    convert to a delta per merge pass, so no row ever carries two attrs
    relative to the same key attr.  Two same-key deltas encode a *line*
    (e.g. a diagonal run inside a sort permutation) that decompresses
    correctly but that the θ-join's independent de-relativization would
    over-approximate to its bounding box — the paper's Fig 5 reversal
    implicitly assumes this invariant, and our
    ``tests/test_query.py::test_diagonal_relation_not_overcounted`` pins it.
    """
    if m == 0:
        return [()]
    combos = [tuple([False] * m)]
    for i in range(m):
        c = [False] * m
        c[i] = True
        combos.append(tuple(c))
    return combos


def _step2_pass(key_lo, key_hi, val_lo, val_hi, val_ref, j, combo):
    """One merge pass on key attribute ``j`` under a fixed rep combo.

    ``combo[i] == True`` means value attr ``i`` is grouped by its delta
    against key ``j`` (only rows still absolute can convert); ``False`` means
    grouped by its stored (ref, lo, hi) triple.
    """
    n, l = key_lo.shape
    m = val_lo.shape[1]
    if n <= 1:
        return key_lo, key_hi, val_lo, val_hi, val_ref
    kj = key_lo[:, j]  # width-0 until merged in its own pass… may be interval
    kj_hi = key_hi[:, j]

    group_cols: list[np.ndarray] = []
    for k in range(l):
        if k == j:
            continue
        group_cols += [key_lo[:, k], key_hi[:, k]]
    # Only rows whose key-j interval is still width 0 may convert to a delta
    # rep: against an already-widened key the delta is not a single value.
    # A row may also never gain a SECOND attr relative to this key (the
    # ≤1-delta-per-key invariant; see _rep_combos).
    narrow_key = kj == kj_hi
    already_ref_j = (val_ref == j).any(axis=1)
    use_delta = np.zeros((n, m), dtype=bool)
    for i in range(m):
        if combo[i]:
            can = (val_ref[:, i] == -1) & narrow_key & ~already_ref_j
            use_delta[:, i] = can
            # marker separates delta-grouped rows from triple-grouped ones
            marker = np.where(can, l, val_ref[:, i]).astype(np.int64)
            glo = np.where(can, val_lo[:, i] - kj, val_lo[:, i])
            ghi = np.where(can, val_hi[:, i] - kj, val_hi[:, i])
        else:
            marker = val_ref[:, i].astype(np.int64)
            glo, ghi = val_lo[:, i], val_hi[:, i]
        group_cols += [marker, glo, ghi]

    order = lexsort_rows(group_cols + [kj])
    group = _group_ids([c[order] for c in group_cols], n)
    starts, lo, hi = coalesce_1d(group, kj[order], kj_hi[order])
    if starts.size == n:  # nothing merged
        return key_lo, key_hi, val_lo, val_hi, val_ref

    sel = order[starts]
    seg_len = np.diff(np.append(starts, n))
    merged = seg_len > 1

    new_key_lo, new_key_hi = key_lo[sel].copy(), key_hi[sel].copy()
    new_key_lo[:, j], new_key_hi[:, j] = lo, hi
    new_val_lo, new_val_hi = val_lo[sel].copy(), val_hi[sel].copy()
    new_val_ref = val_ref[sel].copy()
    # Rows that actually merged under a delta rep must store the delta.
    for i in range(m):
        if not combo[i]:
            continue
        conv = merged & use_delta[order, i][starts]
        if not conv.any():
            continue
        base = kj[sel]
        new_val_lo[conv, i] = val_lo[sel][conv, i] - base[conv]
        new_val_hi[conv, i] = val_hi[sel][conv, i] - base[conv]
        new_val_ref[conv, i] = j
    return new_key_lo, new_key_hi, new_val_lo, new_val_hi, new_val_ref


# --------------------------------------------------------------------------- #
# Step 2 — the paper's sequential greedy (fidelity reference)
# --------------------------------------------------------------------------- #
def _step2_paper(key_lo, key_hi, val_lo, val_hi, val_ref):
    l = key_lo.shape[1]
    for j in range(l - 1, -1, -1):
        key_lo, key_hi, val_lo, val_hi, val_ref = _step2_paper_attr(
            key_lo, key_hi, val_lo, val_hi, val_ref, j
        )
    return key_lo, key_hi, val_lo, val_hi, val_ref


def _step2_paper_attr(key_lo, key_hi, val_lo, val_hi, val_ref, j):
    n, l = key_lo.shape
    m = val_lo.shape[1]
    if n <= 1:
        return key_lo, key_hi, val_lo, val_hi, val_ref
    sort_cols = []
    for k in range(l):
        if k != j:
            sort_cols += [key_lo[:, k], key_hi[:, k]]
    sort_cols.append(key_lo[:, j])
    order = lexsort_rows(sort_cols)
    kl, kh = key_lo[order], key_hi[order]
    vl, vh, vr = val_lo[order], val_hi[order], val_ref[order]

    out_rows: list[tuple] = []
    run_start = 0

    def flush(s: int, e: int, cand_sets) -> None:
        """Emit run [s, e) as one row."""
        row_kl, row_kh = kl[s].copy(), kh[s].copy()
        row_kh[j] = kh[e - 1][j]
        row_vl, row_vh, row_vr = vl[s].copy(), vh[s].copy(), vr[s].copy()
        if e - s > 1:
            for i in range(m):
                if "abs" in cand_sets[i]:
                    continue  # absolute representation preserved
                # delta rep against key j
                row_vl[i] = vl[s][i] - kl[s][j]
                row_vh[i] = vh[s][i] - kl[s][j]
                row_vr[i] = j
        out_rows.append((row_kl, row_kh, row_vl, row_vh, row_vr))

    cand = _init_cand_sets(vr[0], m)
    for t in range(1, n):
        same_others = all(
            kl[t][k] == kl[t - 1][k] and kh[t][k] == kh[t - 1][k]
            for k in range(l)
            if k != j
        )
        contiguous = kl[t][j] == kh[t - 1][j] + 1
        new_cand = None
        if same_others and contiguous:
            new_cand = []
            ok = True
            for i in range(m):
                s = set()
                if "abs" in cand[i] and (
                    vr[t][i] == vr[t - 1][i]
                    and vl[t][i] == vl[t - 1][i]
                    and vh[t][i] == vh[t - 1][i]
                ):
                    s.add("abs")
                if (
                    "delta" in cand[i]
                    and vr[t][i] == -1
                    and vr[t - 1][i] == -1
                    and vl[t][i] - kl[t][j] == vl[run_start][i] - kl[run_start][j]
                    and vh[t][i] - kl[t][j] == vh[run_start][i] - kl[run_start][j]
                ):
                    s.add("delta")
                if not s:
                    ok = False
                    break
                new_cand.append(s)
            if ok:
                # ≤1-delta-per-key invariant (see _rep_combos): a run that
                # would force two same-key delta conversions must flush
                delta_only = sum(1 for s in new_cand if s == {"delta"})
                if delta_only > 1:
                    ok = False
            if not ok:
                new_cand = None
        if new_cand is None:
            flush(run_start, t, cand)
            run_start = t
            cand = _init_cand_sets(vr[t], m)
        else:
            cand = new_cand
    flush(run_start, n, cand)

    kl2 = np.stack([r[0] for r in out_rows])
    kh2 = np.stack([r[1] for r in out_rows])
    vl2 = np.stack([r[2] for r in out_rows]) if m else np.zeros((len(out_rows), 0), np.int64)
    vh2 = np.stack([r[3] for r in out_rows]) if m else np.zeros((len(out_rows), 0), np.int64)
    vr2 = (
        np.stack([r[4] for r in out_rows]).astype(np.int8)
        if m
        else np.zeros((len(out_rows), 0), np.int8)
    )
    return kl2, kh2, vl2, vh2, vr2


def _init_cand_sets(ref_row: np.ndarray, m: int) -> list[set]:
    return [
        {"abs", "delta"} if ref_row[i] == -1 else {"abs"} for i in range(m)
    ]
