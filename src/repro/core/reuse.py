"""Lineage reuse: signatures, index reshaping, automatic prediction (§VI).

Three signature granularities map operation calls to stored lineage:

* ``base_sig(op_name, in_arrs, op_args)``   — exact input arrays must match.
* ``dim_sig(op_name, in_shapes, op_args)``  — only the input *shapes* must
  match (linear algebra, NN forward passes, …).
* ``gen_sig(op_name, op_args)``             — shape-independent: the stored
  table is *index-reshaped* into a generalized representation where every
  interval spanning a full axis extent ``[0, d_i − 1]`` is replaced by the
  symbolic extent ``D_i``; instantiating at a new shape substitutes the new
  extents (paper §VI.B, Fig 6).

:class:`ReusePredictor` implements §VI.C: on first registration a tentative
``dim_sig``/``gen_sig`` mapping is stored; the next ``m`` (default 1)
matching calls are captured normally and compared — a match promotes the
mapping to permanent (for ``gen_sig`` the confirming calls must use
*different* shapes), a mismatch marks the partial signature non-reusable
(the paper's ``cross`` misprediction case).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field, replace
from typing import Any

import numpy as np

from .table import CompressedTable

__all__ = [
    "generalize",
    "instantiate",
    "tables_equal",
    "sig_key_base",
    "sig_key_dim",
    "sig_key_gen",
    "ReusePredictor",
    "ReuseDecision",
]


# --------------------------------------------------------------------------- #
# Index reshaping (§VI.B)
# --------------------------------------------------------------------------- #
def generalize(table: CompressedTable) -> CompressedTable:
    """Mark every full-extent interval as symbolic (``[0, D_i − 1]``).

    Only *absolute* intervals can be generalized: a delta interval is already
    shape-free by construction, which is why the relative transformation of
    ProvRC is what makes index reshaping possible at all.
    """
    t = table
    key_sym = np.full_like(t.key_sym, -1)
    val_sym = np.full_like(t.val_sym, -1)
    for j, d in enumerate(t.key_shape):
        full = (t.key_lo[:, j] == 0) & (t.key_hi[:, j] == d - 1)
        key_sym[full, j] = j
    for i, d in enumerate(t.val_shape):
        full = (
            (t.val_ref[:, i] == -1)
            & (t.val_lo[:, i] == 0)
            & (t.val_hi[:, i] == d - 1)
        )
        val_sym[full, i] = i
    return replace(t, key_sym=key_sym, val_sym=val_sym)


def instantiate(
    table: CompressedTable,
    key_shape: tuple[int, ...],
    val_shape: tuple[int, ...],
) -> CompressedTable:
    """Substitute concrete axis extents into a generalized table."""
    t = table
    if len(key_shape) != t.n_key or len(val_shape) != t.n_val:
        raise ValueError("rank mismatch instantiating generalized table")
    key_lo, key_hi = t.key_lo.copy(), t.key_hi.copy()
    val_lo, val_hi = t.val_lo.copy(), t.val_hi.copy()
    for j, d in enumerate(key_shape):
        m = t.key_sym[:, j] >= 0
        key_lo[m, j] = 0
        key_hi[m, j] = d - 1
    for i, d in enumerate(val_shape):
        m = t.val_sym[:, i] >= 0
        val_lo[m, i] = 0
        val_hi[m, i] = d - 1
    return CompressedTable(
        key_shape,
        val_shape,
        key_lo,
        key_hi,
        val_lo,
        val_hi,
        t.val_ref.copy(),
        t.direction,
    )


def tables_equal(a: CompressedTable, b: CompressedTable) -> bool:
    """Row-order-insensitive structural equality of two compressed tables."""
    if (
        a.key_shape != b.key_shape
        or a.val_shape != b.val_shape
        or a.direction != b.direction
        or a.n_rows != b.n_rows
    ):
        return False

    def canon(t: CompressedTable) -> np.ndarray:
        cols = np.concatenate(
            [
                t.key_lo,
                t.key_hi,
                t.val_lo,
                t.val_hi,
                t.val_ref.astype(np.int64),
                t.key_sym.astype(np.int64),
                t.val_sym.astype(np.int64),
            ],
            axis=1,
        )
        return np.unique(cols, axis=0)

    ca, cb = canon(a), canon(b)
    return ca.shape == cb.shape and bool(np.array_equal(ca, cb))


def symbolic_tables_equal(a: CompressedTable, b: CompressedTable) -> bool:
    """Equality of generalized tables ignoring the captured concrete extents.

    Symbolic cells are compared by their symbol, not the stored lo/hi.
    """
    if (
        a.n_key != b.n_key
        or a.n_val != b.n_val
        or a.direction != b.direction
        or a.n_rows != b.n_rows
    ):
        return False

    def canon(t: CompressedTable) -> np.ndarray:
        key_lo, key_hi = t.key_lo.copy(), t.key_hi.copy()
        val_lo, val_hi = t.val_lo.copy(), t.val_hi.copy()
        ks, vs = t.key_sym >= 0, t.val_sym >= 0
        key_lo[ks] = 0
        key_hi[ks] = -2  # sentinel: "symbolic extent"
        val_lo[vs] = 0
        val_hi[vs] = -2
        cols = np.concatenate(
            [
                key_lo,
                key_hi,
                val_lo,
                val_hi,
                t.val_ref.astype(np.int64),
                t.key_sym.astype(np.int64),
                t.val_sym.astype(np.int64),
            ],
            axis=1,
        )
        return np.unique(cols, axis=0)

    ca, cb = canon(a), canon(b)
    return ca.shape == cb.shape and bool(np.array_equal(ca, cb))


# --------------------------------------------------------------------------- #
# Operation signatures
# --------------------------------------------------------------------------- #
def _args_repr(op_args: Any) -> str:
    try:
        return json.dumps(op_args, sort_keys=True, default=str)
    except TypeError:
        return repr(op_args)


def sig_key_base(op_name: str, in_arrs: tuple[str, ...], op_args: Any) -> str:
    return f"base::{op_name}::{','.join(in_arrs)}::{_args_repr(op_args)}"


def sig_key_dim(
    op_name: str, in_shapes: tuple[tuple[int, ...], ...], op_args: Any
) -> str:
    return f"dim::{op_name}::{in_shapes!r}::{_args_repr(op_args)}"


def sig_key_gen(op_name: str, op_args: Any) -> str:
    return f"gen::{op_name}::{_args_repr(op_args)}"


# --------------------------------------------------------------------------- #
# Automatic reuse prediction (§VI.C)
# --------------------------------------------------------------------------- #
@dataclass
class _SigState:
    kind: str  # "dim" | "gen"
    status: str = "tentative"  # tentative | confirmed | rejected
    matches: int = 0
    # map from (in_pos, out_pos) pair label -> stored table(s)
    tables: dict[str, CompressedTable] = field(default_factory=dict)
    seen_shapes: set = field(default_factory=set)


@dataclass
class ReuseDecision:
    reused: bool
    source: str | None = None  # "base" | "dim" | "gen"
    tables: dict[str, CompressedTable] | None = None


class ReusePredictor:
    """Tracks per-partial-signature reuse state across registrations.

    Persistence is dirty-tracked *per signature* (mirroring the catalog's
    per-entry ``_dirty``/``_persisted`` split): :meth:`observe` marks only
    the signatures it actually mutates, and :meth:`state_manifest` reuses
    the previously persisted record (blob names included) for every clean
    signature — so one new observation no longer rewrites every ``sig_*``
    blob on ``save()``.
    """

    def __init__(self, m: int = 1):
        self.m = m
        self.state: dict[str, _SigState] = {}
        # per-signature persistence bookkeeping
        self._dirty: set[str] = set()
        self._persisted_recs: dict[str, dict] = {}

    @property
    def dirty(self) -> bool:
        """Whether any signature changed since the last snapshot/load."""
        return bool(self._dirty)

    # ------------------------------------------------------------------ #
    def lookup(
        self,
        dim_key: str,
        gen_key: str,
        shapes_token: tuple,
        pair_shapes: dict[str, tuple[tuple[int, ...], tuple[int, ...]]],
    ) -> ReuseDecision:
        """Check whether a confirmed mapping can serve this call."""
        st = self.state.get(dim_key)
        if st is not None and st.status == "confirmed":
            return ReuseDecision(True, "dim", dict(st.tables))
        st = self.state.get(gen_key)
        if st is not None and st.status == "confirmed":
            inst = {
                label: instantiate(
                    tbl, *self._inst_shapes(tbl, pair_shapes[label])
                )
                for label, tbl in st.tables.items()
            }
            return ReuseDecision(True, "gen", inst)
        return ReuseDecision(False)

    @staticmethod
    def _inst_shapes(tbl, pair):
        out_shape, in_shape = pair
        if tbl.direction == "backward":
            return out_shape, in_shape
        return in_shape, out_shape

    # ------------------------------------------------------------------ #
    def observe(
        self,
        dim_key: str,
        gen_key: str,
        shapes_token: tuple,
        captured: dict[str, CompressedTable],
    ) -> None:
        """Feed a freshly captured lineage set into the prediction machine."""
        # ---- dim_sig ---------------------------------------------------- #
        st = self.state.get(dim_key)
        if st is None:
            self.state[dim_key] = _SigState("dim", tables=dict(captured))
            self._dirty.add(dim_key)
        elif st.status in ("tentative",):
            ok = all(
                label in st.tables and tables_equal(st.tables[label], t)
                for label, t in captured.items()
            ) and len(st.tables) == len(captured)
            if ok:
                st.matches += 1
                if st.matches >= self.m:
                    st.status = "confirmed"
            else:
                st.status = "rejected"
            self._dirty.add(dim_key)
        # ---- gen_sig ---------------------------------------------------- #
        gen_tables = {label: generalize(t) for label, t in captured.items()}
        st = self.state.get(gen_key)
        if st is None:
            s = _SigState("gen", tables=gen_tables)
            s.seen_shapes.add(shapes_token)
            self.state[gen_key] = s
            self._dirty.add(gen_key)
        elif st.status == "tentative":
            ok = all(
                label in st.tables
                and symbolic_tables_equal(st.tables[label], t)
                for label, t in gen_tables.items()
            ) and len(st.tables) == len(gen_tables)
            if not ok:
                st.status = "rejected"
                self._dirty.add(gen_key)
            elif shapes_token not in st.seen_shapes:
                # gen_sig confirmation requires a *different* shape (§VI.C)
                st.matches += 1
                st.seen_shapes.add(shapes_token)
                st.tables = gen_tables  # keep the latest generalization
                if st.matches >= self.m:
                    st.status = "confirmed"
                self._dirty.add(gen_key)

    def status(self, key: str) -> str | None:
        st = self.state.get(key)
        return st.status if st else None

    # ------------------------------------------------------------------ #
    # persistence (catalog manifest v2)
    # ------------------------------------------------------------------ #
    def state_manifest(self, save_table) -> dict:
        """JSON-safe snapshot of the prediction state.

        ``save_table(sig_key, label, table) -> str`` persists one stored
        table and returns its blob name — the predictor stays I/O-free; the
        catalog owns file layout.  Rejected signatures keep only their
        verdict (their tables can never be consulted again).

        Dirty tracking is per signature: a clean signature's previous record
        is reused verbatim (no blob rewrite); only signatures touched by
        :meth:`observe` since the last snapshot have their tables re-saved.
        """
        sigs = []
        for key, st in self.state.items():
            rec = self._persisted_recs.get(key)
            if rec is None or key in self._dirty:
                rec = {
                    "key": key,
                    "kind": st.kind,
                    "status": st.status,
                    "matches": st.matches,
                    "seen_shapes": [
                        [list(map(int, s)) for s in tok] for tok in st.seen_shapes
                    ],
                    "tables": {},
                }
                if st.status != "rejected":
                    rec["tables"] = {
                        label: save_table(key, label, tbl)
                        for label, tbl in st.tables.items()
                    }
                self._persisted_recs[key] = rec
            sigs.append(rec)
        self._dirty.clear()
        return {"m": self.m, "sigs": sigs}

    @classmethod
    def from_manifest(cls, manifest: dict, load_table) -> "ReusePredictor":
        """Rebuild a predictor from :meth:`state_manifest` output.

        ``load_table(blob_name) -> CompressedTable`` resolves the stored
        tables (catalog-owned I/O).  A reloaded predictor keeps confirmed
        mappings live, so ``register_operation`` on a reopened catalog still
        bypasses capture.
        """
        p = cls(m=int(manifest.get("m", 1)))
        for rec in manifest.get("sigs", []):
            st = _SigState(rec["kind"], rec["status"], int(rec["matches"]))
            st.seen_shapes = {
                tuple(tuple(int(x) for x in s) for s in tok)
                for tok in rec["seen_shapes"]
            }
            st.tables = {
                label: load_table(fn) for label, fn in rec["tables"].items()
            }
            p.state[rec["key"]] = st
            p._persisted_recs[rec["key"]] = rec
        return p
