"""Registry of numpy/jnp array operations with lineage adapters.

The paper's Table IX evaluates ProvRC compression + automatic reuse over 136
numpy API operations (element-wise vs "complex").  This registry is the
offline analog: every entry knows how to produce its fine-grained lineage
for a given input shape, whether that lineage is value-dependent, and which
family it belongs to.  ``benchmarks/table9_coverage.py`` sweeps it; the
integration facade ``repro.lineage`` re-exports these adapters (alongside
DSLog, the lineage graph, and the planner) as the single import surface for
logging pipeline/model ops into DSLog — see
``examples/lineage_debugging.py`` for the end-to-end flow.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

from . import capture as C
from .relation import LineageRelation

__all__ = ["OpSpec", "OPS", "get_op", "op_names"]


@dataclass(frozen=True)
class OpSpec:
    name: str
    category: str  # "element" | "complex"
    value_dependent: bool
    # lineage(shape, rng) -> {(out_pos, in_pos): LineageRelation}
    lineage: Callable[[tuple[int, ...], np.random.Generator], dict]
    # two+ distinct input shapes for reuse confirmation sweeps
    shapes: tuple[tuple[int, ...], ...] = ((8, 6), (5, 9))
    # True when the lineage pattern itself changes with shape — the paper's
    # `cross` case, which gen_sig reuse must NOT cover (misprediction risk).
    shape_pattern_dependent: bool = False


def _unary(shape, rng):
    return {(0, 0): C.identity_lineage(shape)}


def _binary_same(shape, rng):
    return {(0, 0): C.identity_lineage(shape), (0, 1): C.identity_lineage(shape)}


def _binary_broadcast(shape, rng):
    # second operand is a broadcast row vector
    vec = (shape[-1],)
    return {
        (0, 0): C.identity_lineage(shape),
        (0, 1): C.broadcast_lineage(vec, shape),
    }


def _reduce_all(shape, rng):
    return {(0, 0): C.reduce_lineage(shape, tuple(range(len(shape))))}


def _reduce_ax(ax):
    def f(shape, rng):
        return {(0, 0): C.reduce_lineage(shape, ax % len(shape))}

    return f


def _softmax(shape, rng):
    return {(0, 0): C.softmax_lineage(shape, -1)}


def _cumulative(shape, rng):
    n = int(np.prod(shape))
    return {(0, 0): _lift_flat(C.cumulative_lineage(n), shape)}


def _lift_flat(rel_flat: LineageRelation, shape) -> LineageRelation:
    """cumsum over the flattened array (numpy default axis=None view)."""
    n = int(np.prod(shape))
    return LineageRelation((n,), (n,), rel_flat.out_idx, rel_flat.in_idx)


def _matmul(shape, rng):
    m, k = shape
    n = k + 2
    ra, rb = C.matmul_lineage(m, k, n)
    return {(0, 0): ra, (0, 1): rb}


def _outer(shape, rng):
    m = shape[0]
    n = shape[-1] + 1
    ra, rb = C.outer_lineage(m, n)
    return {(0, 0): ra, (0, 1): rb}


def _transpose(shape, rng):
    perm = tuple(reversed(range(len(shape))))
    return {(0, 0): C.transpose_lineage(shape, perm)}


def _reshape(shape, rng):
    n = int(np.prod(shape))
    return {(0, 0): C.reshape_lineage(shape, (n,))}


def _expand(shape, rng):
    return {(0, 0): C.reshape_lineage(shape, (1,) + tuple(shape))}


def _slice_half(shape, rng):
    stops = tuple(max(1, d // 2) for d in shape)
    return {(0, 0): C.slice_lineage(shape, (0,) * len(shape), stops)}


def _strided(shape, rng):
    return {
        (0, 0): C.slice_lineage(
            shape, (0,) * len(shape), shape, (2,) + (1,) * (len(shape) - 1)
        )
    }


def _concat(shape, rng):
    rels = C.concat_lineage([shape, shape], 0)
    return {(0, 0): rels[0], (0, 1): rels[1]}


def _stack(shape, rng):
    # stack = new leading axis; operand s lands in slot s of axis 0
    out_shape = (2,) + tuple(shape)
    idx = C.all_indices(shape)
    rels = {}
    for s in range(2):
        out = np.concatenate([np.full((idx.shape[0], 1), s, np.int64), idx], axis=1)
        rels[(0, s)] = LineageRelation(out_shape, shape, out, idx)
    return rels


def _tile(shape, rng):
    return {(0, 0): C.tile_lineage(shape, (2,) * len(shape))}


def _repeat(shape, rng):
    return {(0, 0): C.repeat_lineage(shape, 3, 0)}


def _roll(shape, rng):
    return {(0, 0): C.roll_lineage(shape, 2, 0)}


def _flip(shape, rng):
    return {(0, 0): C.flip_lineage(shape, 0)}


def _pad(shape, rng):
    return {(0, 0): C.pad_lineage(shape, [(1, 1)] * len(shape))}


def _diag(shape, rng):
    n = min(shape)
    out = np.arange(n, dtype=np.int64)[:, None]
    inn = np.stack([np.arange(n), np.arange(n)], axis=1).astype(np.int64)
    return {(0, 0): LineageRelation((n,), (shape[0], shape[1]), out, inn)}


def _triu(shape, rng):
    h, w = shape
    i, j = np.triu_indices(h, m=w)
    idx = np.stack([i, j], axis=1).astype(np.int64)
    return {(0, 0): LineageRelation(shape, shape, idx, idx)}


def _tril(shape, rng):
    h, w = shape
    i, j = np.tril_indices(h, m=w)
    idx = np.stack([i, j], axis=1).astype(np.int64)
    return {(0, 0): LineageRelation(shape, shape, idx, idx)}


def _trace(shape, rng):
    n = min(shape)
    inn = np.stack([np.arange(n), np.arange(n)], axis=1).astype(np.int64)
    out = np.zeros((n, 1), np.int64)
    return {(0, 0): LineageRelation((1,), shape, out, inn)}


def _convolve(shape, rng):
    n = int(np.prod(shape))
    k = 3
    rel = C.conv1d_lineage(n, k)
    # kernel operand lineage: out[i] <- w[d] for all d
    grid = C.all_indices((n - k + 1, k))
    rel_w = LineageRelation((n - k + 1,), (k,), grid[:, :1], grid[:, 1:])
    return {(0, 0): rel, (0, 1): rel_w}


def _sort(shape, rng):
    vals = rng.random(shape)
    return {(0, 0): C.sort_lineage(vals, axis=-1)}


def _take(shape, rng):
    idx = rng.integers(0, shape[0], size=shape[0] // 2 + 1)
    return {(0, 0): C.take_lineage(shape, idx, 0)}


def _where(shape, rng):
    # out = where(cond, x, y): elementwise from both branches
    return {(0, 0): C.identity_lineage(shape), (0, 1): C.identity_lineage(shape)}


def _kron(shape, rng):
    h, w = shape
    # kron with a 2x2 block: out[(i,p),(j,q)] <- a[i,j] (and b[p,q])
    out_shape = (2 * h, 2 * w)
    oidx = C.all_indices(out_shape)
    a_idx = np.stack([oidx[:, 0] // 2, oidx[:, 1] // 2], axis=1)
    b_idx = np.stack([oidx[:, 0] % 2, oidx[:, 1] % 2], axis=1)
    return {
        (0, 0): LineageRelation(out_shape, shape, oidx, a_idx),
        (0, 1): LineageRelation(out_shape, (2, 2), oidx, b_idx),
    }


def _cross(shape, rng):
    """np.cross over arrays of vectors — the paper's misprediction case.

    For 3-vectors each output component reads the two *other* components of
    both operands; for 2-vectors the output is a scalar reading both
    components.  The lineage pattern changes with the trailing dim, so a
    gen_sig generalized over one trailing size extrapolates wrongly.
    """
    n, d = shape
    rows_o, rows_a = [], []
    if d == 3:
        for c in range(3):
            for oth in [(c + 1) % 3, (c + 2) % 3]:
                rows_o.append((c, oth))
        out_shape = (n, 3)
    else:  # d == 2 -> scalar per vector pair
        rows_o = [(0, 0), (0, 1)]
        out_shape = (n, 1)
    o_list, a_list = [], []
    for r in range(n):
        for oc, ac in rows_o:
            o_list.append((r, oc))
            a_list.append((r, ac))
    o = np.array(o_list, np.int64)
    a = np.array(a_list, np.int64)
    rel = LineageRelation(out_shape, shape, o, a)
    return {(0, 0): rel, (0, 1): rel}


_E = "element"
_X = "complex"

_ELEMENTWISE_UNARY = [
    "negative", "abs", "exp", "log", "log1p", "expm1", "sqrt", "square",
    "reciprocal", "sign", "floor", "ceil", "round", "rint", "trunc",
    "sin", "cos", "tan", "arcsin", "arccos", "arctan", "sinh", "cosh",
    "tanh", "arcsinh", "arccosh", "arctanh", "exp2", "log2", "log10",
    "cbrt", "fabs", "positive", "rad2deg", "deg2rad", "sigmoid", "relu",
    "gelu", "silu", "softplus", "erf", "rsqrt", "logit", "clip",
    "nan_to_num", "isfinite_mask", "dropout_mask_apply", "scale", "shift",
    "normalize_affine",
]

_ELEMENTWISE_BINARY = [
    "add", "subtract", "multiply", "true_divide", "power", "maximum",
    "minimum", "fmod", "arctan2", "hypot", "logaddexp", "copysign",
    "heaviside", "nextafter", "remainder",
]

_BROADCAST_BINARY = [
    "add_rowvec", "mul_rowvec", "sub_rowvec", "div_rowvec",
    "bias_add", "scale_cols",
]


def _mk_ops() -> dict[str, OpSpec]:
    ops: dict[str, OpSpec] = {}
    for nm in _ELEMENTWISE_UNARY:
        ops[nm] = OpSpec(nm, _E, False, _unary)
    for nm in _ELEMENTWISE_BINARY:
        ops[nm] = OpSpec(nm, _E, False, _binary_same)
    for nm in _BROADCAST_BINARY:
        ops[nm] = OpSpec(nm, _E, False, _binary_broadcast)
    complex_ops = {
        "sum": OpSpec("sum", _X, False, _reduce_all),
        "mean": OpSpec("mean", _X, False, _reduce_all),
        "prod": OpSpec("prod", _X, False, _reduce_all),
        "max": OpSpec("max", _X, False, _reduce_all),
        "min": OpSpec("min", _X, False, _reduce_all),
        "std": OpSpec("std", _X, False, _reduce_all),
        "var": OpSpec("var", _X, False, _reduce_all),
        "sum_axis0": OpSpec("sum_axis0", _X, False, _reduce_ax(0)),
        "sum_axis1": OpSpec("sum_axis1", _X, False, _reduce_ax(1)),
        "mean_axis0": OpSpec("mean_axis0", _X, False, _reduce_ax(0)),
        "max_axis1": OpSpec("max_axis1", _X, False, _reduce_ax(1)),
        "softmax": OpSpec("softmax", _X, False, _softmax),
        "log_softmax": OpSpec("log_softmax", _X, False, _softmax),
        "cumsum": OpSpec("cumsum", _X, False, _cumulative),
        "cumprod": OpSpec("cumprod", _X, False, _cumulative),
        "matmul": OpSpec("matmul", _X, False, _matmul),
        "dot": OpSpec("dot", _X, False, _matmul),
        "outer": OpSpec("outer", _X, False, _outer),
        "transpose": OpSpec("transpose", _X, False, _transpose),
        "swapaxes": OpSpec("swapaxes", _X, False, _transpose),
        "reshape": OpSpec("reshape", _X, False, _reshape),
        "ravel": OpSpec("ravel", _X, False, _reshape),
        "flatten": OpSpec("flatten", _X, False, _reshape),
        "expand_dims": OpSpec("expand_dims", _X, False, _expand),
        "atleast_3d": OpSpec("atleast_3d", _X, False, _expand),
        "slice_half": OpSpec("slice_half", _X, False, _slice_half),
        "strided_slice": OpSpec("strided_slice", _X, False, _strided),
        "concatenate": OpSpec("concatenate", _X, False, _concat),
        "vstack": OpSpec("vstack", _X, False, _concat),
        "hstack": OpSpec(
            "hstack", _X, False,
            lambda shape, rng: {
                (0, i): r for i, r in enumerate(C.concat_lineage([shape, shape], -1))
            },
        ),
        "stack": OpSpec("stack", _X, False, _stack),
        "tile": OpSpec("tile", _X, False, _tile),
        "repeat": OpSpec("repeat", _X, False, _repeat),
        "roll": OpSpec("roll", _X, False, _roll),
        "flip": OpSpec("flip", _X, False, _flip),
        "flipud": OpSpec("flipud", _X, False, _flip),
        "fliplr": OpSpec(
            "fliplr", _X, False, lambda shape, rng: {(0, 0): C.flip_lineage(shape, 1)}
        ),
        "rot90": OpSpec(
            "rot90", _X, False,
            lambda shape, rng: {
                (0, 0): C.transpose_lineage(shape, (1, 0))
            },
        ),
        "pad": OpSpec("pad", _X, False, _pad),
        "broadcast_to": OpSpec(
            "broadcast_to", _X, False,
            lambda shape, rng: {(0, 0): C.broadcast_lineage(shape, (3,) + tuple(shape))},
        ),
        "diag": OpSpec("diag", _X, False, _diag),
        "triu": OpSpec("triu", _X, False, _triu),
        "tril": OpSpec("tril", _X, False, _tril),
        "trace": OpSpec("trace", _X, False, _trace),
        "convolve": OpSpec("convolve", _X, False, _convolve),
        "correlate": OpSpec("correlate", _X, False, _convolve),
        "kron": OpSpec("kron", _X, False, _kron),
        "sort": OpSpec("sort", _X, True, _sort),
        "argsort_gather": OpSpec("argsort_gather", _X, True, _sort),
        "take": OpSpec("take", _X, True, _take),
        "where": OpSpec("where", _E, False, _where),
        "cross": OpSpec(
            "cross", _X, False, _cross,
            shapes=((6, 3), (9, 3), (7, 2)),
            shape_pattern_dependent=True,
        ),
    }
    ops.update(complex_ops)
    return ops


OPS: dict[str, OpSpec] = _mk_ops()


def get_op(name: str) -> OpSpec:
    return OPS[name]


def op_names() -> list[str]:
    return sorted(OPS)
