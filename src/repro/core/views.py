"""Materialized lineage views and the cell-level answer cache.

Hot ``src -> dst`` routes get their multi-hop ProvRC relations *composed*
into a single stored :class:`~repro.core.table.CompressedTable` — a
shortcut edge the planner costs like any other hop — and exact repeated
queries are answered from a bounded cell-level cache before planning at
all.  Both are invalidated precisely through the same events the WAL
records (``entry`` / ``drop`` / ``dirty``): a mutation kills only the
views and cached answers whose route touches the mutated array.

Composition is *operationally exact*: querying the composed table emits
the same cell set as running the per-hop chain, for every query (results
become byte-identical after the planner's canonical final normal form,
:func:`~repro.core.query.canonical_boxes`).  Routes whose rows cannot be
composed exactly under the engine's per-attribute box semantics raise
:class:`CompositionError` and are remembered as uncomposable — the answer
cache still serves their repeats.

Admission is heat-driven: an EMA-aged per-route counter fed by the query
stream (and by the planner's ``record_hop`` feedback on view hops) admits
a route once it crosses a threshold, under a global row budget with
LRU-style demotion of the coldest views.
"""

from __future__ import annotations

import numpy as np

from . import _locks
from .query import QueryBox, _route_pairs, _unique_rows, merge_boxes
from .table import CompressedTable, TableHandle

__all__ = [
    "CompositionError",
    "MaterializedView",
    "ViewManager",
    "compose_tables",
    "compose_route",
    "is_view_id",
    "view_pseudo_id",
    "view_id_of",
]

# View hops ride through the planner as pseudo lineage ids below zero, so
# they can never collide with real entries: view k <-> lineage id -(k+1).
def view_pseudo_id(view_id: int) -> int:
    return -int(view_id) - 1


def view_id_of(pseudo_id: int) -> int:
    return -int(pseudo_id) - 1


def is_view_id(lineage_id: int) -> bool:
    return lineage_id < 0


class CompositionError(ValueError):
    """The route's relations cannot be composed exactly in ProvRC form."""


# --------------------------------------------------------------------------- #
# Exact relation composition (A: K -> Y joined with B: Y -> Z)
# --------------------------------------------------------------------------- #
def _empty_table(
    key_shape, val_shape, n_key: int, n_val: int, direction: str
) -> CompressedTable:
    z = np.zeros((0, max(n_key, 1)), np.int64)[:, :n_key]
    v = np.zeros((0, max(n_val, 1)), np.int64)[:, :n_val]
    return CompressedTable(
        key_shape, val_shape, z, z.copy(), v, v.copy(), v.copy(),
        direction=direction,
    )


def compose_tables(
    A: CompressedTable,
    B: CompressedTable,
    max_rows: int | None = None,
    direction: str = "backward",
) -> CompressedTable:
    """Compose two ProvRC tables: ``A`` maps K -> Y, ``B`` maps Y -> Z.

    The result maps K -> Z and is operationally exact: for every query
    box, joining it against the composed table emits the same cell set as
    joining through ``A`` and then ``B``.  Rows that cannot be composed
    exactly under per-attribute box semantics raise
    :class:`CompositionError` (symbolic tables, relative deltas whose key
    image is not containable in ``B``'s key box, value attributes sharing
    a key reference).
    """
    if A.val_shape != B.key_shape:
        raise CompositionError(
            f"shape mismatch: A values {A.val_shape} vs B keys {B.key_shape}"
        )
    if A.is_symbolic or B.is_symbolic:
        raise CompositionError("symbolic tables do not compose")
    l, mid, m2 = A.n_key, A.n_val, B.n_val
    if A.n_rows == 0 or B.n_rows == 0:
        return _empty_table(A.key_shape, B.val_shape, l, m2, direction)
    a_ref_full = np.asarray(A.val_ref, np.int64)
    # Two relative value attrs referencing the same key attr couple those
    # outputs through the key; the chain's per-attribute product semantics
    # lose that coupling, so no single composed row can reproduce it.
    for r in range(l):
        if np.any(np.count_nonzero(a_ref_full == r, axis=1) > 1):
            raise CompositionError(
                "rows with duplicate key references are not composable"
            )
    vb_lo, vb_hi = A.value_bounds()
    ai, bi = _route_pairs(vb_lo, vb_hi, B.key_lo, B.key_hi, B.key_index, "auto")
    n_pairs = int(ai.size)
    if max_rows is not None and n_pairs > max_rows:
        raise CompositionError(
            f"composition explodes: {n_pairs} candidate pairs > {max_rows}"
        )
    if n_pairs == 0:
        return _empty_table(A.key_shape, B.val_shape, l, m2, direction)
    kl = A.key_lo[ai].astype(np.int64, copy=True)
    kh = A.key_hi[ai].astype(np.int64, copy=True)
    a_ref = a_ref_full[ai]
    a_vlo = np.asarray(A.val_lo, np.int64)[ai]
    a_vhi = np.asarray(A.val_hi, np.int64)[ai]
    b_klo = np.asarray(B.key_lo, np.int64)[bi]
    b_khi = np.asarray(B.key_hi, np.int64)[bi]
    abs_a = a_ref < 0

    # Y pass.  Absolute A attrs intersect with B's key box (both static, so
    # the chain's intermediate interval is query-independent and exact);
    # relative attrs tighten the composed key instead:  k_r + d hits
    # [b_lo, b_hi] for some d in [d_lo, d_hi] iff k_r in
    # [b_lo - d_hi, b_hi - d_lo] — the same overlap test the chain applies.
    y_lo = np.where(abs_a, np.maximum(a_vlo, b_klo), np.int64(0))
    y_hi = np.where(abs_a, np.minimum(a_vhi, b_khi), np.int64(0))
    valid = ~np.any(abs_a & (y_lo > y_hi), axis=1)
    for j in range(mid):
        rows = np.nonzero(~abs_a[:, j])[0]
        if rows.size == 0:
            continue
        r = a_ref[rows, j]
        kl[rows, r] = np.maximum(kl[rows, r], b_klo[rows, j] - a_vhi[rows, j])
        kh[rows, r] = np.minimum(kh[rows, r], b_khi[rows, j] - a_vlo[rows, j])
    valid &= np.all(kl <= kh, axis=1)

    # Z pass.  Copy absolute B attrs; re-root B attrs referencing an
    # absolute Y onto the (exact) intermediate interval; chain deltas for
    # B attrs referencing a relative Y.
    b_ref = np.asarray(B.val_ref, np.int64)[bi]
    out_lo = np.asarray(B.val_lo, np.int64)[bi].copy()
    out_hi = np.asarray(B.val_hi, np.int64)[bi].copy()
    out_ref = np.full((n_pairs, m2), -1, np.int64)
    for i in range(m2):
        refs = b_ref[:, i]
        for j in range(mid):
            jm = refs == j
            if not jm.any():
                continue
            aj = jm & abs_a[:, j]
            out_lo[aj, i] += y_lo[aj, j]
            out_hi[aj, i] += y_hi[aj, j]
            rj = np.nonzero(jm & ~abs_a[:, j])[0]
            if rj.size == 0:
                continue
            r = a_ref[rj, j]
            out_ref[rj, i] = r
            out_lo[rj, i] += a_vlo[rj, j]
            out_hi[rj, i] += a_vhi[rj, j]
            # A non-point delta composes exactly only when the tightened
            # key's whole image lands inside B's key box — otherwise the
            # chain's clamp cuts cells the composed row would keep.
            spread = np.nonzero(
                (a_vlo[rj, j] != a_vhi[rj, j]) & valid[rj]
            )[0]
            if spread.size:
                rs, rr = rj[spread], r[spread]
                img_lo = kl[rs, rr] + a_vlo[rs, j]
                img_hi = kh[rs, rr] + a_vhi[rs, j]
                if np.any(img_lo < b_klo[rs, j]) or np.any(
                    img_hi > b_khi[rs, j]
                ):
                    raise CompositionError(
                        "relative interval delta escapes the next hop's "
                        "key box; route is not exactly composable"
                    )
    if not valid.any():
        return _empty_table(A.key_shape, B.val_shape, l, m2, direction)
    packed = np.concatenate(
        [kl[valid], kh[valid], out_lo[valid], out_hi[valid], out_ref[valid]],
        axis=1,
    )
    packed = _unique_rows(packed)
    if max_rows is not None and packed.shape[0] > max_rows:
        raise CompositionError(
            f"composed relation has {packed.shape[0]} rows > budget {max_rows}"
        )
    kl, kh = packed[:, :l], packed[:, l : 2 * l]
    off = 2 * l
    return CompressedTable(
        A.key_shape,
        B.val_shape,
        kl,
        kh,
        packed[:, off : off + m2],
        packed[:, off + m2 : off + 2 * m2],
        packed[:, off + 2 * m2 :],
        direction=direction,
    )


def compose_route(
    tables: list[CompressedTable],
    max_rows: int | None = None,
    direction: str = "backward",
) -> CompressedTable:
    """Fold a chain of hop tables (in composition order) into one."""
    if not tables:
        raise CompositionError("empty route")
    out = tables[0]
    for nxt in tables[1:]:
        out = compose_tables(out, nxt, max_rows, direction)
    return out


def _concat_tables(tables: list[CompressedTable]) -> CompressedTable:
    """Row-concatenate same-schema tables (parallel entries on one hop,
    or per-path composed relations over one route)."""
    if len(tables) == 1:
        return tables[0]
    first = tables[0]
    for t in tables[1:]:
        if t.key_shape != first.key_shape or t.val_shape != first.val_shape:
            raise CompositionError("hop tables disagree on shapes")
        if t.is_symbolic:
            raise CompositionError("symbolic tables do not compose")
    return CompressedTable(
        first.key_shape,
        first.val_shape,
        np.concatenate([t.key_lo for t in tables]),
        np.concatenate([t.key_hi for t in tables]),
        np.concatenate([t.val_lo for t in tables]),
        np.concatenate([t.val_hi for t in tables]),
        np.concatenate([np.asarray(t.val_ref, np.int64) for t in tables]),
        direction=first.direction,
    )


def _dedup_table(t: CompressedTable) -> CompressedTable:
    if t.n_rows <= 1:
        return t
    packed = _unique_rows(
        np.concatenate(
            [t.key_lo, t.key_hi, t.val_lo, t.val_hi,
             np.asarray(t.val_ref, np.int64)],
            axis=1,
        )
    )
    l, m = t.n_key, t.n_val
    return CompressedTable(
        t.key_shape,
        t.val_shape,
        packed[:, :l],
        packed[:, l : 2 * l],
        packed[:, 2 * l : 2 * l + m],
        packed[:, 2 * l + m : 2 * l + 2 * m],
        packed[:, 2 * l + 2 * m :],
        direction=t.direction,
    )


# --------------------------------------------------------------------------- #
# Materialized views
# --------------------------------------------------------------------------- #
class MaterializedView:
    """One composed route relation, stored like a lineage entry.

    ``src``/``dst`` are in dataflow order (``src`` upstream).  The
    backward table maps dst cells to src cells; ``fwd`` (when every hop
    had a forward table) maps src to dst.  ``lids``/``arrays`` are the
    route's closure, consulted by precise invalidation; ``lsns`` snapshots
    every WAL's end LSN at composition time, so ``fsck`` can prove a
    manifest-listed view predates no surviving invalidation record.
    """

    __slots__ = (
        "view_id", "src", "dst", "lids", "arrays",
        "_bwd", "_fwd", "lsns", "last_use", "_entry", "_rec",
    )

    def __init__(self, view_id, src, dst, lids, arrays, bwd, fwd, lsns):
        self.view_id = int(view_id)
        self.src = src
        self.dst = dst
        self.lids = frozenset(int(x) for x in lids)
        self.arrays = frozenset(arrays)
        self._bwd = bwd
        self._fwd = fwd
        self.lsns = dict(lsns)
        self.last_use = 0
        self._entry = None
        self._rec = None  # cached manifest record once the blobs are on disk

    @property
    def backward(self) -> CompressedTable:
        if isinstance(self._bwd, TableHandle):
            return self._bwd.get()
        return self._bwd

    @property
    def forward(self) -> CompressedTable | None:
        if isinstance(self._fwd, TableHandle):
            return self._fwd.get()
        return self._fwd

    @property
    def backward_rows(self) -> int:
        if isinstance(self._bwd, TableHandle):
            return self._bwd.rows
        return self._bwd.n_rows

    @property
    def forward_rows(self) -> int | None:
        if self._fwd is None:
            return None
        if isinstance(self._fwd, TableHandle):
            return self._fwd.rows
        return self._fwd.n_rows

    @property
    def total_rows(self) -> int:
        return self.backward_rows + (self.forward_rows or 0)

    def __repr__(self) -> str:
        return (
            f"MaterializedView(id={self.view_id}, {self.src!r}->{self.dst!r}, "
            f"rows={self.backward_rows}, lids={sorted(self.lids)})"
        )


class ViewManager:
    """Views + answer cache + heat tracking + precise invalidation.

    One per store (``DSLog`` and the ``ShardedDSLog`` facade each own
    one); all state lives behind ``views._lock`` (rank 15 in
    ``tools/lockorder.py`` — below the table and stats locks it takes
    while composing, above the shard-load lock that may hold it).
    """

    def __init__(
        self,
        log: "DSLog | ShardedDSLog",
        *,
        enabled: bool = True,
        admit_after: float = 3.0,
        heat_decay: float = 0.85,
        budget_rows: int = 250_000,
        max_view_rows: int = 100_000,
        max_paths: int = 8,
        cache_capacity: int = 256,
        persist_cache: int = 64,
    ):
        self.log = log
        self.enabled = enabled
        self.admit_after = float(admit_after)
        self.heat_decay = float(heat_decay)
        self.budget_rows = int(budget_rows)
        self.max_view_rows = int(max_view_rows)
        self.max_paths = int(max_paths)
        self.cache_capacity = int(cache_capacity)
        self.persist_cache = int(persist_cache)
        self._lock = _locks.new_rlock("views._lock")
        self.views: dict[int, MaterializedView] = _locks.guard_mapping(
            {}, self._lock, "ViewManager.views"
        )
        self._by_route: dict[tuple[str, str], int] = _locks.guard_mapping(
            {}, self._lock, "ViewManager._by_route"
        )
        self._heat: dict[tuple[str, str], float] = _locks.guard_mapping(
            {}, self._lock, "ViewManager._heat"
        )
        # routes proven non-composable (or over budget): don't retry until
        # the topology changes
        self._uncomposable: dict[tuple[str, str], bool] = _locks.guard_mapping(
            {}, self._lock, "ViewManager._uncomposable"
        )
        # answer cache: insertion-ordered dict doubling as the LRU list
        self._cache: dict[tuple, dict] = _locks.guard_mapping(
            {}, self._lock, "ViewManager._cache"
        )
        # route-plan memo: plans are cell-independent, so a hot route's
        # winning plan (view shortcut or not) is reused verbatim until any
        # invalidation, admission, or demotion changes the race
        self._plans: dict[tuple, tuple] = _locks.guard_mapping(
            {}, self._lock, "ViewManager._plans"
        )
        # EMA'd selectivity feedback for view hops (pseudo ids never reach
        # the store's hop_stats, whose keys shard by owning entry)
        self._hops: dict[tuple, list[float]] = _locks.guard_mapping(
            {}, self._lock, "ViewManager._hops"
        )
        self._next_id = 0
        self._tick = 0
        self._dirty = False  # view set / invalidation state changed
        # bumped by every invalidation event; a composition that started
        # under an older epoch is discarded instead of admitted
        self._epoch = 0

    # ------------------------------------------------------------------ #
    @property
    def dirty(self) -> bool:
        """View set (or an invalidation that purged cached answers)
        changed since the last manifest chunk was taken."""
        return self._dirty

    def _bump(self, key: str, n: int = 1) -> None:
        self.log._bump(key, n)

    def _lsns(self) -> dict[str, int]:
        fn = getattr(self.log, "_view_lsns", None)
        return fn() if fn is not None else {}

    # ------------------------------------------------------------------ #
    # Planner surface
    # ------------------------------------------------------------------ #
    def shortcut_for(self, src: str, dst: str) -> int | None:
        """Pseudo lineage id of a live view covering src->dst (either
        orientation), or None."""
        if not self.enabled:
            return None
        with self._lock:
            vid = self._by_route.get((src, dst))
            if vid is None:
                vid = self._by_route.get((dst, src))
            if vid is None:
                return None
            self._tick += 1
            self.views[vid].last_use = self._tick
            return view_pseudo_id(vid)

    def entry_for(self, pseudo_id: int):
        """A real :class:`~repro.core.catalog.LineageEntry` over the view's
        tables, so every planner/executor path works unchanged."""
        from .catalog import LineageEntry  # deferred: catalog imports us

        with self._lock:
            view = self.views[view_id_of(pseudo_id)]
            if view._entry is None:
                view._entry = LineageEntry(
                    pseudo_id,
                    view.src,
                    view.dst,
                    view._bwd,
                    view._fwd,
                    op_name=f"view#{view.view_id}",
                )
            return view._entry

    def record_hop(self, lineage_id, stored, frontier_on, pairs, qrows):
        decay = getattr(self.log, "hop_decay", 0.9)
        with self._lock:
            st = self._hops.setdefault(
                (lineage_id, stored, frontier_on), [0.0, 0.0]
            )
            st[0] = st[0] * decay + float(pairs)
            st[1] = st[1] * decay + float(qrows)

    def hop_measurement(self, lineage_id, stored, frontier_on):
        with self._lock:
            st = self._hops.get((lineage_id, stored, frontier_on))
        if not st or st[1] <= 0:
            return None
        return st[0] / st[1]

    # ------------------------------------------------------------------ #
    # Route-plan memo
    # ------------------------------------------------------------------ #
    _PLAN_MEMO_CAP = 64

    def plan_get(self, src: str, targets: list[str], batched):
        """A memoized plan for this route, or None.  Replays the view-race
        stat the original planning pass recorded and touches the view's
        LRU slot, so memo hits age views exactly like planned hits."""
        if not self.enabled:
            return None
        key = (src, tuple(targets), batched)
        with self._lock:
            hit = self._plans.get(key)
            if hit is None:
                return None
            self._plans.pop(key)
            self._plans[key] = hit  # LRU touch
        plan, stat, route = hit
        if stat is not None:
            self._bump(stat)
        if route is not None:
            self.shortcut_for(*route)  # keeps the view warm for eviction
        return plan

    def plan_put(self, src: str, targets: list[str], batched, plan) -> None:
        if not self.enabled:
            return
        uses_view = any(
            is_view_id(c.lineage_id)
            for steps in plan.steps.values()
            for s in steps
            for c in s.choices
        )
        stat = route = None
        if uses_view:
            stat, route = "view_hits", (src, targets[0])
        elif len(targets) == 1 and self.shortcut_for(src, targets[0]):
            stat = "view_misses"
        key = (src, tuple(targets), batched)
        with self._lock:
            self._plans[key] = (plan, stat, route)
            while len(self._plans) > self._PLAN_MEMO_CAP:
                self._plans.pop(next(iter(self._plans)))

    # ------------------------------------------------------------------ #
    # Heat-driven admission
    # ------------------------------------------------------------------ #
    def _normalize_route(self, a: str, b: str) -> tuple[str, str] | None:
        g = self.log.graph
        if g.has_path(a, b):
            return (a, b)
        if g.has_path(b, a):
            return (b, a)
        return None

    def note_route(self, src: str, targets: list[str]) -> None:
        """Feed one query's route into the heat tracker; materialize when
        a route crosses the admission threshold."""
        if not self.enabled or len(targets) != 1 or targets[0] == src:
            return
        route = self._normalize_route(src, targets[0])
        if route is None:
            return
        with self._lock:
            heat = self._heat.get(route, 0.0) * self.heat_decay + 1.0
            self._heat[route] = heat
            if (
                heat < self.admit_after
                or route in self._by_route
                or route in self._uncomposable
            ):
                return
        self._materialize(route)

    def _materialize(self, route: tuple[str, str]) -> MaterializedView | None:
        """Compose one route and admit the result.

        Composition runs *outside* ``views._lock``: resolving entries may
        lazily load shard manifests and table blobs (which take their own,
        lower-ranked locks).  LSNs and an invalidation epoch are captured
        first; if any invalidation lands while composing, the stale result
        is discarded instead of admitted.
        """
        src, dst = route
        g = self.log.graph
        with self._lock:
            epoch = self._epoch
        lsns = self._lsns()
        paths = g.simple_paths([src], [dst], max_paths=self.max_paths + 1)
        if not paths or len(paths) > self.max_paths:
            with self._lock:
                self._uncomposable[route] = True
            return None
        if all(len(p) == 2 for p in paths):
            return None  # direct edges only: a view would not shorten it
        lids: set[int] = set()
        arrays: set[str] = set()
        bwd_parts: list[CompressedTable] = []
        fwd_parts: list[CompressedTable] = []
        all_forward = True
        try:
            for path in paths:
                arrays.update(path)
                hop_entries = []
                for u, v in zip(path, path[1:]):
                    ids = g.edge_ids(u, v)
                    entries = [self.log.lineage[lid] for lid in ids]
                    lids.update(ids)
                    hop_entries.append(entries)
                btabs = [
                    _concat_tables([e.backward for e in entries])
                    for entries in reversed(hop_entries)
                ]
                bwd_parts.append(
                    compose_route(btabs, self.max_view_rows, "backward")
                )
                if all_forward and all(
                    e.has_forward for es in hop_entries for e in es
                ):
                    ftabs = [
                        _concat_tables([e.forward for e in entries])
                        for entries in hop_entries
                    ]
                    fwd_parts.append(
                        compose_route(ftabs, self.max_view_rows, "forward")
                    )
                else:
                    all_forward = False
            bwd = _dedup_table(_concat_tables(bwd_parts))
            fwd = (
                _dedup_table(_concat_tables(fwd_parts)) if all_forward else None
            )
        except (CompositionError, KeyError):
            # KeyError: an entry on the route was dropped mid-compose
            with self._lock:
                self._uncomposable[route] = True
            return None
        total = bwd.n_rows + (fwd.n_rows if fwd is not None else 0)
        if total > self.max_view_rows:
            with self._lock:
                self._uncomposable[route] = True
            return None
        with self._lock:
            if self._epoch != epoch or route in self._by_route:
                return None  # invalidation (or a racing admit) won
            self._evict_for(total)
            vid = self._next_id
            self._next_id += 1
            view = MaterializedView(vid, src, dst, lids, arrays, bwd, fwd, lsns)
            self._tick += 1
            view.last_use = self._tick
            self.views[vid] = view
            self._by_route[route] = vid
            self.log.graph.add_shortcut(src, dst, view_pseudo_id(vid))
            self._plans.clear()  # the race has a new contender
            self._dirty = True
        self._bump("views_materialized")
        return view

    def _evict_for(self, incoming_rows: int) -> None:
        """LRU-demote the coldest views until the budget fits (lock held)."""
        total = sum(v.total_rows for v in self.views.values())
        while self.views and total + incoming_rows > self.budget_rows:
            vid = min(self.views, key=lambda k: self.views[k].last_use)
            total -= self.views[vid].total_rows
            self._remove_view(vid, count=False)
            self._bump("views_demoted")

    def _remove_view(self, vid: int, count: bool = True) -> None:
        view = self.views.pop(vid)
        self._by_route.pop((view.src, view.dst), None)
        self.log.graph.remove_shortcut(view.src, view.dst)
        stale = [k for k in self._hops if k[0] == view_pseudo_id(vid)]
        for k in stale:
            del self._hops[k]
        self._plans.clear()  # memoized plans may reference the dead view
        self._dirty = True
        if count:
            self._bump("views_invalidated")

    # ------------------------------------------------------------------ #
    # Answer cache
    # ------------------------------------------------------------------ #
    def cache_key(self, src, targets, boxes, merge) -> tuple | None:
        """Stable key for one batch: canonical-ish cell boxes per query.

        Only merged (canonical-form) answers are cached; ``merge=False``
        callers get raw per-hop boxes the cache does not model."""
        if not self.enabled or not merge:
            return None
        parts = []
        for q in boxes:
            mb = merge_boxes(q)
            parts.append((mb.shape, mb.lo.tobytes(), mb.hi.tobytes()))
        return (src, tuple(targets), tuple(parts))

    def cache_get(self, key: tuple):
        with self._lock:
            hit = self._cache.get(key)
            if hit is None:
                self._bump("cache_misses")
                return None
            # LRU touch: re-insert at the ordered dict's tail
            del self._cache[key]
            self._cache[key] = hit
            self._bump("cache_hits")
            return {
                name: [QueryBox(b.shape, b.lo.copy(), b.hi.copy()) for b in bl]
                for name, bl in hit["answer"].items()
            }

    def cache_put(self, key: tuple, out: dict, src, targets, plan) -> None:
        if not self.enabled:
            return
        lids: set[int] = set()
        for step_list in plan.steps.values():
            for step in step_list:
                for choice in step.choices:
                    lid = choice.lineage_id
                    if is_view_id(lid):
                        with self._lock:
                            view = self.views.get(view_id_of(lid))
                        lids.update(view.lids if view is not None else ())
                    else:
                        lids.add(lid)
        entry = {
            "answer": {
                name: [QueryBox(b.shape, b.lo.copy(), b.hi.copy()) for b in bl]
                for name, bl in out.items()
            },
            "lids": lids,
            "src": src,
            "targets": tuple(targets),
            "arrays": set(plan.node_array.values()),
        }
        with self._lock:
            self._cache.pop(key, None)
            self._cache[key] = entry
            while len(self._cache) > self.cache_capacity:
                del self._cache[next(iter(self._cache))]

    # ------------------------------------------------------------------ #
    # WAL-precise invalidation
    # ------------------------------------------------------------------ #
    def on_mutation(self, lineage_id: int) -> None:
        """A ``dirty`` or ``drop`` event on one entry: kill exactly the
        views and cached answers whose route includes it."""
        with self._lock:
            self._epoch += 1
            # memoized plans may route through the mutated entry
            self._plans.clear()
            dead = [
                vid for vid, v in self.views.items() if lineage_id in v.lids
            ]
            for vid in dead:
                self._remove_view(vid)
            stale = [
                k for k, e in self._cache.items() if lineage_id in e["lids"]
            ]
            for k in stale:
                del self._cache[k]
            if stale:
                self._dirty = True
            self._uncomposable.clear()  # the topology/blobs changed

    def on_new_edge(self, src: str, dst: str) -> None:
        """A new ``entry`` event: kill views and answers whose route the
        new edge lands on (an endpoint upstream of ``src`` and one
        downstream of ``dst``)."""
        g = self.log.graph
        with self._lock:
            self._epoch += 1
            # a new edge can open routes a memoized plan never traverses,
            # so the memo dies even when no views or answers are live
            self._plans.clear()
            if not self.views and not self._cache:
                self._uncomposable.clear()
                return
            up = g.reachable([src], "backward")
            down = g.reachable([dst], "forward")
            dead = [
                vid
                for vid, v in self.views.items()
                if v.src in up and v.dst in down
            ]
            for vid in dead:
                self._remove_view(vid)
            stale = [
                k
                for k, e in self._cache.items()
                if any(
                    (e["src"] in up and t in down)
                    or (t in up and e["src"] in down)
                    for t in e["targets"]
                )
            ]
            for k in stale:
                del self._cache[k]
            if stale:
                self._dirty = True
            self._uncomposable.clear()

    def invalidate_all(self) -> None:
        with self._lock:
            self._epoch += 1
            self._plans.clear()
            for vid in list(self.views):
                self._remove_view(vid)
            if self._cache:
                self._dirty = True
            self._cache.clear()
            self._uncomposable.clear()

    # ------------------------------------------------------------------ #
    # Persistence (blobs through the owning store's durable writers)
    # ------------------------------------------------------------------ #
    def manifest_chunk(self, write_blob) -> dict:
        """Manifest record of every live view; ``write_blob(fn, table)``
        persists a blob durably.  Marks the manager clean."""
        # Snapshot which views still need blobs, then compose and write
        # them *outside* the lock: ``write_blob`` fsyncs and
        # ``view.backward`` may decode a table blob from disk, and every
        # reader would serialise behind that latency if it ran under
        # ``views._lock``.  Views are immutable once composed and each
        # blob is written exactly once, so no lock is needed while
        # writing; a view removed concurrently just leaves an
        # unreferenced blob for ``compact()`` to vacuum.
        with self._lock:
            pending = [
                (vid, self.views[vid])
                for vid in sorted(self.views)
                if self.views[vid]._rec is None
            ]
        written: dict[int, dict] = {}
        for vid, view in pending:
            fn = f"view_{vid}.prvc"
            write_blob(fn, view.backward)
            rec = {
                "id": vid,
                "src": view.src,
                "dst": view.dst,
                "lids": sorted(view.lids),
                "arrays": sorted(view.arrays),
                "file": fn,
                "rows": view.backward_rows,
                "fwd": None,
                "fwd_rows": None,
                "lsns": dict(view.lsns),
            }
            if view._fwd is not None:
                fwd_fn = f"view_{vid}_fwd.prvc"
                write_blob(fwd_fn, view.forward)
                rec["fwd"] = fwd_fn
                rec["fwd_rows"] = view.forward_rows
            written[vid] = rec
        with self._lock:
            recs = []
            clean = True
            for vid in sorted(self.views):
                view = self.views[vid]
                if view._rec is None:
                    rec = written.get(vid)
                    if rec is None:
                        # admitted after the snapshot: its blob is not on
                        # disk yet, so it stays out of this manifest and
                        # the manager stays dirty for the next save
                        clean = False
                        continue
                    view._rec = rec
                recs.append(view._rec)
            self._dirty = not clean
            return {"next_id": self._next_id, "views": recs}

    def load_chunk(self, chunk: dict, make_handle) -> None:
        """Restore views from a manifest chunk; ``make_handle(fn, rows)``
        returns a lazy :class:`~repro.core.table.TableHandle`."""
        if not chunk:
            return
        with self._lock:
            self._next_id = int(chunk.get("next_id", 0))
            for rec in chunk.get("views", []):
                vid = int(rec["id"])
                bwd = make_handle(rec["file"], rec.get("rows"))
                fwd = (
                    make_handle(rec["fwd"], rec.get("fwd_rows"))
                    if rec.get("fwd")
                    else None
                )
                view = MaterializedView(
                    vid,
                    rec["src"],
                    rec["dst"],
                    rec["lids"],
                    rec["arrays"],
                    bwd,
                    fwd,
                    {k: int(v) for k, v in rec.get("lsns", {}).items()},
                )
                view._rec = dict(rec)
                self.views[vid] = view
                self._by_route[(view.src, view.dst)] = vid
                self.log.graph.add_shortcut(
                    view.src, view.dst, view_pseudo_id(vid)
                )
            self._dirty = False

    def blob_files(self) -> set[str]:
        with self._lock:
            out = set()
            for vid, view in self.views.items():
                out.add(f"view_{vid}.prvc")
                if view._fwd is not None:
                    out.add(f"view_{vid}_fwd.prvc")
            return out

    def cache_chunk(self) -> dict:
        """JSON-able sidecar of the most recent cached answers."""
        with self._lock:
            keys = list(self._cache)[-self.persist_cache :]
            entries = []
            for key in keys:
                e = self._cache[key]
                src, targets, parts = key
                entries.append(
                    {
                        "src": src,
                        "targets": list(targets),
                        "queries": [
                            {
                                "shape": list(shape),
                                "lo": np.frombuffer(lo, np.int64)
                                .reshape(-1, len(shape))
                                .tolist(),
                                "hi": np.frombuffer(hi, np.int64)
                                .reshape(-1, len(shape))
                                .tolist(),
                            }
                            for shape, lo, hi in parts
                        ],
                        "answer": {
                            name: [
                                {
                                    "shape": list(b.shape),
                                    "lo": b.lo.tolist(),
                                    "hi": b.hi.tolist(),
                                }
                                for b in bl
                            ]
                            for name, bl in e["answer"].items()
                        },
                        "lids": sorted(e["lids"]),
                        "arrays": sorted(e["arrays"]),
                    }
                )
            return {"entries": entries}

    def load_cache_chunk(self, chunk: dict) -> None:
        if not chunk:
            return

        def box(rec) -> QueryBox:
            shape = tuple(rec["shape"])
            lo = np.asarray(rec["lo"], np.int64).reshape(-1, len(shape))
            hi = np.asarray(rec["hi"], np.int64).reshape(-1, len(shape))
            return QueryBox(shape, lo, hi)

        with self._lock:
            for e in chunk.get("entries", []):
                key = (
                    e["src"],
                    tuple(e["targets"]),
                    tuple(
                        (
                            tuple(q["shape"]),
                            np.asarray(q["lo"], np.int64).tobytes(),
                            np.asarray(q["hi"], np.int64).tobytes(),
                        )
                        for q in e["queries"]
                    ),
                )
                self._cache[key] = {
                    "answer": {
                        name: [box(r) for r in bl]
                        for name, bl in e["answer"].items()
                    },
                    "lids": set(int(x) for x in e["lids"]),
                    "src": e["src"],
                    "targets": tuple(e["targets"]),
                    "arrays": set(e["arrays"]),
                }
            while len(self._cache) > self.cache_capacity:
                del self._cache[next(iter(self._cache))]

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        with self._lock:
            return {
                "views": len(self.views),
                "view_rows": sum(v.total_rows for v in self.views.values()),
                "cached_answers": len(self._cache),
                "hot_routes": sum(
                    1 for h in self._heat.values() if h >= self.admit_after
                ),
            }
