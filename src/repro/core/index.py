"""Interval index: sorted candidate pruning for the θ-join (paper §V at scale).

The range join of §V.B.1 asks, for every query box and table row, whether the
key intervals overlap on all attributes.  The dense formulation materializes
an ``nq × nr`` pair matrix — fine for small tables, hopeless at catalog scale.
This module provides the indexed alternative:

For each attribute ``j`` the rows are sorted by ``lo[:, j]`` and we keep the
*running maximum* of ``hi`` in that order.  A probe interval ``[qlo, qhi]``
then locates its candidate window with two binary searches:

* ``end   = searchsorted(sorted_lo, qhi, 'right')`` — rows past ``end`` start
  after the probe ends, so they cannot overlap;
* ``start = searchsorted(run_max_hi, qlo, 'left')`` — ``run_max_hi`` is
  non-decreasing, and every row before ``start`` has ``hi < qlo`` (its prefix
  maximum is below ``qlo``), so none of them can overlap either.

Everything in ``order[start:end]`` is a candidate; the exact conjunction over
*all* attributes is then verified on the (small) candidate set only.  Per
query row we probe every attribute, take the window sizes as a selectivity
estimate, and enumerate only the most selective attribute's window — a
one-attribute cost model that needs no statistics beyond the index itself.

The index is pure numpy, serializable (only the sort permutations are stored;
the gathered/sorted copies are rebuilt in O(n) on attach), and is cached on
:class:`~repro.core.table.CompressedTable` / persisted by the catalog.
"""

from __future__ import annotations

import io
import json

import numpy as np

__all__ = ["IntervalIndex", "interval_stats", "ragged_ranges"]

_IDX_MAGIC = b"PRVCIDX1\n"


def ragged_ranges(
    starts: np.ndarray, ends: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Enumerate ``[starts[i], ends[i])`` for every i, fully vectorized.

    Returns ``(owner, pos)`` where ``pos`` concatenates the ranges and
    ``owner[k]`` is the ``i`` that range element ``pos[k]`` came from.
    """
    counts = np.maximum(ends - starts, 0).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    owner = np.repeat(np.arange(counts.size, dtype=np.int64), counts)
    base = np.cumsum(counts) - counts  # offset of each range in the output
    pos = np.arange(total, dtype=np.int64) - base[owner] + starts.astype(np.int64)[owner]
    return owner, pos


def interval_stats(
    lo: np.ndarray, hi: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Per-attribute ``(mean interval length, covered span)`` of a column set.

    The planner's cost model turns these two numbers into an overlap
    probability per attribute (``(Lq + Lr) / span``, clamped to 1): the
    chance that a random query interval of mean length ``Lq`` meets a random
    stored interval of mean length ``Lr`` inside the covered span.  Exact
    per-frontier estimates come from :meth:`IntervalIndex.estimate_candidates`;
    these closed-form stats are for hops whose frontier does not exist yet at
    planning time.
    """
    lo = np.asarray(lo, np.int64)
    hi = np.asarray(hi, np.int64)
    if lo.ndim != 2 or lo.shape != hi.shape:
        raise ValueError(f"bad interval columns: {lo.shape} vs {hi.shape}")
    if lo.shape[0] == 0:
        n_attrs = lo.shape[1]
        return np.ones(n_attrs), np.ones(n_attrs)
    mean_len = (hi - lo + 1).mean(axis=0)
    span = np.maximum(hi.max(axis=0) - lo.min(axis=0) + 1, 1)
    return mean_len.astype(float), span.astype(float)


class IntervalIndex:
    """Per-attribute sorted interval index over ``[lo, hi]`` columns.

    Parameters
    ----------
    lo, hi : ``[n_rows, n_attrs]`` int64 closed interval bounds.
    order  : optional precomputed ``[n_attrs, n_rows]`` sort permutations
             (used when attaching a persisted index; skips the O(n log n)
             argsorts and only pays the O(n) gathers).
    """

    def __init__(
        self, lo: np.ndarray, hi: np.ndarray, order: np.ndarray | None = None
    ):
        lo = np.asarray(lo, np.int64)
        hi = np.asarray(hi, np.int64)
        if lo.ndim != 2 or lo.shape != hi.shape:
            raise ValueError(f"bad interval columns: {lo.shape} vs {hi.shape}")
        self.lo, self.hi = lo, hi
        self.n_rows, self.n_attrs = lo.shape
        supplied = order is not None
        if order is None:
            order = np.stack(
                [np.argsort(lo[:, j], kind="stable") for j in range(self.n_attrs)]
            ) if self.n_attrs else np.zeros((0, self.n_rows), np.int64)
        self.order = np.asarray(order, np.int64).reshape(self.n_attrs, self.n_rows)
        if supplied:
            self._validate_order()
        # gathered copies in sort order + prefix running max of hi
        self.sorted_lo = [lo[self.order[j], j] for j in range(self.n_attrs)]
        self.run_max_hi = [
            np.maximum.accumulate(hi[self.order[j], j]) for j in range(self.n_attrs)
        ]

    def _validate_order(self) -> None:
        """Reject a supplied permutation that does not fit these bounds.

        A persisted sidecar can be stale (written for a previous version of
        the table) or corrupt; attaching it unchecked would silently drop
        overlap candidates.  Raising ``ValueError`` here triggers the
        caller's lazy-rebuild fallback instead.
        """
        o = self.order
        if o.size and ((o < 0).any() or (o >= self.n_rows).any()):
            raise ValueError("index permutation out of range for table")
        for j in range(self.n_attrs):
            if np.bincount(o[j], minlength=self.n_rows).max(initial=0) > 1:
                raise ValueError("index order is not a permutation")
            if (np.diff(self.lo[o[j], j]) < 0).any():
                raise ValueError("index order does not sort the table's lo bounds")

    # ------------------------------------------------------------------ #
    # probing
    # ------------------------------------------------------------------ #
    def probe_windows(
        self, q_lo: np.ndarray, q_hi: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """Candidate window ``[start, end)`` per (query row, attribute).

        Both are ``[nq, n_attrs]``; the window over ``order[j]`` is a superset
        of the rows whose attribute-``j`` interval overlaps the probe.
        """
        nq = q_lo.shape[0]
        starts = np.empty((nq, self.n_attrs), np.int64)
        ends = np.empty((nq, self.n_attrs), np.int64)
        for j in range(self.n_attrs):
            ends[:, j] = np.searchsorted(self.sorted_lo[j], q_hi[:, j], "right")
            starts[:, j] = np.searchsorted(self.run_max_hi[j], q_lo[:, j], "left")
        np.minimum(starts, ends, out=starts)
        return starts, ends

    def estimate_candidates(
        self,
        q_lo: np.ndarray,
        q_hi: np.ndarray,
        windows: tuple[np.ndarray, np.ndarray] | None = None,
    ) -> int:
        """Upper bound on candidate pairs if each row probes its best attr."""
        if q_lo.shape[0] == 0 or self.n_rows == 0:
            return 0
        if self.n_attrs == 0:
            return q_lo.shape[0] * self.n_rows
        starts, ends = windows if windows is not None else self.probe_windows(q_lo, q_hi)
        return int((ends - starts).min(axis=1).sum())

    def candidate_pairs(
        self,
        q_lo: np.ndarray,
        q_hi: np.ndarray,
        windows: tuple[np.ndarray, np.ndarray] | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Exact overlap pairs ``(qi, ri)`` (all attributes), lexsorted.

        Equivalent to ``np.nonzero`` of the dense overlap matrix, but the
        work is proportional to the most selective attribute's candidate
        window per query row, not ``nq × nr``.  Pass ``windows`` (from
        :meth:`probe_windows`) to reuse a probe pass already paid for.
        """
        nq = q_lo.shape[0]
        if nq == 0 or self.n_rows == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        if self.n_attrs == 0:  # 0-d keys: every (q, r) pair matches
            qi = np.repeat(np.arange(nq, dtype=np.int64), self.n_rows)
            ri = np.tile(np.arange(self.n_rows, dtype=np.int64), nq)
            return qi, ri
        starts, ends = windows if windows is not None else self.probe_windows(q_lo, q_hi)
        best = np.argmin(ends - starts, axis=1)  # most selective attr per row
        qi_parts, ri_parts = [], []
        for j in range(self.n_attrs):
            rows = np.flatnonzero(best == j)
            if rows.size == 0:
                continue
            owner, pos = ragged_ranges(starts[rows, j], ends[rows, j])
            qi = rows[owner]
            ri = self.order[j][pos]
            ok = np.ones(qi.size, bool)
            for k in range(self.n_attrs):
                ok &= (q_lo[qi, k] <= self.hi[ri, k]) & (
                    self.lo[ri, k] <= q_hi[qi, k]
                )
            qi_parts.append(qi[ok])
            ri_parts.append(ri[ok])
        if not qi_parts:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        qi = np.concatenate(qi_parts)
        ri = np.concatenate(ri_parts)
        # match the dense path's np.nonzero ordering (row-major)
        perm = np.lexsort((ri, qi))
        return qi[perm], ri[perm]

    # ------------------------------------------------------------------ #
    # serialization (catalog sidecar files)
    # ------------------------------------------------------------------ #
    def to_bytes(self) -> bytes:
        """Persist only the permutations; bounds live with the table."""
        order = self.order
        packed = (
            order.astype(np.int32) if self.n_rows <= np.iinfo(np.int32).max else order
        )
        header = json.dumps(
            {
                "n_rows": self.n_rows,
                "n_attrs": self.n_attrs,
                "dtype": packed.dtype.str,
            }
        ).encode()
        buf = io.BytesIO()
        buf.write(_IDX_MAGIC)
        buf.write(len(header).to_bytes(4, "little"))
        buf.write(header)
        buf.write(np.ascontiguousarray(packed).tobytes())
        return buf.getvalue()

    @staticmethod
    def from_bytes(data: bytes, lo: np.ndarray, hi: np.ndarray) -> "IntervalIndex":
        """Attach a persisted index to its table's interval columns.

        Raises ``ValueError`` on magic/shape mismatch so callers can fall
        back to rebuilding from scratch.
        """
        if data[: len(_IDX_MAGIC)] != _IDX_MAGIC:
            raise ValueError("not a ProvRC index blob")
        off = len(_IDX_MAGIC)
        hlen = int.from_bytes(data[off : off + 4], "little")
        off += 4
        header = json.loads(data[off : off + hlen])
        off += hlen
        n_rows, n_attrs = header["n_rows"], header["n_attrs"]
        if (n_rows, n_attrs) != tuple(np.asarray(lo).shape):
            raise ValueError(
                f"index shape {(n_rows, n_attrs)} does not match table "
                f"{np.asarray(lo).shape}"
            )
        dt = np.dtype(header["dtype"])
        order = np.frombuffer(
            data, dtype=dt, count=n_rows * n_attrs, offset=off
        ).reshape(n_attrs, n_rows)
        return IntervalIndex(lo, hi, order=order.astype(np.int64))
