"""Write-ahead lineage log: append-only, checksummed, torn-tail tolerant.

One :class:`WriteAheadLog` backs one store directory (`wal.log`); a sharded
store keeps one per shard plus a root log.  The catalog appends a record
for every durable mutation — lineage entries (with their serialized
tables), op registrations, version mints, predictor observations, explicit
``mark_dirty`` invalidations, and drops — *before* the mutation is
reflected in any manifest.  Durability then costs one buffered ``write``
per record plus an fsync amortized by the
:class:`~repro.core.commit.CommitPipeline`'s group commit, instead of a
full manifest rewrite per entry.

On-disk format
--------------
::

    header:  b"DSWAL1\\n" | u64 base_lsn
    record:  u32 payload_len | u32 crc32(payload) | payload
    payload: u32 json_len | json meta (incl. "t" type, "nb" blob lengths)
             | blob_0 | blob_1 | ...

LSNs are byte offsets relative to the log's creation: ``base_lsn`` + file
offset.  A **checkpoint** (the catalog's incremental ``save()``) records
the current end LSN in the manifest and truncates the log back to a bare
header whose ``base_lsn`` is that end LSN — so LSNs stay monotonic across
truncations, and recovery can tell already-checkpointed records (LSN below
the manifest's ``wal_lsn``) from the tail that must be replayed.

Recovery (:meth:`WriteAheadLog.recover`) scans records sequentially and
stops at the first torn one — a short header, a short payload, or a crc
mismatch — truncating the file back to the last intact record boundary.
Every complete record before the tear survives; this is the prefix the
crash-recovery property test compares against the synchronous-save oracle.

Shared mode
-----------
``shared=True`` turns the log into a multi-writer append channel (the
sharded store's root log under concurrent non-exclusive writers): appends
buffer in memory and each flush takes an exclusive ``flock``, seeks to the
true end, writes the batch, fsyncs, and releases — so records from
concurrent writer processes interleave at record granularity, never
mid-record.  Shared logs are only truncated by an exclusive checkpoint.
"""

from __future__ import annotations

import json
import os
import struct
import time
import zlib
from typing import Iterator

from repro.obs.metrics import MetricsRegistry, StatsView

from . import _locks

try:  # POSIX advisory locks for shared-mode appends
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX fallback
    fcntl = None  # type: ignore[assignment]

__all__ = ["WalRecord", "WriteAheadLog", "WAL_FILENAME"]

WAL_FILENAME = "wal.log"

_MAGIC = b"DSWAL1\n"
_HEADER_SIZE = len(_MAGIC) + 8  # magic + u64 base_lsn
_REC_HEADER = struct.Struct("<II")  # payload_len, crc32


class WalRecord:
    """One decoded log record: a type tag, JSON-safe meta, binary blobs."""

    __slots__ = ("type", "meta", "blobs", "lsn")

    def __init__(self, rtype: str, meta: dict, blobs: list[bytes], lsn: int = 0):
        self.type = rtype
        self.meta = meta
        self.blobs = blobs
        self.lsn = lsn  # end LSN: the record is durable iff lsn <= flushed end

    def __repr__(self) -> str:
        return (
            f"WalRecord({self.type!r}, lsn={self.lsn}, "
            f"blobs={[len(b) for b in self.blobs]})"
        )


def _encode(rtype: str, meta: dict, blobs: list[bytes]) -> bytes:
    head = dict(meta)
    head["t"] = rtype
    head["nb"] = [len(b) for b in blobs]
    j = json.dumps(head).encode()
    payload = struct.pack("<I", len(j)) + j + b"".join(blobs)
    return _REC_HEADER.pack(len(payload), zlib.crc32(payload)) + payload


def _decode_payload(payload: bytes) -> WalRecord:
    (jlen,) = struct.unpack_from("<I", payload, 0)
    head = json.loads(payload[4 : 4 + jlen])
    rtype = head.pop("t")
    sizes = head.pop("nb")
    blobs = []
    off = 4 + jlen
    for n in sizes:
        blobs.append(payload[off : off + n])
        off += n
    return WalRecord(rtype, head, blobs)


class WriteAheadLog:
    """Append-only record log over one file, with torn-tail recovery.

    Exclusive mode (default) keeps the file handle open and tracks the end
    offset in memory; shared mode buffers appends and writes them under an
    ``flock`` so several processes can interleave whole records.
    """

    def __init__(self, path: str, shared: bool = False, metrics=None):
        self.path = path
        self.shared = bool(shared)
        self._lock = _locks.new_lock("wal._lock")
        self._pending: list[bytes] = []  # shared mode: unwritten records
        self._f = None
        self._end = _HEADER_SIZE  # exclusive mode: current file offset
        self._shared_good = _HEADER_SIZE  # shared mode: verified boundary
        self.base_lsn = 0
        # meters live in the (internally locked) registry — the owning
        # store's when attached, a private one for standalone logs; the
        # legacy ``wal.stats["records"]`` read surface is an alias view.
        if metrics is None:
            metrics = MetricsRegistry("wal")
        self.metrics = metrics
        metrics.seed_counters(
            ("wal_records", "wal_flushes", "wal_syncs", "wal_bytes")
        )
        self.stats = StatsView(
            metrics,
            {
                "records": "wal_records",
                "flushes": "wal_flushes",
                "syncs": "wal_syncs",
                "bytes": "wal_bytes",
            },
        )
        self._open()

    # ------------------------------------------------------------------ #
    def _open(self) -> None:
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        exists = os.path.exists(self.path)
        self._f = open(self.path, "r+b" if exists else "w+b")
        if self.shared:
            def init_shared():
                self._ensure_header()
                # last verified intact boundary; each flush re-verifies
                # only the records other writers appended since
                self._shared_good = self._boundary_from(_HEADER_SIZE)

            self._flocked(init_shared)
        else:
            self._ensure_header()
            # position appends at the last *intact* record boundary, never
            # blind EOF: after a torn write, new records overwrite the torn
            # bytes instead of being stranded behind them
            self._scan(2**62, [], truncate=False)

    def _ensure_header(self) -> None:
        self._f.seek(0, os.SEEK_END)
        if self._f.tell() < _HEADER_SIZE:
            self._f.seek(0)
            self._f.write(_MAGIC + struct.pack("<Q", 0))
            self._f.flush()
            self.base_lsn = 0
        else:
            self._f.seek(0)
            magic = self._f.read(len(_MAGIC))
            if magic != _MAGIC:
                raise ValueError(f"{self.path!r} is not a DSLog WAL")
            (self.base_lsn,) = struct.unpack("<Q", self._f.read(8))

    def _boundary_from(self, start: int) -> int:
        """Offset of the last intact record boundary at or after ``start``
        (call with the file/flock held as appropriate)."""
        self._f.seek(start)
        good = start
        while True:
            hdr = self._f.read(_REC_HEADER.size)
            if len(hdr) < _REC_HEADER.size:
                return good
            plen, crc = _REC_HEADER.unpack(hdr)
            payload = self._f.read(plen)
            if len(payload) < plen or zlib.crc32(payload) != crc:
                return good
            good += _REC_HEADER.size + plen

    def _flocked(self, fn):
        if fcntl is not None:
            fcntl.flock(self._f.fileno(), fcntl.LOCK_EX)
        try:
            return fn()
        finally:
            if fcntl is not None:
                fcntl.flock(self._f.fileno(), fcntl.LOCK_UN)

    # ------------------------------------------------------------------ #
    @property
    def end_lsn(self) -> int:
        """LSN one past the last appended record (pending included)."""
        if self.shared:
            size = os.path.getsize(self.path) if os.path.exists(self.path) else 0
            size = max(size, _HEADER_SIZE)
            return self.base_lsn + (size - _HEADER_SIZE) + sum(
                len(b) for b in self._pending
            )
        return self.base_lsn + (self._end - _HEADER_SIZE)

    @staticmethod
    def file_has_records(path: str) -> bool:
        """Whether a log file on disk holds any record bytes past its
        header (cheap stat — no open, no scan)."""
        try:
            return os.path.getsize(path) > _HEADER_SIZE
        except OSError:
            return False

    @staticmethod
    def file_end_lsn(path: str) -> int:
        """End LSN of a log file on disk without opening it as a live log.

        Read-only frame scan up to the last intact record boundary (a torn
        tail contributes nothing — recovery would discard it too).  Returns
        ``0`` for a missing or non-WAL file.  Used by stale-view checks
        that need a shard's LSN horizon without loading the shard.
        """
        try:
            with open(path, "rb") as f:
                if f.read(len(_MAGIC)) != _MAGIC:
                    return 0
                raw = f.read(8)
                if len(raw) < 8:
                    return 0
                (base,) = struct.unpack("<Q", raw)
                good = _HEADER_SIZE
                while True:
                    hdr = f.read(_REC_HEADER.size)
                    if len(hdr) < _REC_HEADER.size:
                        break
                    plen, crc = _REC_HEADER.unpack(hdr)
                    payload = f.read(plen)
                    if len(payload) < plen or zlib.crc32(payload) != crc:
                        break
                    good += _REC_HEADER.size + plen
                return base + (good - _HEADER_SIZE)
        except OSError:
            return 0

    @property
    def has_records(self) -> bool:
        if self._pending:
            return True
        if self.shared:
            return os.path.getsize(self.path) > _HEADER_SIZE
        return self._end > _HEADER_SIZE

    # ------------------------------------------------------------------ #
    def append(self, rtype: str, meta: dict, blobs: list[bytes] | tuple = ()) -> int:
        """Buffer one record; returns its end LSN.

        In shared mode the return value is ``-1``: concurrent writers move
        the true end, which is only pinned down at flush (computing it here
        would cost a stat syscall per record on the ingest hot path)."""
        data = _encode(rtype, meta, list(blobs))
        with self._lock:
            if self.shared:
                self._pending.append(data)
                lsn = -1
            else:
                self._f.seek(self._end)
                self._f.write(data)
                self._end += len(data)
                lsn = self.base_lsn + (self._end - _HEADER_SIZE)
            self.metrics.inc("wal_records")
            self.metrics.inc("wal_bytes", len(data))
            return lsn

    def flush(self, sync: bool = True) -> None:
        """Push buffered records to the OS (and optionally to disk).

        The fsync happens *outside* the append lock: a concurrent writer
        keeps appending (into the next batch) while this batch hardens —
        the property that makes group commit actually overlap ingest with
        disk latency instead of serializing behind it.
        """
        with self._lock:
            if self.shared and self._pending:
                batch, self._pending = self._pending, []

                def write_batch():
                    # append at the last *intact* record boundary, not
                    # blind EOF: a crashed writer's torn tail gets
                    # overwritten instead of stranding our fsynced records
                    # behind it (where the next exclusive repair() would
                    # discard them).  Only bytes appended since our last
                    # verification are re-scanned.
                    good = self._boundary_from(self._shared_good)
                    self._f.seek(good)
                    for data in batch:
                        self._f.write(data)
                    self._f.flush()
                    end = self._f.tell()
                    if end < os.path.getsize(self.path):
                        self._f.truncate(end)  # shrank past a long tear
                    self._shared_good = end

                # shared-mode appends MUST flock under wal._lock: the
                # flock serialises against *other processes* on the root
                # log, and releasing our own lock first would let a second
                # thread interleave a batch between boundary verification
                # and the write
                self._flocked(write_batch)  # dsflow: ignore[lock-fsync]
            else:
                self._f.flush()
            fd = self._f.fileno()
        self.metrics.inc("wal_flushes")
        if sync:
            t0 = time.perf_counter()
            os.fsync(fd)
            self.metrics.inc("wal_syncs")
            self.metrics.observe("wal_fsync_seconds", time.perf_counter() - t0)

    # ------------------------------------------------------------------ #
    def recover(self, min_lsn: int = 0, truncate: bool = False) -> list[WalRecord]:
        """Scan the log and return intact records whose end LSN is past
        ``min_lsn`` (the manifest's checkpoint LSN).

        Safe on a freshly created log (returns ``[]``).  The tear point is
        the first record with a short header, short payload, or crc
        mismatch; everything after it is ignored.  With ``truncate=True``
        the file is also cut back to the last intact boundary — pass that
        ONLY while holding the store's writer lease: a plain read-only
        ``load()`` must never mutate a log a live writer may be appending
        to (its in-flight record looks exactly like a torn tail).
        Exclusive-mode appends overwrite the torn region regardless (the
        write offset rewinds to the last intact boundary); physical
        truncation matters for the *shared* root log, whose appends seek to
        the file end.
        """
        out: list[WalRecord] = []
        with self._lock:
            if self.shared:
                # the scan must not race a concurrent appender in another
                # process; flock under wal._lock is the point of shared
                # mode (cold path: runs once per open, not per query)
                # dsflow: ignore[lock-fsync]
                return self._flocked(lambda: self._scan(min_lsn, out, truncate))
            return self._scan(min_lsn, out, truncate)

    def repair(self) -> None:
        """Truncate any torn tail (call only as the leased/exclusive owner)."""
        self.recover(min_lsn=2**62, truncate=True)

    def _scan(
        self, min_lsn: int, out: list[WalRecord], truncate: bool
    ) -> list[WalRecord]:
            self._f.flush()
            size = os.path.getsize(self.path)
            self._f.seek(_HEADER_SIZE)
            off = _HEADER_SIZE
            good = off
            while True:
                hdr = self._f.read(_REC_HEADER.size)
                if len(hdr) < _REC_HEADER.size:
                    break
                plen, crc = _REC_HEADER.unpack(hdr)
                payload = self._f.read(plen)
                if len(payload) < plen or zlib.crc32(payload) != crc:
                    break
                off += _REC_HEADER.size + plen
                good = off
                lsn = self.base_lsn + (good - _HEADER_SIZE)
                if lsn > min_lsn:
                    rec = _decode_payload(payload)
                    rec.lsn = lsn
                    out.append(rec)
            if truncate and good < size:  # torn tail: drop it
                self._f.truncate(good)
                self._f.flush()
            if not self.shared:
                # exclusive appends resume at the last intact boundary, so
                # torn bytes are overwritten even without truncation
                self._end = good
            return out

    def replay(self, min_lsn: int = 0) -> Iterator[WalRecord]:
        """Iterate intact records past ``min_lsn`` without truncating."""
        return iter(self.recover(min_lsn))

    # ------------------------------------------------------------------ #
    def checkpoint(self) -> int:
        """Truncate the log after its contents reached the manifest.

        Resets the file to a bare header whose ``base_lsn`` is the old end
        LSN, keeping LSNs monotonic.  Returns the new base LSN.  Never call
        this on a shared log unless the caller holds exclusive ownership
        (the sharded store's exclusive-mode checkpoint).
        """
        with self._lock:
            end = self.base_lsn + (
                (os.path.getsize(self.path) if self.shared else self._end)
                - _HEADER_SIZE
            )
            self._pending.clear()
            self._f.seek(0)
            self._f.write(_MAGIC + struct.pack("<Q", end))
            self._f.truncate(_HEADER_SIZE)
            self._f.flush()
            # the truncation and its fsync must be atomic w.r.t. appenders
            # on this log: releasing wal._lock between them could fsync a
            # header an interleaved append already grew past (cold path)
            os.fsync(self._f.fileno())  # dsflow: ignore[lock-fsync]
            self.base_lsn = end
            self._end = _HEADER_SIZE
            self._shared_good = _HEADER_SIZE
            return end

    def close(self) -> None:
        if self._f is not None:
            try:
                self.flush(sync=False)
            except ValueError:  # already closed underneath us
                pass
            self._f.close()
            self._f = None

    def __repr__(self) -> str:
        mode = "shared" if self.shared else "exclusive"
        return f"WriteAheadLog({self.path!r}, {mode}, end_lsn={self.end_lsn})"
