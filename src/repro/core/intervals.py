"""Integer-interval primitives shared by the ProvRC encoder and the query engine.

All lineage data in DSLog is expressed over *closed* integer intervals
``[lo, hi]`` (inclusive on both ends, 0-based).  A width-0 interval
(``lo == hi``) is a single cell index.  The helpers here are pure numpy and
fully vectorized; they are the CPU reference path that the Pallas kernels in
``repro.kernels`` mirror on TPU.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "lexsort_rows",
    "segment_starts",
    "segment_ids_from_starts",
    "segment_reduce_min",
    "segment_reduce_max",
    "segment_reduce_first",
    "segment_all",
    "cummax_with_reset",
    "coalesce_1d",
    "interval_overlap",
    "interval_intersect",
]


def lexsort_rows(cols: list[np.ndarray]) -> np.ndarray:
    """Return the permutation sorting rows by ``cols[0]`` (primary) onward.

    ``np.lexsort`` takes the *last* key as primary, hence the reversal.
    """
    if not cols:
        raise ValueError("need at least one sort column")
    return np.lexsort(tuple(reversed(cols)))


def segment_starts(boundary: np.ndarray) -> np.ndarray:
    """Indices where a new segment starts.  ``boundary[0]`` is forced True."""
    b = boundary.copy()
    if b.size:
        b[0] = True
    return np.flatnonzero(b)


def segment_ids_from_starts(starts: np.ndarray, n: int) -> np.ndarray:
    seg = np.zeros(n, dtype=np.int64)
    if starts.size:
        seg[starts[1:]] = 1
    return np.cumsum(seg)


def segment_reduce_min(x: np.ndarray, starts: np.ndarray) -> np.ndarray:
    return np.minimum.reduceat(x, starts) if x.size else x[:0]


def segment_reduce_max(x: np.ndarray, starts: np.ndarray) -> np.ndarray:
    return np.maximum.reduceat(x, starts) if x.size else x[:0]


def segment_reduce_first(x: np.ndarray, starts: np.ndarray) -> np.ndarray:
    return x[starts] if x.size else x[:0]


def segment_all(flags: np.ndarray, starts: np.ndarray) -> np.ndarray:
    """Per-segment logical AND of a boolean vector."""
    if flags.size == 0:
        return flags[:0]
    return np.minimum.reduceat(flags.astype(np.int8), starts) > 0


def cummax_with_reset(x: np.ndarray, group_ids: np.ndarray) -> np.ndarray:
    """Cumulative max of ``x`` that resets at each change of ``group_ids``.

    Implemented with the monotone-offset trick so it stays fully vectorized:
    within a group the added offset is constant, and offsets grow with the
    group id, so ``np.maximum.accumulate`` can never carry a maximum backward
    across a group boundary.
    """
    if x.size == 0:
        return x.copy()
    x = x.astype(np.int64)
    span = int(x.max()) - int(x.min()) + 2
    off = group_ids.astype(np.int64) * span
    return np.maximum.accumulate(x + off) - off


def coalesce_1d(
    group_ids: np.ndarray, lo: np.ndarray, hi: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Union adjacent/overlapping intervals sharing a group id.

    Rows must already be sorted by ``(group_ids, lo)``.  Returns
    ``(starts, out_lo, out_hi)`` where ``starts`` indexes the first source row
    of each output interval (useful to gather untouched columns).
    Two intervals merge when ``next.lo <= running_max(hi) + 1``.
    """
    n = lo.size
    if n == 0:
        return np.zeros(0, np.int64), lo.copy(), hi.copy()
    cm = cummax_with_reset(hi, group_ids)
    boundary = np.ones(n, dtype=bool)
    boundary[1:] = (group_ids[1:] != group_ids[:-1]) | (lo[1:] > cm[:-1] + 1)
    starts = np.flatnonzero(boundary)
    out_lo = lo[starts]
    out_hi = segment_reduce_max(hi, starts)
    return starts, out_lo, out_hi


def interval_overlap(
    alo: np.ndarray, ahi: np.ndarray, blo: np.ndarray, bhi: np.ndarray
) -> np.ndarray:
    """Elementwise (broadcasting) test ``[alo,ahi] ∩ [blo,bhi] != ∅``."""
    return np.logical_and(alo <= bhi, blo <= ahi)


def interval_intersect(
    alo: np.ndarray, ahi: np.ndarray, blo: np.ndarray, bhi: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    return np.maximum(alo, blo), np.minimum(ahi, bhi)
