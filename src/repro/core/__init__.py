"""DSLog core: ProvRC compression, in-situ queries, reuse, catalog.

This package is the paper's contribution (Zhao & Krishnan, "Compression and
In-Situ Query Processing for Fine-Grained Array Lineage").  Public API:

    from repro.core import DSLog, QueryBox, compress, LineageRelation
"""

from .capture import capture_jacobian  # noqa: F401
from .catalog import ArrayDef, DSLog, LineageEntry  # noqa: F401
from .commit import CommitPipeline, LeaseHeldError, WriterLease  # noqa: F401
from .graph import CycleError, LineageGraph  # noqa: F401
from .index import IntervalIndex  # noqa: F401
from .planner import QueryPlan, QueryPlanner  # noqa: F401
from .provrc import compress, compress_both  # noqa: F401
from .query import (  # noqa: F401
    QueryBox,
    merge_boxes,
    query_path,
    theta_join,
    theta_join_batch,
    theta_join_inverse,
    theta_join_inverse_batch,
)
from .relation import LineageRelation  # noqa: F401
from .reuse import ReusePredictor, generalize, instantiate  # noqa: F401
from .shard import (  # noqa: F401
    AffinityShardPolicy,
    ExchangeStep,
    HashShardPolicy,
    ShardedDSLog,
    ShardedLineageGraph,
    ShardedQueryPlan,
    ShardedQueryPlanner,
    ShardPolicy,
)
from .table import CompressedTable, TableHandle  # noqa: F401
from .wal import WalRecord, WriteAheadLog  # noqa: F401
