"""Telemetry export: JSON snapshot schema, Prometheus text, health report.

``telemetry_snapshot(store)`` wraps ``store.metrics_snapshot()`` in the
``dslog-telemetry/v1`` envelope that both store types persist as a
``telemetry.json`` sidecar on checkpoint.  ``validate_telemetry`` is the
schema check used by tests and the CI smoke step; ``render_prometheus``
emits the text exposition format and ``parse_prometheus`` is the
minimal line validator the smoke step asserts with.  ``health``
combines registry red-flag heuristics with ``fsck``'s findings JSON —
the health endpoint the ROADMAP's remote-shard item asks for.
"""

from __future__ import annotations

import json
import time

__all__ = [
    "TELEMETRY_SCHEMA",
    "telemetry_snapshot",
    "validate_telemetry",
    "render_prometheus",
    "parse_prometheus",
    "health",
]

TELEMETRY_SCHEMA = "dslog-telemetry/v1"


def telemetry_snapshot(store) -> dict:
    """Full telemetry envelope for a ``DSLog`` or ``ShardedDSLog``."""
    snap = store.metrics_snapshot()
    return {
        "schema": TELEMETRY_SCHEMA,
        "store": type(store).__name__,
        "root": getattr(store, "root", None),
        "generated_at": time.time(),
        **snap,
    }


def validate_telemetry(obj) -> dict:
    """Schema check; raises ``ValueError`` with a precise path on failure.

    Returns ``{"counters": n, "gauges": n, "histograms": n}`` so callers
    can assert non-emptiness.
    """
    if not isinstance(obj, dict):
        raise ValueError("telemetry: top level must be an object")
    if obj.get("schema") != TELEMETRY_SCHEMA:
        raise ValueError(f"telemetry: schema must be {TELEMETRY_SCHEMA!r}")
    for field in ("store", "registry"):
        if not isinstance(obj.get(field), str):
            raise ValueError(f"telemetry: {field!r} must be a string")
    for section in ("counters", "gauges", "histograms"):
        rows = obj.get(section)
        if not isinstance(rows, list):
            raise ValueError(f"telemetry: {section!r} must be a list")
        for i, row in enumerate(rows):
            where = f"telemetry: {section}[{i}]"
            if not isinstance(row, dict):
                raise ValueError(f"{where} must be an object")
            if not isinstance(row.get("name"), str):
                raise ValueError(f"{where}.name must be a string")
            if not isinstance(row.get("labels"), dict):
                raise ValueError(f"{where}.labels must be an object")
            if section == "histograms":
                for field in ("count", "sum", "min", "max", "p50", "p90", "p99"):
                    if not isinstance(row.get(field), (int, float)):
                        raise ValueError(f"{where}.{field} must be numeric")
                buckets = row.get("buckets")
                if not isinstance(buckets, list) or not all(
                    isinstance(b, (list, tuple)) and len(b) == 2 for b in buckets
                ):
                    raise ValueError(f"{where}.buckets must be [index, count] pairs")
            else:
                if not isinstance(row.get("value"), (int, float)):
                    raise ValueError(f"{where}.value must be numeric")
    return {
        "counters": len(obj["counters"]),
        "gauges": len(obj["gauges"]),
        "histograms": len(obj["histograms"]),
    }


def _prom_name(name: str, prefix: str) -> str:
    safe = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return f"{prefix}_{safe}"


def _prom_labels(labels: dict, extra: dict | None = None) -> str:
    merged = dict(labels)
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    body = ",".join(
        f'{k}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
        for k, v in sorted(merged.items())
    )
    return "{" + body + "}"


def render_prometheus(snapshot: dict, prefix: str = "dslog") -> str:
    """Prometheus text exposition (0.0.4) for a telemetry snapshot."""
    lines: list[str] = []
    seen_types: set[str] = set()

    def type_line(name: str, kind: str) -> None:
        if name not in seen_types:
            seen_types.add(name)
            lines.append(f"# TYPE {name} {kind}")

    for row in snapshot.get("counters", ()):
        name = _prom_name(row["name"], prefix) + "_total"
        type_line(name, "counter")
        lines.append(f"{name}{_prom_labels(row['labels'])} {row['value']}")
    for row in snapshot.get("gauges", ()):
        name = _prom_name(row["name"], prefix)
        type_line(name, "gauge")
        lines.append(f"{name}{_prom_labels(row['labels'])} {row['value']}")
    for row in snapshot.get("histograms", ()):
        name = _prom_name(row["name"], prefix)
        type_line(name, "histogram")
        base = row.get("bucket_base", 1e-9)
        factor = row.get("bucket_factor", 2.0)
        cum = 0
        for idx, count in row.get("buckets", ()):
            cum += count
            le = base * factor ** int(idx)
            lines.append(f"{name}_bucket{_prom_labels(row['labels'], {'le': repr(le)})} {cum}")
        lines.append(f"{name}_bucket{_prom_labels(row['labels'], {'le': '+Inf'})} {row['count']}")
        lines.append(f"{name}_sum{_prom_labels(row['labels'])} {row['sum']}")
        lines.append(f"{name}_count{_prom_labels(row['labels'])} {row['count']}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> int:
    """Validate exposition text line-by-line; returns the sample count.

    Not a full parser — enough to catch malformed names, labels, or
    values, which is what the CI smoke step asserts.
    """
    samples = 0
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        body = line
        if "{" in body:
            name, rest = body.split("{", 1)
            if "}" not in rest:
                raise ValueError(f"prometheus line {lineno}: unterminated labels")
            labels, value_part = rest.rsplit("}", 1)
            for pair in labels.split(","):
                if "=" not in pair:
                    raise ValueError(f"prometheus line {lineno}: bad label {pair!r}")
                k, v = pair.split("=", 1)
                if not k.strip() or not (v.startswith('"') and v.endswith('"')):
                    raise ValueError(f"prometheus line {lineno}: bad label {pair!r}")
        else:
            parts = body.split()
            if len(parts) != 2:
                raise ValueError(f"prometheus line {lineno}: expected 'name value'")
            name, value_part = parts
        name = name.strip()
        if not name or not (name[0].isalpha() or name[0] == "_"):
            raise ValueError(f"prometheus line {lineno}: bad metric name {name!r}")
        value = value_part.strip().split()[0]
        float(value)  # raises ValueError on malformed sample
        samples += 1
    return samples


def _flag(flags: list, severity: str, name: str, detail: str) -> None:
    flags.append({"severity": severity, "flag": name, "detail": detail})


def health(store, run_fsck: bool = True) -> dict:
    """Red-flag report: registry heuristics + ``fsck`` findings JSON."""
    snap = telemetry_snapshot(store)
    counters = {}
    for row in snap.get("counters", ()):
        counters[row["name"]] = counters.get(row["name"], 0) + row["value"]
    hists = {}
    for row in snap.get("histograms", ()):
        if not row["labels"]:
            hists[row["name"]] = row

    flags: list[dict] = []
    replayed = counters.get("wal_replayed", 0)
    if replayed:
        _flag(
            flags,
            "warning",
            "wal-replayed",
            f"{replayed} WAL records replayed on open (unclean shutdown)",
        )
    fsync = hists.get("wal_fsync_seconds")
    if fsync and fsync["count"] >= 8 and fsync["p99"] > 0.25:
        _flag(
            flags,
            "warning",
            "fsync-slow",
            f"fsync p99 {fsync['p99'] * 1e3:.1f}ms over {fsync['count']} syncs",
        )
    made = counters.get("views_materialized", 0)
    killed = counters.get("views_invalidated", 0)
    if made >= 4 and killed > 4 * made:
        _flag(
            flags,
            "warning",
            "views-thrashing",
            f"{killed} invalidations for {made} materializations",
        )
    hits = counters.get("cache_hits", 0)
    misses = counters.get("cache_misses", 0)
    if hits + misses >= 64 and hits < (hits + misses) * 0.01:
        _flag(
            flags,
            "info",
            "cache-cold",
            f"answer-cache hit rate {hits}/{hits + misses}",
        )

    fsck_report = None
    ok = True
    if run_fsck and getattr(store, "root", None):
        try:
            from repro.tools.fsck import fsck_store

            fsck_report = fsck_store(store.root).to_json()
            # findings follow the shared analysis-tool schema
            # (repro.tools.findings): rule = fsck category, message = detail
            for finding in fsck_report.get("findings", ()):
                if finding.get("severity") == "error":
                    ok = False
                    _flag(
                        flags,
                        "error",
                        f"fsck:{finding.get('rule')}",
                        finding.get("message", ""),
                    )
        except Exception as exc:  # fsck must never take the store down
            _flag(flags, "info", "fsck-unavailable", repr(exc))
    return {
        "ok": ok and not any(f["severity"] == "error" for f in flags),
        "flags": flags,
        "fsck": fsck_report,
        "counters": counters,
        "generated_at": snap["generated_at"],
    }


def dump_json(snapshot: dict) -> str:
    return json.dumps(snapshot, indent=2, sort_keys=True)
