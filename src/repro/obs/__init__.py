"""Telemetry subsystem: metrics registry, structured tracing, exporters.

``repro.obs`` is deliberately free of ``repro.core`` imports at module
level so core modules can depend on it without cycles.  The three
pieces:

- :mod:`repro.obs.metrics` — typed counters/gauges/log-bucketed
  histograms behind a single internally-locked :class:`MetricsRegistry`;
  ``DSLog.io_stats`` is a live read-only view over it.
- :mod:`repro.obs.trace` — off-by-default per-query span trees
  (``plan -> hop -> kernel launch / twin / exchange / cache probe /
  view race``) with wall time and instrument deltas per span.
- :mod:`repro.obs.export` — ``telemetry.json`` snapshot schema,
  Prometheus text exposition, and the combined ``health()`` report.
"""

from repro.obs.metrics import (
    Histogram,
    IoStatsView,
    MetricsRegistry,
    StatsView,
)
from repro.obs.trace import QueryTrace, Span, maybe_span
from repro.obs.export import (
    TELEMETRY_SCHEMA,
    health,
    parse_prometheus,
    render_prometheus,
    telemetry_snapshot,
    validate_telemetry,
)

__all__ = [
    "Histogram",
    "IoStatsView",
    "MetricsRegistry",
    "StatsView",
    "QueryTrace",
    "Span",
    "maybe_span",
    "TELEMETRY_SCHEMA",
    "health",
    "parse_prometheus",
    "render_prometheus",
    "telemetry_snapshot",
    "validate_telemetry",
]
