"""Structured per-query tracing: span trees with instrument deltas.

A :class:`QueryTrace` is created by ``prov_query(..., trace=True)`` and
installed as ``log._active_trace`` for the duration of the query.  Hot
paths check ``self._active_trace is not None`` — a single attribute load
— so the tracing-off cost is effectively zero and is bounded by a
microbenchmark in ``tests/test_obs.py``.

Spans form a tree rooted at the ``query`` span.  Each span records wall
time (``perf_counter`` deltas) and, when a registry is attached, the
delta of every unlabeled counter that moved while the span was open.
Worker threads (``prov_query(..., parallel=N)``) have no span stack of
their own; their spans attach to the root, which keeps the tree
race-free without cross-thread coordination.

The span-stack lock is minted through ``repro.core._locks`` (name
``trace._lock``, rank 90 — a leaf above ``metrics._lock``) so the
dynamic race detector watches it too.
"""

from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Iterator

__all__ = ["QueryTrace", "Span", "maybe_span"]


class Span:
    __slots__ = ("name", "kind", "attrs", "start", "duration", "delta", "children")

    def __init__(self, name: str, kind: str = "", attrs: dict | None = None) -> None:
        self.name = name
        self.kind = kind
        self.attrs = attrs or {}
        self.start = 0.0
        self.duration: float | None = None
        self.delta: dict[str, int] = {}
        self.children: list[Span] = []

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "kind": self.kind,
            "attrs": self.attrs,
            "duration_ms": None if self.duration is None else self.duration * 1e3,
            "delta": self.delta,
            "children": [c.to_dict() for c in self.children],
        }

    def walk(self) -> Iterator["Span"]:
        yield self
        for child in self.children:
            yield from child.walk()


class _NullSpan:
    """Throwaway span stand-in so untraced code can set ``sp.attrs``."""

    __slots__ = ("attrs",)

    def __init__(self) -> None:
        self.attrs: dict = {}


class _NullSpanCtx:
    __slots__ = ()

    def __enter__(self) -> _NullSpan:
        return _NullSpan()

    def __exit__(self, *exc) -> bool:
        return False


_NULL_CTX = _NullSpanCtx()


def maybe_span(trace: "QueryTrace | None", name: str, kind: str = "", **attrs):
    """``trace.span(...)`` when tracing, a no-op context otherwise."""
    if trace is None:
        return _NULL_CTX
    return trace.span(name, kind=kind, **attrs)


class QueryTrace:
    """Span tree for one query, with optional counter-delta capture."""

    def __init__(self, registry=None, label: str = "query") -> None:
        self._registry = registry
        try:
            from repro.core import _locks

            self._lock = _locks.new_lock("trace._lock")
        except ImportError:  # pragma: no cover - standalone use
            self._lock = threading.Lock()
        self._tls = threading.local()
        self.root = Span(label, kind="query")
        self.root.start = time.perf_counter()

    # -- span stack (per thread) -----------------------------------------

    def _stack(self) -> list[Span]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def current(self) -> Span:
        stack = self._stack()
        return stack[-1] if stack else self.root

    def _attach(self, parent: Span, span: Span) -> None:
        with self._lock:
            parent.children.append(span)

    # -- recording API ----------------------------------------------------

    @contextmanager
    def span(self, name: str, kind: str = "", **attrs):
        """Open a child span; on exit record duration + counter deltas."""
        sp = Span(name, kind=kind, attrs=attrs)
        parent = self.current()
        stack = self._stack()
        stack.append(sp)
        before = self._registry.counters_flat() if self._registry is not None else None
        sp.start = time.perf_counter()
        try:
            yield sp
        finally:
            sp.duration = time.perf_counter() - sp.start
            if before is not None:
                after = self._registry.counters_flat()
                sp.delta = {
                    k: after[k] - before.get(k, 0)
                    for k in after
                    if after[k] != before.get(k, 0)
                }
            stack.pop()
            self._attach(parent, sp)

    def event(self, name: str, kind: str = "", duration: float | None = None, **attrs) -> Span:
        """Record a leaf span without opening a scope (for inline sites)."""
        sp = Span(name, kind=kind, attrs=attrs)
        sp.duration = duration
        self._attach(self.current(), sp)
        return sp

    def finish(self) -> "QueryTrace":
        if self.root.duration is None:
            self.root.duration = time.perf_counter() - self.root.start
        return self

    # -- inspection -------------------------------------------------------

    def spans(self, kind: str | None = None) -> list[Span]:
        return [s for s in self.root.walk() if kind is None or s.kind == kind]

    def kinds(self) -> set[str]:
        return {s.kind for s in self.root.walk() if s.kind}

    def to_dict(self) -> dict:
        return self.finish().root.to_dict()

    def render(self, max_depth: int = 8) -> str:
        """Indented tree view of the trace."""
        self.finish()
        lines: list[str] = []

        def fmt(span: Span, depth: int) -> None:
            if depth > max_depth:
                return
            dur = "" if span.duration is None else f" {span.duration * 1e3:.3f}ms"
            attrs = ""
            if span.attrs:
                attrs = " " + " ".join(f"{k}={v}" for k, v in span.attrs.items())
            delta = ""
            if span.delta:
                moved = ", ".join(f"{k}+{v}" for k, v in sorted(span.delta.items()))
                delta = f" [{moved}]"
            lines.append(f"{'  ' * depth}{span.name}{dur}{attrs}{delta}")
            for child in span.children:
                fmt(child, depth + 1)

        fmt(self.root, 0)
        return "\n".join(lines)
