"""Typed metric instruments behind a single internally-locked registry.

Three instrument kinds, all keyed ``(name, labels)`` where ``labels`` is
a sorted tuple of ``(key, value)`` string pairs:

- **counters** — monotonic ints (``inc``);
- **gauges** — last-write-wins floats (``set_gauge``), plus snapshot-time
  *collectors* so derived state (hop-stat EMAs, live view counts) can be
  exported without any hot-path cost;
- **histograms** — geometric log-bucketed (``observe``) with exact
  count/sum/min/max and p50/p90/p99 extraction.

The registry lock is minted through ``repro.core._locks`` (name
``metrics._lock``, rank 80 in ``tools/lockorder.py``) so the
``DSLOG_RACE_DETECT=1`` detector sees it; the import happens lazily
inside ``__init__`` to keep this module import-cycle free.  Rank 80 sits
above every ``core`` lock because instrument updates happen while stats
or WAL locks are held, never the other way round.

``IoStatsView`` and ``StatsView`` are read-only ``Mapping`` facades that
keep the historical ``log.io_stats["key"]`` / ``wal.stats["records"]``
read idiom working on top of registry counters.
"""

from __future__ import annotations

import math
import threading
from collections.abc import Mapping
from typing import Callable, Iterable, Iterator

__all__ = ["Histogram", "IoStatsView", "MetricsRegistry", "StatsView"]

# Geometric buckets: upper bound of bucket i is BASE * FACTOR**i.  With
# BASE=1e-9 and FACTOR=2 the 64 buckets span ~1ns .. ~1.8e10, covering
# both latencies in seconds and batch sizes in rows.
BUCKET_BASE = 1e-9
BUCKET_FACTOR = 2.0
N_BUCKETS = 64

_LOG_FACTOR = math.log(BUCKET_FACTOR)


def bucket_index(value: float) -> int:
    """Index of the geometric bucket whose upper bound covers ``value``."""
    if value <= BUCKET_BASE:
        return 0
    idx = int(math.ceil(math.log(value / BUCKET_BASE) / _LOG_FACTOR - 1e-9))
    if idx < 0:
        return 0
    if idx >= N_BUCKETS:
        return N_BUCKETS - 1
    return idx


def bucket_upper(idx: int) -> float:
    return BUCKET_BASE * BUCKET_FACTOR**idx


def _label_key(labels: dict) -> tuple:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Histogram:
    """Sparse log-bucketed histogram with exact count/sum/min/max."""

    __slots__ = ("buckets", "count", "total", "vmin", "vmax")

    def __init__(self) -> None:
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        idx = bucket_index(v)
        self.buckets[idx] = self.buckets.get(idx, 0) + 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def percentile(self, q: float) -> float:
        """Approximate quantile ``q`` in [0, 1] from the bucket walk.

        Returns the upper bound of the bucket where the cumulative count
        crosses ``q * count``, clamped to the exact observed [min, max].
        """
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for idx in sorted(self.buckets):
            cum += self.buckets[idx]
            if cum >= target:
                est = bucket_upper(idx)
                return max(self.vmin, min(est, self.vmax))
        return self.vmax

    def merge(self, other: "Histogram") -> None:
        for idx, n in other.buckets.items():
            self.buckets[idx] = self.buckets.get(idx, 0) + n
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    def to_dict(self) -> dict:
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.vmin if self.count else 0.0,
            "max": self.vmax if self.count else 0.0,
            "p50": self.percentile(0.50),
            "p90": self.percentile(0.90),
            "p99": self.percentile(0.99),
            "buckets": sorted(self.buckets.items()),
            "bucket_base": BUCKET_BASE,
            "bucket_factor": BUCKET_FACTOR,
        }

    @classmethod
    def from_dict(cls, payload: Mapping) -> "Histogram":
        h = cls()
        for idx, n in payload.get("buckets", ()):
            h.buckets[int(idx)] = int(n)
        h.count = int(payload.get("count", 0))
        h.total = float(payload.get("sum", 0.0))
        if h.count:
            h.vmin = float(payload.get("min", 0.0))
            h.vmax = float(payload.get("max", 0.0))
        return h


class MetricsRegistry:
    """All instruments for one store (or one shard) under a single lock.

    ``Collector`` callables run at snapshot time *outside* the registry
    lock (they may take lower-ranked core locks) and yield
    ``(name, labels_dict, value)`` gauge triples.
    """

    def __init__(self, name: str = "dslog") -> None:
        self.name = name
        try:
            from repro.core import _locks

            self._lock = _locks.new_lock("metrics._lock")
        except ImportError:  # standalone use outside the repo tree
            self._lock = threading.Lock()
        self._counters: dict[tuple, int] = {}
        self._gauges: dict[tuple, float] = {}
        self._histograms: dict[tuple, Histogram] = {}
        self._collectors: list[Callable[[], Iterable[tuple]]] = []

    # -- counters ---------------------------------------------------------

    def seed_counters(self, names: Iterable[str]) -> None:
        """Pre-register unlabeled counters at zero so reads/`in` work."""
        with self._lock:
            for name in names:
                self._counters.setdefault((name, ()), 0)

    def inc(self, name: str, n: int = 1, **labels) -> None:
        key = (name, _label_key(labels))
        with self._lock:
            self._counters[key] = self._counters.get(key, 0) + n

    def counter_value(self, name: str, **labels) -> int:
        key = (name, _label_key(labels))
        with self._lock:
            return self._counters.get(key, 0)

    def counters_flat(self) -> dict[str, int]:
        """Unlabeled counters as a plain dict (the ``io_stats`` surface).

        Labeled series fold into their base name so aggregate counts
        (e.g. per-path ``queries``) stay visible through the dict view.
        """
        with self._lock:
            out: dict[str, int] = {}
            for (name, labels), val in self._counters.items():
                if not labels:
                    out[name] = out.get(name, 0) + val
                elif name not in out:
                    out[name] = val
                else:
                    out[name] += val
            return out

    # -- gauges -----------------------------------------------------------

    def set_gauge(self, name: str, value: float, **labels) -> None:
        key = (name, _label_key(labels))
        with self._lock:
            self._gauges[key] = float(value)

    def register_collector(self, fn: Callable[[], Iterable[tuple]]) -> None:
        with self._lock:
            self._collectors.append(fn)

    # -- histograms -------------------------------------------------------

    def observe(self, name: str, value: float, **labels) -> None:
        key = (name, _label_key(labels))
        with self._lock:
            hist = self._histograms.get(key)
            if hist is None:
                hist = self._histograms[key] = Histogram()
            hist.observe(value)

    def histogram(self, name: str, **labels) -> Histogram | None:
        key = (name, _label_key(labels))
        with self._lock:
            return self._histograms.get(key)

    def percentiles(self, name: str, **labels) -> dict[str, float]:
        hist = self.histogram(name, **labels)
        if hist is None:
            return {"count": 0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
        return {
            "count": hist.count,
            "p50": hist.percentile(0.50),
            "p90": hist.percentile(0.90),
            "p99": hist.percentile(0.99),
        }

    # -- snapshot / merge -------------------------------------------------

    def snapshot(self) -> dict:
        """Structured dump of every instrument, collectors included."""
        with self._lock:
            counters = [
                {"name": name, "labels": dict(labels), "value": val}
                for (name, labels), val in sorted(self._counters.items())
            ]
            gauges = {key: val for key, val in self._gauges.items()}
            histograms = [
                {"name": name, "labels": dict(labels), **hist.to_dict()}
                for (name, labels), hist in sorted(self._histograms.items())
            ]
            collectors = list(self._collectors)
        # Collectors run outside the registry lock: they may take core
        # locks that rank below metrics._lock.
        for fn in collectors:
            try:
                triples = list(fn())
            except Exception:
                continue
            for name, labels, value in triples:
                gauges[(name, _label_key(labels))] = float(value)
        return {
            "registry": self.name,
            "counters": counters,
            "gauges": [
                {"name": name, "labels": dict(labels), "value": val}
                for (name, labels), val in sorted(gauges.items())
            ],
            "histograms": histograms,
        }

    @staticmethod
    def merge_snapshots(snapshots: Iterable[dict], name: str = "merged") -> dict:
        """Sum counters/histograms and sum gauges across registries.

        Series merge by ``(name, labels)`` union — instruments minted by
        only one child still appear in the merged view.
        """
        counters: dict[tuple, int] = {}
        gauges: dict[tuple, float] = {}
        histograms: dict[tuple, Histogram] = {}
        for snap in snapshots:
            for row in snap.get("counters", ()):
                key = (row["name"], _label_key(row.get("labels", {})))
                counters[key] = counters.get(key, 0) + int(row["value"])
            for row in snap.get("gauges", ()):
                key = (row["name"], _label_key(row.get("labels", {})))
                gauges[key] = gauges.get(key, 0.0) + float(row["value"])
            for row in snap.get("histograms", ()):
                key = (row["name"], _label_key(row.get("labels", {})))
                hist = histograms.get(key)
                if hist is None:
                    histograms[key] = Histogram.from_dict(row)
                else:
                    hist.merge(Histogram.from_dict(row))
        return {
            "registry": name,
            "counters": [
                {"name": n, "labels": dict(l), "value": v}
                for (n, l), v in sorted(counters.items())
            ],
            "gauges": [
                {"name": n, "labels": dict(l), "value": v}
                for (n, l), v in sorted(gauges.items())
            ],
            "histograms": [
                {"name": n, "labels": dict(l), **h.to_dict()}
                for (n, l), h in sorted(histograms.items())
            ],
        }


class IoStatsView(Mapping):
    """Live read-only ``io_stats`` facade over a registry's counters.

    ``dict(view)``, ``view[key]``, ``view.get``, and ``key in view`` all
    behave like the historical guarded dict; mutation goes through
    ``MetricsRegistry.inc`` (enforced by dslint's ``metric-registry``
    rule in ``core/``).
    """

    __slots__ = ("_registry",)

    def __init__(self, registry: MetricsRegistry) -> None:
        self._registry = registry

    def __getitem__(self, key: str) -> int:
        flat = self._registry.counters_flat()
        return flat[key]

    def __iter__(self) -> Iterator[str]:
        return iter(self._registry.counters_flat())

    def __len__(self) -> int:
        return len(self._registry.counters_flat())

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"IoStatsView({self._registry.counters_flat()!r})"


class StatsView(Mapping):
    """Read-only alias view: short legacy key -> registry counter name."""

    __slots__ = ("_registry", "_aliases")

    def __init__(self, registry: MetricsRegistry, aliases: Mapping) -> None:
        self._registry = registry
        self._aliases = dict(aliases)

    def __getitem__(self, key: str) -> int:
        return self._registry.counter_value(self._aliases[key])

    def __iter__(self) -> Iterator[str]:
        return iter(self._aliases)

    def __len__(self) -> int:
        return len(self._aliases)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"StatsView({dict(self)!r})"
