from .pipeline import PipelineConfig, TokenPipeline  # noqa: F401
