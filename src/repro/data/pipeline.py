"""Deterministic, shardable, checkpointable synthetic token pipeline.

Design: the batch for (seed, step, shard) is a *pure function* — no iterator
state beyond the step counter.  That gives us, for free:

* **checkpoint/restart**: the pipeline state is one integer in the train
  checkpoint;
* **elasticity**: re-sharding to a different data-parallel size replays the
  same global batch split differently (bitwise-identical global stream);
* **fine-grained lineage**: every pipeline stage (source rows → shuffle →
  shard → microbatch) is an index-arithmetic array op whose lineage DSLog
  compresses to O(1) rows and reuses per step via ``gen_sig`` (the paper's
  reuse case is *exactly* the per-step repetition of these ops).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.capture import take_lineage
from ..core.catalog import DSLog
from ..core.relation import LineageRelation

__all__ = ["PipelineConfig", "TokenPipeline"]


@dataclass(frozen=True)
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_source_rows: int = 1 << 20  # synthetic corpus size (documents)


class TokenPipeline:
    """Yields per-shard token batches; optionally logs lineage into DSLog."""

    def __init__(
        self,
        cfg: PipelineConfig,
        data_shards: int = 1,
        shard_id: int = 0,
        dslog: DSLog | None = None,
    ):
        assert cfg.global_batch % data_shards == 0
        self.cfg = cfg
        self.data_shards = data_shards
        self.shard_id = shard_id
        self.dslog = dslog
        self.step = 0

    # ------------------------------------------------------------------ #
    def source_rows_for_step(self, step: int) -> np.ndarray:
        """Global document ids consumed at ``step`` (the shuffle)."""
        rng = np.random.default_rng((self.cfg.seed, step))
        return rng.choice(
            self.cfg.n_source_rows, size=self.cfg.global_batch, replace=False
        )

    def global_batch_tokens(self, step: int) -> np.ndarray:
        rows = self.source_rows_for_step(step)
        # tokens are a pure hash of (document id, position): reproducible
        pos = np.arange(self.cfg.seq_len, dtype=np.uint64)
        mixed = (rows[:, None].astype(np.uint64) * np.uint64(6364136223846793005)
                 + pos[None, :] * np.uint64(1442695040888963407))
        mixed ^= mixed >> np.uint64(33)
        return (mixed % np.uint64(self.cfg.vocab)).astype(np.int32)

    def shard_slice(self, step: int) -> np.ndarray:
        g = self.global_batch_tokens(step)
        per = self.cfg.global_batch // self.data_shards
        return g[self.shard_id * per : (self.shard_id + 1) * per]

    # ------------------------------------------------------------------ #
    def next_batch(self) -> dict:
        step = self.step
        tokens = self.shard_slice(step)
        if self.dslog is not None:
            self._log_lineage(step)
        self.step += 1
        return {"tokens": tokens, "step": step}

    # ------------------------------------------------------------------ #
    # checkpointing: state is just the step counter
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, s: dict) -> None:
        self.step = int(s["step"])

    # ------------------------------------------------------------------ #
    def _log_lineage(self, step: int) -> None:
        """Register this step's pipeline ops in DSLog.

        Chain per step s:  corpus → batch_s (gather of shuffled rows)
                           batch_s → shard_s_k (slice per data shard)
        The gather is value-dependent (different rows each step: base_sig
        only), but the slice/microbatch ops repeat identically and are
        served by gen_sig reuse after the first step.
        """
        cfg = self.cfg
        log = self.dslog
        rows = self.source_rows_for_step(step)
        corpus = "corpus"
        batch = f"batch_s{step}"
        if corpus not in log.arrays:
            log.define_array(corpus, (cfg.n_source_rows, cfg.seq_len))
        log.define_array(batch, (cfg.global_batch, cfg.seq_len))
        log.register_operation(
            "batch_gather",
            [corpus],
            [batch],
            capture=lambda: {
                (0, 0): take_lineage(
                    (cfg.n_source_rows, cfg.seq_len), rows, 0
                )
            },
            op_args={"step": step},
            reuse=False,  # shuffle is step-dependent: never reusable
        )
        per = cfg.global_batch // self.data_shards
        for k in range(self.data_shards):
            shard = f"shard_s{step}_k{k}"
            log.define_array(shard, (per, cfg.seq_len))
            start = k * per
            log.register_operation(
                "shard_slice",
                [batch],
                [shard],
                capture=lambda start=start, per=per: {
                    (0, 0): _slice_rows(
                        (cfg.global_batch, cfg.seq_len), start, per
                    )
                },
                op_args={"k": k, "of": self.data_shards},
            )


def _slice_rows(shape, start, count) -> LineageRelation:
    from ..core.capture import slice_lineage

    rel = slice_lineage(shape, (start, 0), (start + count, shape[1]))
    return rel
