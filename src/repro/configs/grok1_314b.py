"""grok-1-314b — MoE 64L, 8 experts top-2 [hf:xai-org/grok-1; unverified]."""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    head_dim=128,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=0, d_ff_expert=32768),
    rope_theta=1e4,
    source="hf:xai-org/grok-1; unverified",
)
