"""hymba-1.5b — hybrid: parallel attention + mamba heads per layer.

[arXiv:2411.13676; hf] — SWA for most layers (3 global), meta tokens
omitted (DESIGN.md §9).
"""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    attn_pattern="15local:1global",
    window=1024,
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, chunk=128),
    rope_theta=1e4,
    source="arXiv:2411.13676; hf",
)
