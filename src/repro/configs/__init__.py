"""Architecture registry: ``get_arch("<id>")`` / ``--arch <id>``."""

from __future__ import annotations

from .base import SHAPES, ArchConfig, ShapeConfig, smoke_shape  # noqa: F401
from .gemma3_4b import CONFIG as gemma3_4b
from .grok1_314b import CONFIG as grok_1_314b
from .hubert_xlarge import CONFIG as hubert_xlarge
from .hymba_1_5b import CONFIG as hymba_1_5b
from .internvl2_2b import CONFIG as internvl2_2b
from .mamba2_780m import CONFIG as mamba2_780m
from .qwen1_5_110b import CONFIG as qwen1_5_110b
from .qwen1_5_32b import CONFIG as qwen1_5_32b
from .qwen2_0_5b import CONFIG as qwen2_0_5b
from .qwen2_moe_a2_7b import CONFIG as qwen2_moe_a2_7b

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        qwen1_5_110b,
        qwen1_5_32b,
        gemma3_4b,
        qwen2_0_5b,
        hubert_xlarge,
        grok_1_314b,
        qwen2_moe_a2_7b,
        internvl2_2b,
        hymba_1_5b,
        mamba2_780m,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def arch_names() -> list[str]:
    return list(ARCHS)


def skip_reason(arch: ArchConfig, shape_name: str) -> str | None:
    """Assignment skip rules (see DESIGN.md §6)."""
    shape = SHAPES[shape_name]
    if arch.encoder_only and shape.kind == "decode":
        return "encoder-only arch has no autoregressive decode"
    subquadratic = arch.family in ("ssm", "hybrid") or "local" in arch.attn_pattern
    if shape_name == "long_500k" and not subquadratic:
        return "pure full-attention arch; long_500k needs sub-quadratic attention"
    return None
