"""Architecture/config schema for the assigned architecture pool.

Every architecture is a :class:`ArchConfig`; the four assigned input shapes
are :class:`ShapeConfig` entries.  ``reduced()`` produces the CPU-smoke
variant of an architecture (same family/topology, tiny dims).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SHAPES"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    dispatch: str = "einsum"  # einsum (GShard one-hot) | sorted (gather/scatter)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    # attention layout: "global" everywhere, or e.g. "5local:1global"
    attn_pattern: str = "global"
    window: int = 1024
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    encoder_only: bool = False
    frontend: str | None = None  # None | "patch" | "frames"
    frontend_len: int = 256  # patches prepended (vlm)
    frontend_dim: int = 512  # raw frame feature dim (audio)
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    mlp_act: str = "swiglu"  # swiglu | gelu
    remat: str = "full"  # nothing | dots | full
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding table and
        logits shard over the model axis; extra ids are masked to -inf in
        the head (odd vocabs like 50280 otherwise force replicated
        multi-GB logits buffers — see EXPERIMENTS.md §Dry-run)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kinds(self) -> list[str]:
        """Per-layer attention kind from ``attn_pattern``."""
        if self.attention_free:
            return ["ssm"] * self.n_layers
        if self.attn_pattern == "global":
            return ["global"] * self.n_layers
        # "<n>local:<m>global" repeating pattern
        parts = self.attn_pattern.split(":")
        cycle: list[str] = []
        for p in parts:
            num = int("".join(ch for ch in p if ch.isdigit()))
            kind = "".join(ch for ch in p if ch.isalpha())
            cycle += [kind] * num
        return [cycle[i % len(cycle)] for i in range(self.n_layers)]

    def params_billions(self) -> float:
        """Rough dense-equivalent parameter count (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.family == "ssm":
            attn = 0
        n_mats = 3 if self.mlp_act == "swiglu" else 2
        if self.moe:
            ff = (self.moe.n_experts + self.moe.n_shared) * n_mats * d * (
                self.moe.d_ff_expert or self.d_ff
            )
        elif self.d_ff:
            ff = n_mats * d * self.d_ff
        else:
            ff = 0
        ssm = 0
        if self.ssm:
            d_in = self.ssm.expand * d
            ssm = d * (2 * d_in) + d_in * d  # in/out projections (approx)
        return (emb + self.n_layers * (attn + ff + ssm)) / 1e9

    def active_params_billions(self) -> float:
        """Active (per-token) params — MoE counts only routed top-k."""
        if not self.moe:
            return self.params_billions()
        d = self.d_model
        n_mats = 3 if self.mlp_act == "swiglu" else 2
        full_ff = self.moe.n_experts * n_mats * d * (self.moe.d_ff_expert or self.d_ff)
        act_ff = (self.moe.top_k + self.moe.n_shared) * n_mats * d * (
            self.moe.d_ff_expert or self.d_ff
        )
        return self.params_billions() - self.n_layers * (full_ff - act_ff) / 1e9

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16 if self.head_dim else 0,
            window=8,
            frontend_len=4,
            frontend_dim=12,
            remat="nothing",
        )
        if self.moe:
            kw["moe"] = replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                n_shared=min(self.moe.n_shared, 1),
                d_ff_expert=32,
            )
        if self.ssm:
            kw["ssm"] = replace(
                self.ssm, d_state=16, head_dim=16, chunk=8
            )
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def smoke_shape(kind: str = "train") -> ShapeConfig:
    return ShapeConfig(f"smoke_{kind}", 32, 2, kind)
