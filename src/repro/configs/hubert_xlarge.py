"""hubert-xlarge — encoder-only audio backbone (w2v2 arch) [arXiv:2106.07447].

The conv feature-extractor frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed frame features [B, T, 512].
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    encoder_only=True,
    frontend="frames",
    frontend_dim=512,
    mlp_act="gelu",
    rope_theta=1e4,
    source="arXiv:2106.07447; unverified",
)
