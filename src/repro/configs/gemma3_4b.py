"""gemma3-4b — dense 34L, 5:1 local:global sliding window, 128k class.

[hf:google/gemma-3-1b-pt; unverified]
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab=262144,
    head_dim=256,
    attn_pattern="5local:1global",
    window=1024,
    tie_embeddings=True,
    rope_theta=1e6,
    source="hf:google/gemma-3-1b-pt; unverified",
)
