"""internvl2-2b — VLM: InternViT frontend (STUB) + InternLM2 backbone.

[arXiv:2404.16821; hf] — ``input_specs()`` supplies precomputed patch
embeddings [B, 256, d_model]; the text backbone is built in full.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    frontend="patch",
    frontend_len=256,
    rope_theta=1e6,
    source="arXiv:2404.16821; hf",
)
