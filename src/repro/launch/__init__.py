# NOTE: do not import .dryrun here — it sets XLA_FLAGS at import time and
# must only be imported as the entry point of a fresh process.
from .mesh import local_mesh, make_mesh, make_production_mesh  # noqa: F401
