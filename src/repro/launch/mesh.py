"""Production meshes.

Everything is a function — importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).

Compat: ``jax.sharding.AxisType`` (and the ``axis_types`` kwarg of
``jax.make_mesh``) only exist on newer JAX releases.  ``_compat_make_mesh``
passes explicit-Auto axis types when the running JAX supports them and
silently constructs a plain mesh otherwise, so the same call sites work on
both (this container ships 0.4.37, which has neither).
"""

from __future__ import annotations

import jax

try:  # JAX >= 0.4.38
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed JAX
    AxisType = None

__all__ = ["make_production_mesh", "make_mesh", "local_mesh"]


def _compat_make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    if AxisType is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(AxisType.Auto,) * len(axes)
            )
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return _compat_make_mesh(tuple(shape), tuple(axes))


def local_mesh(model_parallel: int = 1):
    """Best-effort mesh over whatever devices exist (CPU runs: 1 device)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel), ("data", "model"))
