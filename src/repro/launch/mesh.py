"""Production meshes.

Everything is a function — importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType

__all__ = ["make_production_mesh", "make_mesh", "local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(
        tuple(shape), tuple(axes), axis_types=(AxisType.Auto,) * len(axes)
    )


def local_mesh(model_parallel: int = 1):
    """Best-effort mesh over whatever devices exist (CPU runs: 1 device)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel), ("data", "model"))
