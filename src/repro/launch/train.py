"""End-to-end trainer (runs on real devices — CPU here, TPU in production).

Wires together every substrate: config registry, mesh + sharding rules,
synthetic data pipeline with optional DSLog lineage logging, AdamW,
checkpoint/restart, straggler watchdog.  ``examples/train_lm.py`` drives a
~100M-param model for a few hundred steps with this entry point.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs import SHAPES, get_arch
from ..configs.base import ShapeConfig
from ..core.catalog import DSLog
from ..data.pipeline import PipelineConfig, TokenPipeline
from ..distributed.elastic import StepWatchdog
from ..distributed.sharding import batch_sharding, default_rules, param_sharding
from ..models.model import init_model
from ..optim.adamw import AdamWConfig, adamw_init
from .mesh import local_mesh
from .steps import attn_plan, make_train_step

__all__ = ["train_loop", "main"]


def train_loop(
    cfg,
    shape: ShapeConfig,
    steps: int = 100,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    lineage_dir: str | None = None,
    model_parallel: int = 1,
    log_every: int = 10,
    seed: int = 0,
    opt_cfg: AdamWConfig | None = None,
):
    mesh = local_mesh(model_parallel)
    rules = default_rules(mesh)
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps)
    plan = attn_plan(cfg, shape, dp_total=int(mesh.shape["data"]))

    params, specs = init_model(jax.random.PRNGKey(seed), cfg)
    opt_state = adamw_init(params)
    p_shard = param_sharding(mesh, specs, rules, params)
    params = jax.tree.map(jax.device_put, params, p_shard)
    opt_state = {
        "m": jax.tree.map(jax.device_put, opt_state["m"], p_shard),
        "v": jax.tree.map(jax.device_put, opt_state["v"], p_shard),
        "step": opt_state["step"],
    }

    dslog = DSLog(root=lineage_dir) if lineage_dir else None
    pipe = TokenPipeline(
        PipelineConfig(cfg.vocab, shape.seq_len, shape.global_batch, seed),
        data_shards=int(mesh.shape["data"]),
        shard_id=0,
        dslog=dslog,
    )
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None

    start_step = 0
    if mgr is not None and mgr.latest_step() is not None:
        restored, extra = mgr.restore(
            shardings={
                "params": p_shard,
                "opt": {"m": p_shard, "v": p_shard},
            }
        )
        if restored is not None:
            params = restored["params"]
            opt_state = {**restored["opt"], "step": jnp.asarray(
                restored["opt"].get("step", extra["step"]), jnp.int32
            )}
            pipe.load_state_dict(extra["pipeline"])
            start_step = int(extra["step"]) + 1
            print(f"resumed from step {start_step - 1}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, plan), donate_argnums=(0, 1))
    watchdog = StepWatchdog()
    history = []
    with mesh:
        for step in range(start_step, steps):
            batch_np = pipe.next_batch()
            batch = {"tokens": jnp.asarray(batch_np["tokens"])}
            if cfg.encoder_only:
                batch = {
                    "frames": jax.random.normal(
                        jax.random.PRNGKey(step),
                        (shape.global_batch, shape.seq_len, cfg.frontend_dim),
                    ),
                    "labels": jnp.asarray(batch_np["tokens"]) % cfg.vocab,
                }
            t0 = time.time()
            params, opt_state, metrics = watchdog.guard(
                step_fn, params, opt_state, batch
            )
            loss = float(metrics["loss"])
            history.append(loss)
            if step % log_every == 0 or step == steps - 1:
                dt = time.time() - t0
                print(
                    f"step {step:5d} loss {loss:8.4f} "
                    f"grad_norm {float(metrics['grad_norm']):7.3f} "
                    f"lr {float(metrics['lr']):.2e} ({dt:.2f}s)",
                    flush=True,
                )
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save(
                    step,
                    {"params": params, "opt": opt_state},
                    extra={"step": step, "pipeline": pipe.state_dict()},
                )
    if mgr is not None:
        mgr.wait()
    if dslog is not None:
        dslog.save()
    return params, history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lineage-dir", default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train")
    train_loop(
        cfg,
        shape,
        steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        lineage_dir=args.lineage_dir,
        model_parallel=args.model_parallel,
    )


if __name__ == "__main__":
    main()
