import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is now locked) --------- #
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as PS  # noqa: E402

from ..configs import ARCHS, SHAPES, get_arch, skip_reason  # noqa: E402
from ..distributed.sharding import (  # noqa: E402
    batch_sharding,
    cache_sharding,
    default_rules,
    param_sharding,
    set_activation_mesh,
)
from ..optim.adamw import AdamWConfig  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .steps import (  # noqa: E402
    abstract_caches,
    abstract_model,
    abstract_opt_state,
    attn_plan,
    input_specs,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture × input shape) cell this lowers + compiles the full
step — ``train_step`` (fwd + bwd + AdamW update) for ``train_*`` shapes,
``prefill``/``serve_step`` for inference shapes — against the production
mesh with 512 placeholder CPU devices, then extracts:

* ``compiled.memory_analysis()``  → per-device residency (proves it fits),
* ``compiled.cost_analysis()``    → HLO FLOPs / bytes for §Roofline,
* the collective schedule (parsed from post-SPMD HLO) → collective bytes.

Results land in ``experiments/dryrun/<mesh>/<arch>__<shape>.json`` and feed
``benchmarks/roofline.py``.
"""

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Per-kind {count, bytes} from post-partitioning HLO.

    Bytes = result-buffer sizes of each collective op (per participating
    device).  ``-done`` ops are skipped so async pairs count once.
    """
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        hit = None
        for kind in _COLLECTIVES:
            if f" {kind}(" in line or f" {kind}-start(" in line:
                hit = kind
                break
        if hit is None or "-done(" in line:
            continue
        lhs = line.split("=", 1)
        if len(lhs) != 2:
            continue
        # result type(s) sit between '=' and the op name
        rhs = lhs[1]
        op_pos = rhs.find(hit)
        size = sum(
            _shape_bytes(m.group(1), m.group(2))
            for m in shape_re.finditer(rhs[:op_pos])
            if m.group(1) in _DTYPE_BYTES
        )
        out[hit]["count"] += 1
        out[hit]["bytes"] += size
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


def _sharded_bytes(shapes_tree, shardings_tree, n_devices: int) -> int:
    """Per-device bytes of a spec tree under its shardings."""
    total = 0
    flat_s, _ = jax.tree.flatten(shapes_tree)
    flat_sh, _ = jax.tree.flatten(shardings_tree)
    for s, sh in zip(flat_s, flat_sh):
        nbytes = int(np.prod(s.shape)) * s.dtype.itemsize if s.shape else s.dtype.itemsize
        if isinstance(sh, NamedSharding):
            spec = sh.spec
            denom = 1
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                for a in axes:
                    denom *= sh.mesh.shape[a]
            nbytes //= max(denom, 1)
        total += nbytes
    return total


# --------------------------------------------------------------------------- #
def _lower_variant(cfg, shape, mesh, rules, plan):
    """Lower+compile one variant; returns (cost dict, collectives dict)."""
    param_shapes, param_specs = abstract_model(cfg, jnp.bfloat16)
    p_shard = param_sharding(mesh, param_specs, rules, param_shapes)
    batch = input_specs(cfg, shape)
    if shape.kind == "train":
        opt_shapes = abstract_opt_state(param_shapes)
        o_shard = {"m": p_shard, "v": p_shard, "step": NamedSharding(mesh, PS())}
        b_shard = batch_sharding(mesh, batch, rules)
        step = make_train_step(cfg, AdamWConfig(), plan)
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard))
        args = (param_shapes, opt_shapes, batch)
    elif shape.kind == "prefill":
        b_shard = batch_sharding(mesh, batch, rules)
        step = make_prefill_step(cfg, shape, plan)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
        args = (param_shapes, batch)
    else:
        caches = abstract_caches(cfg, shape.global_batch, shape.seq_len, jnp.bfloat16)
        c_shard = cache_sharding(mesh, caches, cfg.n_kv_heads, shape.global_batch, rules)
        tok_shard = (
            batch_sharding(mesh, batch, rules)["token"]
            if shape.global_batch > 1
            else NamedSharding(mesh, PS(None, None))
        )
        step = make_decode_step(cfg, layer_unroll=plan.get("layer_unroll", 1))
        jitted = jax.jit(
            step, in_shardings=(p_shard, tok_shard, c_shard, NamedSharding(mesh, PS()))
        )
        args = (param_shapes, batch["token"], caches, jax.ShapeDtypeStruct((), jnp.int32))
    with mesh:
        compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    cost = {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}
    coll = parse_collectives(compiled.as_text())
    return cost, coll


def account_cell(cfg, shape, mesh, rules, plan):
    """Loop-accurate HLO cost accounting.

    XLA cost analysis counts a while-loop body once regardless of trip
    count, so the scan-over-layers production program under-reports.  We
    lower fully-unrolled 1-layer and 2-layer variants and extrapolate:
    ``total = c1 + (L - 1) * (c2 - c1)`` — the difference isolates exactly
    one layer (embedding/head/optimizer tails cancel), remat recompute
    included.  Inner chunk scans are unrolled too.
    """
    import dataclasses

    # cap the unrolled-accounting microbatch count: total FLOPs/bytes are
    # n_micro-invariant (same tokens), only per-microbatch weight gathers
    # scale — corrected analytically below.
    nm_prod = int(plan.get("n_micro", 1))
    nm_acc = min(nm_prod, 8)
    plan_acc = {**plan, "unroll": True, "layer_unroll": True,
                "micro_unroll": True, "n_micro": nm_acc}
    cfg1 = dataclasses.replace(cfg, n_layers=1)
    cfg2 = dataclasses.replace(cfg, n_layers=2)
    cost1, coll1 = _lower_variant(cfg1, shape, mesh, rules, plan_acc)
    cost2, coll2 = _lower_variant(cfg2, shape, mesh, rules, plan_acc)
    gather_scale = nm_prod / nm_acc if nm_prod > nm_acc else 1.0
    L = cfg.n_layers
    out_cost = {}
    for k in ("flops", "bytes accessed", "transcendentals"):
        if k in cost1 and k in cost2:
            # clamp: at tiny decode sizes compiler noise can make the
            # 2-layer module cheaper than 1-layer; a layer never costs < 0
            out_cost[k] = cost1[k] + (L - 1) * max(0.0, cost2[k] - cost1[k])
    out_coll = {}
    for kind in _COLLECTIVES:
        b1, b2 = coll1[kind]["bytes"], coll2[kind]["bytes"]
        n1, n2 = coll1[kind]["count"], coll2[kind]["count"]
        scale = gather_scale if kind == "all-gather" else 1.0
        out_coll[kind] = {
            "bytes": int(scale * (b1 + (L - 1) * max(0, b2 - b1))),
            "count": int(scale * (n1 + (L - 1) * max(0, n2 - n1))),
        }
    out_coll["total_bytes"] = sum(v["bytes"] for v in out_coll.values())
    return out_cost, out_coll


def lower_cell(
    arch_name: str,
    shape_name: str,
    multi_pod: bool,
    overrides: dict | None = None,
):
    """Build + lower + compile one cell; returns the result record.

    ``overrides`` are the §Perf hillclimbing knobs: ``remat``
    (nothing/dots/full), ``attn_heads`` activation policy
    (auto/tp_uneven/seq/batch_only), ``chunk`` (attention KV chunk size),
    ``skip_account`` (skip the 1L/2L accounting pass).
    """
    import dataclasses

    overrides = overrides or {}
    cfg = get_arch(arch_name)
    if overrides.get("remat"):
        cfg = dataclasses.replace(cfg, remat=overrides["remat"])
    if overrides.get("moe_dispatch") and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=overrides["moe_dispatch"])
        )
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape_name)
    if reason:
        return {"arch": arch_name, "shape": shape_name, "status": "skip", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(mesh)
    policy = {k: overrides[k] for k in ("attn_heads",) if overrides.get(k)}
    set_activation_mesh(mesh, rules, policy)
    dp_total = mesh.shape["data"] * mesh.shape.get("pod", 1)
    plan = attn_plan(cfg, shape, dp_total)
    if overrides.get("n_micro"):
        plan = {**plan, "n_micro": int(overrides["n_micro"])}
    if overrides.get("chunk"):
        plan = {**plan, "chunk": int(overrides["chunk"])}
    if overrides.get("attn_impl"):
        plan = {**plan, "mode": overrides["attn_impl"]}
    record = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "kind": shape.kind,
        "plan": plan,
        "overrides": overrides,
    }
    t0 = time.time()

    param_shapes, param_specs = abstract_model(cfg, jnp.bfloat16)
    p_shard = param_sharding(mesh, param_specs, rules, param_shapes)
    batch = input_specs(cfg, shape)

    if shape.kind == "train":
        opt_shapes = abstract_opt_state(param_shapes)
        o_shard = jax.tree.map(
            lambda _: None, opt_shapes
        )
        # optimizer state shards exactly like its parameter (ZeRO)
        o_shard = {
            "m": p_shard,
            "v": p_shard,
            "step": NamedSharding(mesh, PS()),
        }
        b_shard = batch_sharding(mesh, batch, rules)
        step = make_train_step(cfg, AdamWConfig(), plan)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        args = (param_shapes, opt_shapes, batch)
        state_bytes = _sharded_bytes(
            (param_shapes, opt_shapes), (p_shard, o_shard), mesh.size
        )
    elif shape.kind == "prefill":
        b_shard = batch_sharding(mesh, batch, rules)
        step = make_prefill_step(cfg, shape, plan)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
        args = (param_shapes, batch)
        state_bytes = _sharded_bytes(param_shapes, p_shard, mesh.size)
    else:  # decode
        cache_dtype = (
            jnp.float8_e4m3fn
            if overrides.get("cache_dtype") == "fp8"
            else jnp.bfloat16
        )
        caches = abstract_caches(cfg, shape.global_batch, shape.seq_len, cache_dtype)
        c_shard = cache_sharding(
            mesh, caches, cfg.n_kv_heads, shape.global_batch, rules
        )
        tok_shard = (
            batch_sharding(mesh, batch, rules)["token"]
            if shape.global_batch > 1
            else NamedSharding(mesh, PS(None, None))
        )
        step = make_decode_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, tok_shard, c_shard, NamedSharding(mesh, PS())),
            out_shardings=(None, c_shard),
            donate_argnums=(2,),
        )
        args = (
            param_shapes,
            batch["token"],
            caches,
            jax.ShapeDtypeStruct((), jnp.int32),
        )
        state_bytes = _sharded_bytes(
            (param_shapes, caches), (p_shard, c_shard), mesh.size
        )

    with mesh:
        lowered = jitted.lower(*args)
        record["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)

    # ---- analyses ------------------------------------------------------- #
    try:
        mem = compiled.memory_analysis()
        record["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        } if mem is not None else None
    except Exception as e:  # CPU backend may not implement it
        record["memory_analysis"] = f"unavailable: {e}"
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        record["cost_analysis"] = {
            k: float(v)
            for k, v in cost.items()
            if isinstance(v, (int, float)) and k in (
                "flops", "bytes accessed", "bytes accessed output",
                "transcendentals", "optimal_seconds",
            )
        }
    except Exception as e:
        record["cost_analysis"] = f"unavailable: {e}"
    hlo = compiled.as_text()
    record["collectives_scan_program"] = parse_collectives(hlo)
    record["hlo_bytes"] = len(hlo)
    record["state_bytes_per_device"] = state_bytes

    # loop-accurate accounting via unrolled 1L/2L extrapolation
    if overrides.get("skip_account"):
        record["collectives"] = record["collectives_scan_program"]
    else:
        try:
            acc_cost, acc_coll = account_cell(cfg, shape, mesh, rules, plan)
            record["cost_accounted"] = acc_cost
            record["collectives"] = acc_coll
        except Exception as e:
            record["cost_accounted"] = f"unavailable: {type(e).__name__}: {e}"
            record["collectives"] = record["collectives_scan_program"]
    record["status"] = "ok"
    return record


def run(arch_names, shape_names, multi_pod: bool, out_dir: str,
        overrides: dict | None = None, tag: str = "") -> list[dict]:
    mesh_tag = ("pod2x16x16" if multi_pod else "pod16x16") + (
        f"_{tag}" if tag else ""
    )
    os.makedirs(os.path.join(out_dir, mesh_tag), exist_ok=True)
    records = []
    for a in arch_names:
        for s in shape_names:
            path = os.path.join(out_dir, mesh_tag, f"{a}__{s}.json")
            try:
                rec = lower_cell(a, s, multi_pod, overrides)
            except Exception as e:
                rec = {
                    "arch": a,
                    "shape": s,
                    "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:],
                }
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            flops = (rec.get("cost_analysis") or {})
            flops = flops.get("flops") if isinstance(flops, dict) else None
            print(
                f"[{mesh_tag}] {a:18s} {s:12s} -> {rec['status']:5s}"
                + (f" compile={rec.get('compile_s')}s flops={flops:.3e}"
                   if rec["status"] == "ok" and flops else "")
                + (f" ({rec.get('reason','')[:60]})" if rec["status"] == "skip" else "")
                + (f" ERR {rec.get('error','')[:120]}" if rec["status"] == "error" else ""),
                flush=True,
            )
            records.append(rec)
    return records


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--remat", default="")
    ap.add_argument("--attn-heads", default="")
    ap.add_argument("--attn-impl", default="")
    ap.add_argument("--moe-dispatch", default="")
    ap.add_argument("--chunk", default="")
    ap.add_argument("--n-micro", default="")
    ap.add_argument("--cache-dtype", default="")
    ap.add_argument("--skip-account", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for the output subdir")
    args = ap.parse_args()
    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    overrides = {
        k: v
        for k, v in (
            ("remat", args.remat),
            ("attn_heads", args.attn_heads),
            ("attn_impl", args.attn_impl),
            ("moe_dispatch", args.moe_dispatch),
            ("chunk", args.chunk),
            ("n_micro", args.n_micro),
            ("cache_dtype", args.cache_dtype),
            ("skip_account", args.skip_account),
        )
        if v
    }
    for mp in meshes:
        run(archs, shapes, mp, args.out, overrides, args.tag)


if __name__ == "__main__":
    main()
