"""Step functions + abstract input/state specs shared by the dry-run, the
trainer and the server.

``input_specs`` returns ``ShapeDtypeStruct`` stand-ins for every model input
(weak-type-correct, shardable, no device allocation); ``abstract_state``
does the same for params/optimizer/caches so the dry-run lowers the full
update step against the production mesh without materializing 314B params.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, ArchConfig, ShapeConfig
from ..models.blocks import init_caches
from ..models.model import decode_step, init_model, lm_loss, prefill
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update

__all__ = [
    "input_specs",
    "abstract_model",
    "abstract_caches",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "attn_plan",
]


def attn_plan(cfg: ArchConfig, shape: ShapeConfig, dp_total: int = 16) -> dict:
    """Static attention/memory plan per (arch, shape).

    ``n_micro`` (gradient-accumulation microbatches) is sized so the
    per-device checkpointed layer inputs stay ~<= 3 GB:
        act_bytes = B_local * S * D * 2 * L / n_micro.
    """
    plan = {
        "mode": "dot" if shape.seq_len <= 2048 else "chunked",
        "chunk": 1024 if shape.seq_len >= 32768 else 512,
        "unroll": 1,
        "layer_unroll": 1,
        "n_micro": 1,
    }
    if shape.kind == "train":
        b_local = max(1, shape.global_batch // dp_total)
        act_gb = (
            b_local * shape.seq_len * cfg.d_model * 2 * cfg.n_layers / 1e9
        )
        n = 1
        while act_gb / n > 3.0 and n < b_local:
            n *= 2
        plan["n_micro"] = n
    return plan


# --------------------------------------------------------------------------- #
# Abstract specs
# --------------------------------------------------------------------------- #
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for the data batch of one step."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        specs = {"token": jax.ShapeDtypeStruct((b, 1), i32)}
        return specs
    if cfg.frontend == "frames":
        return {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    if cfg.frontend == "patch":
        return {
            "tokens": jax.ShapeDtypeStruct((b, s - cfg.frontend_len), i32),
            "patch_embeds": jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.d_model), jnp.bfloat16
            ),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}


def abstract_model(cfg: ArchConfig, dtype=jnp.bfloat16):
    """(param ShapeDtypeStruct tree, logical spec tree) without allocation."""
    captured = {}

    def f(k):
        vals, specs = init_model(k, cfg, dtype)
        captured["specs"] = specs
        return vals

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, captured["specs"]


def abstract_caches(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_caches(cfg, batch, max_len, dtype))


def abstract_opt_state(param_shapes):
    return jax.eval_shape(adamw_init, param_shapes)


# --------------------------------------------------------------------------- #
# Step functions
# --------------------------------------------------------------------------- #
def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, plan: dict):
    """Full update step; ``plan["n_micro"] > 1`` runs gradient accumulation
    over microbatches (a lax.scan), bounding live activations to one
    microbatch — the feature that lets the 80-layer/314B configs fit v5e
    HBM at 1M-token global batches."""
    loss_fn = functools.partial(
        lm_loss,
        cfg=cfg,
        mode=plan["mode"],
        chunk=plan["chunk"],
        unroll=plan.get("unroll", 1),
        layer_unroll=plan.get("layer_unroll", 1),
    )
    n_micro = int(plan.get("n_micro", 1))

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, (ce, aux)), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(
                    (n_micro, x.shape[0] // n_micro) + tuple(x.shape[1:])
                ),
                batch,
            )

            def body(carry, mb):
                gsum, ce_sum, aux_sum = carry
                (l, (ce_i, aux_i)), g = jax.value_and_grad(
                    loss_fn, has_aux=True
                )(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return (gsum, ce_sum + ce_i, aux_sum + aux_i), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, ce, aux), _ = jax.lax.scan(
                body,
                (g0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                micro,
                unroll=plan.get("micro_unroll", 1),
            )
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            ce, aux = ce / n_micro, aux / n_micro
            loss = ce
        params, opt_state, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {**metrics, "loss": loss, "ce": ce, "aux": aux}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, shape: ShapeConfig, plan: dict):
    def prefill_step(params, batch):
        return prefill(
            params, batch, cfg, shape.seq_len,
            mode=plan["mode"], chunk=plan["chunk"],
            unroll=plan.get("unroll", 1),
            layer_unroll=plan.get("layer_unroll", 1),
        )

    return prefill_step


def make_decode_step(cfg: ArchConfig, layer_unroll: int = 1):
    def serve_step(params, token, caches, cur_len):
        return decode_step(params, token, caches, cur_len, cfg, layer_unroll)

    return serve_step
