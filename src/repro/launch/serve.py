"""Batched serving loop: prefill a batch of prompts, then greedy-decode.

CPU-runnable demonstration of the decode path with KV/SSM caches;
``examples/serve_decode.py`` drives it.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_arch
from ..models.blocks import init_caches
from ..models.model import decode_step, forward, init_model

__all__ = ["generate", "main"]


def generate(
    cfg,
    params,
    prompts: jnp.ndarray,
    max_new_tokens: int = 16,
    greedy: bool = True,
    seed: int = 0,
):
    """prompts: [B, S0] int32 → [B, S0 + max_new_tokens]."""
    b, s0 = prompts.shape
    max_len = s0 + max_new_tokens + 1
    caches = init_caches(cfg, b, max_len, jnp.float32)

    decode = jax.jit(
        lambda p, t, c, n: decode_step(p, t, c, n, cfg), donate_argnums=(2,)
    )
    # prompt ingestion via the decode path (token-by-token prefill keeps the
    # cache layout identical; fused prefill is a perf follow-up, §Perf)
    tokens = prompts
    logits = None
    for pos in range(s0):
        logits, caches = decode(params, tokens[:, pos : pos + 1], caches, jnp.int32(pos))
    key = jax.random.PRNGKey(seed)
    for i in range(max_new_tokens):
        if greedy:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits[:, -1])[:, None].astype(jnp.int32)
        tokens = jnp.concatenate([tokens, nxt], axis=1)
        logits, caches = decode(params, nxt, caches, jnp.int32(s0 + i))
    return tokens


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.encoder_only:
        raise SystemExit("encoder-only architectures have no decode path")
    key = jax.random.PRNGKey(0)
    params, _ = init_model(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(cfg, params, prompts, args.new_tokens)
    dt = time.time() - t0
    n_new = args.batch * args.new_tokens
    print(f"generated {out.shape} in {dt:.2f}s ({n_new / dt:.1f} tok/s)")
    print(out[:, args.prompt_len :])


if __name__ == "__main__":
    main()
