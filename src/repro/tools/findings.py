"""The shared finding schema every analysis tool's ``--json`` mode emits.

One flat record shape — ``{tool, rule, severity, path, line, message}`` —
so the ROADMAP's future health endpoint (and CI's artifact consumers)
parse a single format regardless of which layer produced the finding:

* ``tool``      — producing tool name (``dslint``, ``dsflow``, ``fsck``)
* ``rule``      — the rule / check category within that tool
* ``severity``  — ``error`` | ``warn`` | ``info``
* ``path``      — file (or store-relative object) the finding is about
* ``line``      — 1-based source line, or 0 when lines don't apply
  (on-disk store objects, whole-file findings)
* ``message``   — human-readable detail
"""

from __future__ import annotations

SCHEMA_KEYS = ("tool", "rule", "severity", "path", "line", "message")
SEVERITIES = ("error", "warn", "info")


def finding_dict(
    tool: str, rule: str, severity: str, path: str, line: int, message: str
) -> dict:
    """A schema-shaped finding record (validated)."""
    rec = {
        "tool": tool,
        "rule": rule,
        "severity": severity,
        "path": path,
        "line": line,
        "message": message,
    }
    validate_finding(rec)
    return rec


def validate_finding(rec: object) -> None:
    """Raise ``ValueError`` unless ``rec`` is a valid shared-schema record."""
    if not isinstance(rec, dict):
        raise ValueError(f"finding must be a dict, got {type(rec).__name__}")
    missing = [k for k in SCHEMA_KEYS if k not in rec]
    if missing:
        raise ValueError(f"finding missing keys {missing}: {rec!r}")
    for key in ("tool", "rule", "severity", "path", "message"):
        if not isinstance(rec[key], str):
            raise ValueError(f"finding[{key!r}] must be a string: {rec!r}")
    if not isinstance(rec["line"], int) or isinstance(rec["line"], bool):
        raise ValueError(f"finding['line'] must be an int: {rec!r}")
    if rec["line"] < 0:
        raise ValueError(f"finding['line'] must be >= 0: {rec!r}")
    if rec["severity"] not in SEVERITIES:
        raise ValueError(
            f"finding['severity'] must be one of {SEVERITIES}: {rec!r}"
        )


def validate_findings(recs: object) -> int:
    """Validate a list of records; returns the count."""
    if not isinstance(recs, list):
        raise ValueError(f"findings must be a list, got {type(recs).__name__}")
    for rec in recs:
        validate_finding(rec)
    return len(recs)
