"""``fsck`` — deep on-disk verifier for DSLog stores (layer 3).

Usage::

    python -m repro.tools.fsck <store-root> [--json]

Opens nothing for writing and never mutates the store: every check reads
raw bytes (WAL scanning reimplemented read-only here rather than through
``WriteAheadLog``, whose constructor opens the file ``r+``).  Checks:

* **manifest ↔ blob closure** — every ``TableHandle`` the manifest would
  mint resolves to a decodable blob (no dangling handles), and no
  catalog-owned ``lineage_*``/``sig_*``/``.idx`` file is orphaned.  The
  closure comes from ``repro.core.catalog.manifest_referenced_files`` — the
  exact helper ``compact()``'s vacuum uses, so GC and verification cannot
  disagree.
* **WAL integrity** — header magic, ``base_lsn`` ≤ the manifest's
  checkpoint LSN, per-record crc32.  A file that simply ends mid-record is
  an honest torn tail (warning: recovery truncates it); a crc mismatch
  with intact records *after* it is mid-log corruption (error: those
  records would be silently discarded).
* **DAG acyclicity** and, on sharded roots, **shard-map agreement**: every
  edge's recorded shard matches its dst array's shard, boundary records
  match a recomputation from the edge list, and each edge's entry exists in
  the owning shard (unless that shard still has WAL records pending —
  legitimate after a crash between shard save and root save).
* **interval invariants** — each blob's ``lo ≤ hi`` per attribute,
  ``val_ref`` within the key arity, row counts equal to the manifest's.
* **materialized views** — every view blob decodes, every lineage id on a
  view's route still exists, and no WAL holds an invalidation the view
  predates: a ``dirty``/``drop`` record for an id on the route, or an
  ``entry`` record landing inside the route (an endpoint upstream of the
  view's source and one downstream of its target), with an LSN past the
  view's recorded horizon for that log, makes the view **stale** (error —
  its rows no longer describe the store).  The answer-cache sidecar
  (``answers.json``) must parse; a torn sidecar is a warning (reopen
  starts cold).
* **lease / writer-slot liveness** — stale ``writer.lock`` files and
  writer-presence slots left by dead processes (warning).

Severities: ``error`` (store integrity violated), ``warn`` (legitimate
crash debris / GC backlog), ``info``.  Exit codes: **0** no errors (warns
allowed — a crashed-but-recoverable store passes), **1** at least one
error, **2** usage error / path is not a store.
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys
import zlib
from dataclasses import dataclass

from repro.core.catalog import is_catalog_blob, manifest_referenced_files
from repro.core.commit import WriterLease, _pid_alive
from repro.core.table import CompressedTable
from repro.core.wal import _HEADER_SIZE, _MAGIC, _REC_HEADER, WAL_FILENAME

# how far past a bad record we look for intact records that would be lost
_RESYNC_SCAN_CAP = 4 << 20


@dataclass
class Finding:
    severity: str  # "error" | "warn" | "info"
    category: str
    path: str
    detail: str

    def __str__(self) -> str:
        return f"{self.severity}: [{self.category}] {self.path}: {self.detail}"


class Report:
    def __init__(self, root: str):
        self.root = root
        self.findings: list[Finding] = []
        self.checked: dict[str, int] = {
            "blobs": 0,
            "wal_records": 0,
            "entries": 0,
            "shards": 0,
            "views": 0,
        }

    def add(self, severity: str, category: str, path: str, detail: str) -> None:
        self.findings.append(Finding(severity, category, path, detail))

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "warn"]

    def categories(self) -> set[str]:
        return {f.category for f in self.findings}

    @property
    def ok(self) -> bool:
        return not self.errors

    def to_json(self) -> dict:
        # findings use the shared analysis-tool schema (repro.tools.findings):
        # the rule is the fsck category, the message its detail, and line is
        # 0 — findings are about on-disk store objects, not source lines
        from .findings import finding_dict

        return {
            "root": self.root,
            "ok": self.ok,
            "checked": dict(self.checked),
            "findings": [
                finding_dict(
                    "fsck", f.category, f.severity, f.path, 0, f.detail
                )
                for f in self.findings
            ],
        }


# --------------------------------------------------------------------------
# WAL scanning (read-only reimplementation of the record framing)
# --------------------------------------------------------------------------


def _check_wal(report: Report, path: str, manifest_lsn: int | None) -> None:
    rel = os.path.relpath(path, report.root)
    try:
        with open(path, "rb") as f:
            data = f.read()
    except OSError as exc:
        report.add("error", "wal-header", rel, f"unreadable: {exc}")
        return
    if len(data) < _HEADER_SIZE:
        # an honest crash can tear the header of a just-created log;
        # recovery rewrites it, losing nothing that was ever acknowledged
        report.add("warn", "wal-header", rel, f"short header ({len(data)} bytes)")
        return
    if data[: len(_MAGIC)] != _MAGIC:
        report.add("error", "wal-header", rel, "bad magic")
        return
    (base_lsn,) = struct.unpack_from("<Q", data, len(_MAGIC))
    if manifest_lsn is not None and base_lsn > manifest_lsn:
        report.add(
            "error",
            "wal-lsn",
            rel,
            f"base_lsn {base_lsn} is past the manifest checkpoint LSN "
            f"{manifest_lsn}: records between them are unrecoverable",
        )
    off = _HEADER_SIZE
    end = len(data)
    while off < end:
        if end - off < _REC_HEADER.size:
            report.add(
                "warn",
                "wal-torn-tail",
                rel,
                f"{end - off} trailing bytes form no record header "
                f"(recovery truncates to offset {off})",
            )
            return
        length, crc = _REC_HEADER.unpack_from(data, off)
        body_at = off + _REC_HEADER.size
        if end - body_at < length:
            report.add(
                "warn",
                "wal-torn-tail",
                rel,
                f"record at offset {off} claims {length} bytes, only "
                f"{end - body_at} present (torn tail)",
            )
            return
        payload = data[body_at : body_at + length]
        if zlib.crc32(payload) != crc:
            report.add(
                "error",
                "wal-crc",
                rel,
                f"crc mismatch on complete record at offset {off}",
            )
            _resync_scan(report, rel, data, body_at + length)
            return
        try:
            (jlen,) = struct.unpack_from("<I", payload, 0)
            json.loads(payload[4 : 4 + jlen])
        except (struct.error, ValueError) as exc:
            report.add(
                "error",
                "wal-record",
                rel,
                f"record at offset {off} has valid crc but undecodable "
                f"payload: {exc}",
            )
        report.checked["wal_records"] += 1
        off = body_at + length


def _resync_scan(report: Report, rel: str, data: bytes, start: int) -> None:
    """After a bad record: do intact records follow it?  Then this is not a
    torn tail — recovery would silently discard durable records."""
    end = min(len(data), start + _RESYNC_SCAN_CAP)
    off = start
    while off + _REC_HEADER.size <= end:
        length, crc = _REC_HEADER.unpack_from(data, off)
        body_at = off + _REC_HEADER.size
        if 0 < length <= end - body_at and zlib.crc32(
            data[body_at : body_at + length]
        ) == crc:
            report.add(
                "error",
                "wal-crc",
                rel,
                f"intact record found at offset {off}, past the corrupt "
                "one: mid-log corruption strands durable records",
            )
            return
        off += 1


# --------------------------------------------------------------------------
# blob checks
# --------------------------------------------------------------------------


def _check_blob(
    report: Report,
    directory: str,
    fn: str,
    expect_rows: int | None,
) -> None:
    rel = os.path.relpath(os.path.join(directory, fn), report.root)
    path = os.path.join(directory, fn)
    if not os.path.isfile(path):
        report.add("error", "dangling-handle", rel, "manifest references a missing blob")
        return
    try:
        with open(path, "rb") as f:
            table = CompressedTable.deserialize(f.read())
    except Exception as exc:
        report.add("error", "blob-decode", rel, f"undecodable table blob: {exc}")
        return
    report.checked["blobs"] += 1
    if expect_rows is not None and table.n_rows != int(expect_rows):
        report.add(
            "error",
            "blob-invariant",
            rel,
            f"manifest says {expect_rows} rows, blob holds {table.n_rows}",
        )
    if (table.key_lo > table.key_hi).any():
        report.add("error", "blob-invariant", rel, "key interval with lo > hi")
    if (table.val_lo > table.val_hi).any():
        report.add("error", "blob-invariant", rel, "value interval with lo > hi")
    if table.n_rows and (
        (table.val_ref < -1) | (table.val_ref >= table.n_key)
    ).any():
        report.add(
            "error",
            "blob-invariant",
            rel,
            f"val_ref outside [-1, {table.n_key})",
        )


# --------------------------------------------------------------------------
# materialized-view checks
# --------------------------------------------------------------------------


def _scan_wal_payloads(path: str) -> list[tuple[str, dict, int]]:
    """Decoded ``(type, meta, end_lsn)`` for every intact record (read-only;
    integrity findings are ``_check_wal``'s job — here a bad frame just ends
    the scan, exactly as recovery would)."""
    out: list[tuple[str, dict, int]] = []
    try:
        with open(path, "rb") as f:
            data = f.read()
    except OSError:
        return out
    if len(data) < _HEADER_SIZE or data[: len(_MAGIC)] != _MAGIC:
        return out
    (base_lsn,) = struct.unpack_from("<Q", data, len(_MAGIC))
    off = _HEADER_SIZE
    while len(data) - off >= _REC_HEADER.size:
        length, crc = _REC_HEADER.unpack_from(data, off)
        body_at = off + _REC_HEADER.size
        if len(data) - body_at < length:
            break
        payload = data[body_at : body_at + length]
        if zlib.crc32(payload) != crc:
            break
        try:
            (jlen,) = struct.unpack_from("<I", payload, 0)
            head = json.loads(payload[4 : 4 + jlen])
            rtype = head.pop("t")
            head.pop("nb", None)
        except (struct.error, ValueError):
            break
        off = body_at + length
        out.append((rtype, head, base_lsn + (off - _HEADER_SIZE)))
    return out


def _reach(adj: dict[str, set[str]], start: str) -> set[str]:
    seen = {start}
    frontier = [start]
    while frontier:
        for nxt in adj.get(frontier.pop(), ()):
            if nxt not in seen:
                seen.add(nxt)
                frontier.append(nxt)
    return seen


def _check_views(
    report: Report,
    directory: str,
    views_chunk: dict | None,
    known_lids: set[int],
    known_arrays: set[str],
    base_edges: list[tuple[int, str, str]],
    wal_paths: dict[str, str],
) -> None:
    """Blob closure, route closure, and WAL-precise staleness for every
    persisted view.  ``base_edges`` is the manifest's ``(lid, src, dst)``
    list; ``wal_paths`` maps each key of a view's ``lsns`` horizon dict to
    its log file."""
    rel_manifest = os.path.relpath(
        os.path.join(directory, "catalog.json"), report.root
    )
    recs = list(views_chunk.get("views", [])) if views_chunk else []
    sidecar = os.path.join(directory, "answers.json")
    if os.path.exists(sidecar):
        rel = os.path.relpath(sidecar, report.root)
        try:
            with open(sidecar) as f:
                chunk = json.load(f)
            for ent in chunk.get("answers", []):
                ent["key"], ent["boxes"]  # shape probe
        except (OSError, ValueError, KeyError, TypeError) as exc:
            report.add(
                "warn",
                "answer-cache",
                rel,
                f"torn answer-cache sidecar ({exc}); reopen starts cold",
            )
    if not recs:
        return

    for rec in recs:
        report.checked["views"] += 1
        vid = rec.get("id")
        _check_blob(report, directory, rec["file"], rec.get("rows"))
        if rec.get("fwd"):
            _check_blob(report, directory, rec["fwd"], rec.get("fwd_rows"))
        for lid in rec.get("lids", []):
            if int(lid) not in known_lids:
                report.add(
                    "error",
                    "view-stale",
                    rel_manifest,
                    f"view {vid} composes lineage id {lid}, which the "
                    "manifest no longer holds",
                )
        for name in rec.get("arrays", []):
            if name not in known_arrays:
                report.add(
                    "error",
                    "view-stale",
                    rel_manifest,
                    f"view {vid} spans array {name!r}, which the manifest "
                    "no longer declares",
                )

    # WAL-precise staleness: replay each log's tail against the views,
    # firing the same rules the live invalidation hooks apply.
    for key, wal_path in sorted(wal_paths.items()):
        records = _scan_wal_payloads(wal_path)
        if not records:
            continue
        rel_wal = os.path.relpath(wal_path, report.root)
        fwd: dict[str, set[str]] = {}
        bwd: dict[str, set[str]] = {}
        by_lid: dict[int, tuple[str, str]] = {}
        for lid, src, dst in base_edges:
            fwd.setdefault(src, set()).add(dst)
            bwd.setdefault(dst, set()).add(src)
            by_lid[lid] = (src, dst)
        for rtype, m, lsn in records:
            horizon = lambda rec: int(rec.get("lsns", {}).get(key, 0))
            if rtype == "entry":
                src, dst = m["src"], m["dst"]
                fwd.setdefault(src, set()).add(dst)
                bwd.setdefault(dst, set()).add(src)
                by_lid[int(m["id"])] = (src, dst)
                up = _reach(bwd, src)
                down = _reach(fwd, dst)
                for rec in recs:
                    if (
                        lsn > horizon(rec)
                        and rec["src"] in up
                        and rec["dst"] in down
                    ):
                        report.add(
                            "error",
                            "view-stale",
                            rel_wal,
                            f"entry {m['id']} ({src}->{dst}, LSN {lsn}) lands "
                            f"on view {rec.get('id')}'s route past its "
                            f"horizon {horizon(rec)}",
                        )
            elif rtype in ("dirty", "drop"):
                lid = int(m["id"])
                if rtype == "drop" and lid in by_lid:
                    src, dst = by_lid.pop(lid)
                    fwd.get(src, set()).discard(dst)
                    bwd.get(dst, set()).discard(src)
                for rec in recs:
                    if lsn > horizon(rec) and lid in [
                        int(x) for x in rec.get("lids", [])
                    ]:
                        report.add(
                            "error",
                            "view-stale",
                            rel_wal,
                            f"{rtype} record for entry {lid} (LSN {lsn}) "
                            f"invalidates view {rec.get('id')} past its "
                            f"horizon {horizon(rec)}",
                        )


# --------------------------------------------------------------------------
# lease / writer-slot checks
# --------------------------------------------------------------------------


def _check_lease(report: Report, directory: str) -> None:
    path = os.path.join(directory, WriterLease.FILENAME)
    if not os.path.exists(path):
        return
    rel = os.path.relpath(path, report.root)
    try:
        with open(path) as f:
            holder = json.load(f)
    except (OSError, ValueError):
        report.add("warn", "stale-lease", rel, "unreadable lease file")
        return
    import socket

    if holder.get("host") == socket.gethostname() and "pid" in holder:
        if _pid_alive(int(holder["pid"])):
            report.add(
                "warn",
                "live-writer",
                rel,
                f"pid {holder['pid']} holds the writer lease; on-disk "
                "state may be mid-commit (findings may be transient)",
            )
        else:
            report.add(
                "warn",
                "stale-lease",
                rel,
                f"lease held by dead pid {holder['pid']} (crashed writer; "
                "the next open steals it)",
            )
    else:
        report.add("info", "foreign-lease", rel, f"lease held on host {holder.get('host')!r}")


def _check_writer_slots(report: Report, root: str) -> None:
    slots_dir = os.path.join(root, "writers")
    if not os.path.isdir(slots_dir):
        return
    import socket

    for slot in sorted(os.listdir(slots_dir)):
        sub = os.path.join(slots_dir, slot)
        holder = WriterLease.holder(sub)
        rel = os.path.relpath(sub, report.root)
        if holder is None:
            report.add("warn", "stale-lease", rel, "empty writer-presence slot")
            continue
        if holder.get("host") == socket.gethostname() and "pid" in holder:
            if not _pid_alive(int(holder["pid"])):
                report.add(
                    "warn",
                    "stale-lease",
                    rel,
                    f"writer slot held by dead pid {holder['pid']}",
                )
            else:
                report.add("warn", "live-writer", rel, f"pid {holder['pid']} is writing")


# --------------------------------------------------------------------------
# single-store (one DSLog directory: plain store or one shard)
# --------------------------------------------------------------------------


def _check_dag_acyclic(report: Report, rel: str, edges: list[tuple[str, str]]) -> None:
    adj: dict[str, list[str]] = {}
    for src, dst in edges:
        adj.setdefault(src, []).append(dst)
    WHITE, GREY, BLACK = 0, 1, 2
    colour: dict[str, int] = {}

    def visit(node: str) -> bool:
        colour[node] = GREY
        for nxt in adj.get(node, ()):
            c = colour.get(nxt, WHITE)
            if c == GREY:
                report.add(
                    "error",
                    "dag-cycle",
                    rel,
                    f"lineage graph contains a cycle through {nxt!r}",
                )
                return False
            if c == WHITE and not visit(nxt):
                return False
        colour[node] = BLACK
        return True

    for node in list(adj):
        if colour.get(node, WHITE) == WHITE:
            if not visit(node):
                return


def _check_store_dir(report: Report, directory: str) -> dict | None:
    """All checks for one DSLog directory; returns its parsed manifest."""
    rel_manifest = os.path.relpath(os.path.join(directory, "catalog.json"), report.root)
    manifest_path = os.path.join(directory, "catalog.json")
    wal_path = os.path.join(directory, WAL_FILENAME)
    meta: dict | None = None
    if os.path.exists(manifest_path):
        try:
            with open(manifest_path, "rb") as f:
                meta = json.loads(f.read().decode("utf-8"))
        except (ValueError, UnicodeDecodeError) as exc:
            report.add("error", "manifest-parse", rel_manifest, f"unparseable manifest: {exc}")
            meta = None
    elif not os.path.exists(wal_path):
        report.add(
            "error",
            "manifest-parse",
            rel_manifest,
            "no manifest and no WAL: not a store directory",
        )
        return None

    manifest_lsn = None
    lineage_recs: list[dict] = []
    predictor_chunk = None
    if meta is not None:
        manifest_lsn = int(meta.get("wal_lsn", 0)) if "wal_lsn" in meta else None
        lineage_recs = list(meta.get("lineage", []))
        predictor_chunk = meta.get("predictor")

    if os.path.exists(wal_path):
        _check_wal(report, wal_path, manifest_lsn)

    for rec in lineage_recs:
        report.checked["entries"] += 1
        _check_blob(report, directory, rec["file"], rec.get("rows"))
        if rec.get("fwd"):
            _check_blob(report, directory, rec["fwd"], rec.get("fwd_rows"))
        for key in ("idx", "fwd_idx"):
            if rec.get(key):
                path = os.path.join(directory, rec[key])
                if not os.path.isfile(path):
                    report.add(
                        "error",
                        "dangling-handle",
                        os.path.relpath(path, report.root),
                        "manifest references a missing index sidecar",
                    )

    if predictor_chunk:
        for sig in predictor_chunk.get("sigs", []):
            for fn in sig.get("tables", {}).values():
                _check_blob(report, directory, fn, None)

    if meta is not None:
        _check_dag_acyclic(
            report,
            rel_manifest,
            [(rec["src"], rec["dst"]) for rec in lineage_recs],
        )
        _check_views(
            report,
            directory,
            meta.get("views"),
            {int(rec["id"]) for rec in lineage_recs},
            set(meta.get("arrays", {})),
            [(int(r["id"]), r["src"], r["dst"]) for r in lineage_recs],
            {"": wal_path} if os.path.exists(wal_path) else {},
        )
        # orphan sweep with the exact closure compact() vacuums against
        referenced = manifest_referenced_files(
            lineage_recs, predictor_chunk, meta.get("views")
        )
        for fn in sorted(os.listdir(directory)):
            if not os.path.isfile(os.path.join(directory, fn)):
                continue
            if fn in referenced or not is_catalog_blob(fn):
                continue
            report.add(
                "warn",
                "orphan-blob",
                os.path.relpath(os.path.join(directory, fn), report.root),
                "catalog-owned blob not referenced by the manifest "
                "(compact() reclaims it)",
            )

    _check_lease(report, directory)
    return meta


# --------------------------------------------------------------------------
# sharded root
# --------------------------------------------------------------------------


def _wal_has_records(directory: str) -> bool:
    path = os.path.join(directory, WAL_FILENAME)
    try:
        return os.path.getsize(path) > _HEADER_SIZE
    except OSError:
        return False


def _check_sharded_root(report: Report, root: str, meta: dict) -> None:
    rel_manifest = os.path.relpath(os.path.join(root, "catalog.json"), report.root)
    n_shards = int(meta.get("n_shards", 0))
    arrays = meta.get("arrays", {})
    edges = meta.get("edges", [])
    boundary = meta.get("boundary", [])

    for name, rec in arrays.items():
        shard = int(rec.get("shard", -1))
        if not (0 <= shard < n_shards):
            report.add(
                "error",
                "shard-map",
                rel_manifest,
                f"array {name!r} assigned to shard {shard} of {n_shards}",
            )

    seen_lids: dict[int, int] = {}
    shard_manifests: dict[int, dict | None] = {}
    shard_pending: dict[int, bool] = {}
    for k in range(n_shards):
        sub = os.path.join(root, f"shard_{k:02d}")
        shard_pending[k] = _wal_has_records(sub)
        if os.path.isdir(sub):
            report.checked["shards"] += 1
            shard_manifests[k] = _check_store_dir(report, sub)
        else:
            shard_manifests[k] = None

    shard_entry_ids: dict[int, set[int]] = {}
    for k, smeta in shard_manifests.items():
        if smeta is not None:
            shard_entry_ids[k] = {int(r["id"]) for r in smeta.get("lineage", [])}

    for src, dst, lid, shard in edges:
        lid, shard = int(lid), int(shard)
        if lid in seen_lids:
            report.add(
                "error",
                "shard-map",
                rel_manifest,
                f"lineage id {lid} appears on shards {seen_lids[lid]} and {shard}",
            )
        seen_lids[lid] = shard
        if not (0 <= shard < n_shards):
            report.add(
                "error",
                "shard-map",
                rel_manifest,
                f"edge {src}->{dst} (id {lid}) on shard {shard} of {n_shards}",
            )
            continue
        dst_rec = arrays.get(dst)
        if dst_rec is not None and int(dst_rec.get("shard", -1)) != shard:
            report.add(
                "error",
                "shard-map",
                rel_manifest,
                f"edge {src}->{dst} (id {lid}) recorded on shard {shard}, "
                f"but array {dst!r} lives on shard {dst_rec.get('shard')}",
            )
        if shard in shard_entry_ids and lid not in shard_entry_ids[shard]:
            if not shard_pending.get(shard):
                report.add(
                    "error",
                    "shard-map",
                    rel_manifest,
                    f"root references entry {lid} that shard {shard}'s "
                    "manifest does not hold (and its WAL is empty)",
                )

    # boundary table must equal a recomputation from the edge list
    expect_boundary = set()
    for src, dst, lid, shard in edges:
        src_rec = arrays.get(src)
        if src_rec is not None and int(src_rec.get("shard", -1)) != int(shard):
            expect_boundary.add(int(lid))
    got_boundary = {int(rec[0]) for rec in boundary}
    for lid in sorted(expect_boundary - got_boundary):
        report.add(
            "error",
            "shard-map",
            rel_manifest,
            f"edge {lid} crosses shards but is missing from the boundary table",
        )
    for lid in sorted(got_boundary - expect_boundary):
        report.add(
            "error",
            "shard-map",
            rel_manifest,
            f"boundary table lists edge {lid}, which does not cross shards",
        )

    _check_dag_acyclic(
        report, rel_manifest, [(src, dst) for src, dst, _, _ in edges]
    )

    # root dir: WAL, predictor blobs, orphans, leases, writer slots
    manifest_lsn = int(meta["wal_lsn"]) if "wal_lsn" in meta else None
    wal_path = os.path.join(root, WAL_FILENAME)
    if os.path.exists(wal_path):
        _check_wal(report, wal_path, manifest_lsn)
    predictor_chunk = meta.get("predictor")
    if predictor_chunk:
        for sig in predictor_chunk.get("sigs", []):
            for fn in sig.get("tables", {}).values():
                _check_blob(report, root, fn, None)
    # whole-route views live on the root; any log (root or shard) can
    # hold the record that staled one
    view_wals = {}
    if os.path.exists(wal_path):
        view_wals["root"] = wal_path
    for k in range(n_shards):
        sub_wal = os.path.join(root, f"shard_{k:02d}", WAL_FILENAME)
        if os.path.exists(sub_wal):
            view_wals[f"shard_{k:02d}"] = sub_wal
    _check_views(
        report,
        root,
        meta.get("views"),
        {int(lid) for _, _, lid, _ in edges},
        set(arrays),
        [(int(lid), src, dst) for src, dst, lid, _ in edges],
        view_wals,
    )
    referenced = manifest_referenced_files((), predictor_chunk, meta.get("views"))
    for fn in sorted(os.listdir(root)):
        if not os.path.isfile(os.path.join(root, fn)):
            continue
        if fn in referenced or not is_catalog_blob(fn):
            continue
        report.add(
            "warn",
            "orphan-blob",
            os.path.relpath(os.path.join(root, fn), report.root),
            "catalog-owned blob not referenced by the root manifest",
        )
    _check_lease(report, root)
    _check_writer_slots(report, root)


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------


def fsck_store(root: str) -> Report:
    """Verify the store rooted at ``root``; never mutates anything."""
    report = Report(root)
    manifest_path = os.path.join(root, "catalog.json")
    meta = None
    if os.path.exists(manifest_path):
        try:
            with open(manifest_path, "rb") as f:
                meta = json.loads(f.read().decode("utf-8"))
        except (ValueError, UnicodeDecodeError):
            meta = None  # _check_store_dir re-reports the parse failure
    if meta is not None and meta.get("sharded"):
        _check_sharded_root(report, root, meta)
    else:
        _check_store_dir(report, root)
    return report


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tools.fsck",
        description="deep on-disk verifier for DSLog stores (read-only)",
    )
    ap.add_argument("root", help="store root directory")
    ap.add_argument("--json", action="store_true", help="machine-readable output")
    args = ap.parse_args(argv)
    if not os.path.isdir(args.root):
        print(f"fsck: {args.root!r} is not a directory", file=sys.stderr)
        return 2
    if not (
        os.path.exists(os.path.join(args.root, "catalog.json"))
        or os.path.exists(os.path.join(args.root, WAL_FILENAME))
    ):
        print(f"fsck: {args.root!r} holds no manifest or WAL", file=sys.stderr)
        return 2
    report = fsck_store(args.root)
    if args.json:
        print(json.dumps(report.to_json(), indent=2))
    else:
        for f in report.findings:
            print(f)
        state = "clean" if report.ok else "CORRUPT"
        print(
            f"fsck: {state}: {report.checked['entries']} entries, "
            f"{report.checked['blobs']} blobs, "
            f"{report.checked['views']} views, "
            f"{report.checked['wal_records']} wal records, "
            f"{report.checked['shards']} shards checked; "
            f"{len(report.errors)} error(s), {len(report.warnings)} warning(s)"
        )
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
