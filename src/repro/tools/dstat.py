"""Telemetry inspector for DSLog stores (``python -m repro.tools.dstat``).

Reads the write-only ``telemetry.json`` sidecar a store refreshes on every
checkpoint (see :func:`repro.obs.export.telemetry_snapshot`) and renders it
without importing or opening the store itself — safe to point at a
directory a live writer owns.

Subcommands::

    python -m repro.tools.dstat dump  ROOT [--json | --prometheus]
    python -m repro.tools.dstat watch ROOT [--interval 2.0] [--count N]
    python -m repro.tools.dstat diff  A B

* ``dump`` — human-readable counters / gauges / histogram percentiles; or
  the validated snapshot verbatim (``--json``); or Prometheus text
  exposition (``--prometheus``).
* ``watch`` — re-read the sidecar every ``--interval`` seconds and print
  the counters that changed since the previous read (top-style delta
  view).  ``--count`` bounds the number of reads (0 = forever).
* ``diff`` — counter and histogram-count deltas between two snapshots
  (older first); each operand is a ``telemetry.json`` path or a store
  root containing one.

Exit status: 0 on success, 2 on unreadable/invalid input.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.obs.export import render_prometheus, validate_telemetry

__all__ = ["load_snapshot", "format_snapshot", "diff_snapshots", "main"]


def _snapshot_path(target: str) -> str:
    """Resolve a CLI operand to a telemetry.json path."""
    if os.path.isdir(target):
        return os.path.join(target, "telemetry.json")
    return target


def load_snapshot(target: str) -> dict:
    """Load and schema-validate a snapshot from a file or store root."""
    path = _snapshot_path(target)
    with open(path, "rb") as f:
        snap = json.loads(f.read().decode("utf-8"))
    validate_telemetry(snap)
    return snap


def _counter_map(snap: dict) -> dict[str, int]:
    """Counters flattened to ``name{k=v,...}`` -> value."""
    out: dict[str, int] = {}
    for row in snap.get("counters", []):
        labels = row.get("labels") or {}
        key = row["name"]
        if labels:
            inner = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
            key = f"{key}{{{inner}}}"
        out[key] = out.get(key, 0) + int(row["value"])
    return out


def _histogram_rows(snap: dict) -> list[tuple[str, dict]]:
    rows = []
    for row in snap.get("histograms", []):
        labels = row.get("labels") or {}
        key = row["name"]
        if labels:
            inner = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
            key = f"{key}{{{inner}}}"
        rows.append((key, row))
    return rows


def format_snapshot(snap: dict) -> str:
    """Human-readable dump: counters, gauges, histogram percentiles."""
    lines = [
        f"registry: {snap.get('registry', '?')}"
        f"  store: {snap.get('store', '?')}  root: {snap.get('root', '?')}"
    ]
    counters = _counter_map(snap)
    if counters:
        lines.append("counters:")
        width = max(len(k) for k in counters)
        for key in sorted(counters):
            lines.append(f"  {key:<{width}}  {counters[key]}")
    gauges = snap.get("gauges", [])
    if gauges:
        lines.append("gauges:")
        for row in sorted(gauges, key=lambda r: (r["name"], str(r["labels"]))):
            labels = row.get("labels") or {}
            inner = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
            name = f"{row['name']}{{{inner}}}" if inner else row["name"]
            lines.append(f"  {name}  {row['value']:g}")
    hists = _histogram_rows(snap)
    if hists:
        lines.append("histograms:")
        for key, row in sorted(hists):
            lines.append(
                f"  {key}  count={row['count']} sum={row['sum']:.6g} "
                f"min={row['min']:.3g} p50={row['p50']:.3g} "
                f"p90={row['p90']:.3g} p99={row['p99']:.3g} "
                f"max={row['max']:.3g}"
            )
    return "\n".join(lines)


def diff_snapshots(old: dict, new: dict) -> dict:
    """Counter and histogram-count deltas between two snapshots.

    Keys present on either side participate; a counter that only exists in
    ``new`` diffs against zero.  Unchanged series are omitted.
    """
    oc, nc = _counter_map(old), _counter_map(new)
    counters = {
        key: nc.get(key, 0) - oc.get(key, 0)
        for key in sorted(set(oc) | set(nc))
        if nc.get(key, 0) != oc.get(key, 0)
    }
    oh = {k: r["count"] for k, r in _histogram_rows(old)}
    nh = {k: r["count"] for k, r in _histogram_rows(new)}
    histograms = {
        key: nh.get(key, 0) - oh.get(key, 0)
        for key in sorted(set(oh) | set(nh))
        if nh.get(key, 0) != oh.get(key, 0)
    }
    return {"counters": counters, "histograms": histograms}


def _cmd_dump(args: argparse.Namespace) -> int:
    snap = load_snapshot(args.target)
    if args.json:
        print(json.dumps(snap, indent=2))
    elif args.prometheus:
        print(render_prometheus(snap), end="")
    else:
        print(format_snapshot(snap))
    return 0


def _cmd_watch(args: argparse.Namespace) -> int:
    prev: dict | None = None
    reads = 0
    while True:
        try:
            snap = load_snapshot(args.target)
        except (OSError, ValueError) as exc:
            print(f"dstat: {exc}", file=sys.stderr)
            snap = None
        if snap is not None:
            if prev is None:
                print(format_snapshot(snap))
            else:
                delta = diff_snapshots(prev, snap)
                changed = {**delta["counters"], **delta["histograms"]}
                stamp = time.strftime("%H:%M:%S")
                if changed:
                    body = "  ".join(
                        f"{k}{v:+d}" for k, v in sorted(changed.items())
                    )
                    print(f"[{stamp}] {body}")
                else:
                    print(f"[{stamp}] (no change)")
            prev = snap
        reads += 1
        if args.count and reads >= args.count:
            return 0
        time.sleep(args.interval)


def _cmd_diff(args: argparse.Namespace) -> int:
    old = load_snapshot(args.old)
    new = load_snapshot(args.new)
    delta = diff_snapshots(old, new)
    if args.json:
        print(json.dumps(delta, indent=2))
        return 0
    if not delta["counters"] and not delta["histograms"]:
        print("no change")
        return 0
    for section in ("counters", "histograms"):
        if delta[section]:
            print(f"{section}:")
            for key, val in delta[section].items():
                print(f"  {key}  {val:+d}")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tools.dstat",
        description="inspect a DSLog store's telemetry.json sidecar",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    dump = sub.add_parser("dump", help="print one snapshot")
    dump.add_argument("target", help="store root or telemetry.json path")
    fmt = dump.add_mutually_exclusive_group()
    fmt.add_argument("--json", action="store_true", help="raw validated JSON")
    fmt.add_argument(
        "--prometheus", action="store_true", help="Prometheus text exposition"
    )
    dump.set_defaults(fn=_cmd_dump)

    watch = sub.add_parser("watch", help="poll the sidecar, print deltas")
    watch.add_argument("target", help="store root or telemetry.json path")
    watch.add_argument("--interval", type=float, default=2.0)
    watch.add_argument(
        "--count", type=int, default=0, help="stop after N reads (0 = forever)"
    )
    watch.set_defaults(fn=_cmd_watch)

    diff = sub.add_parser("diff", help="delta between two snapshots")
    diff.add_argument("old", help="older snapshot (root or file)")
    diff.add_argument("new", help="newer snapshot (root or file)")
    diff.add_argument("--json", action="store_true")
    diff.set_defaults(fn=_cmd_diff)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except FileNotFoundError as exc:
        print(f"dstat: {exc}", file=sys.stderr)
        return 2
    except ValueError as exc:
        print(f"dstat: invalid telemetry: {exc}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
