"""``dsflow`` — interprocedural lock/effect analysis (layer 1.5).

Usage::

    python -m repro.tools.dsflow src/                 # exit 1 on findings
    python -m repro.tools.dsflow src/ --json
    python -m repro.tools.dsflow src/ --baseline tools/dsflow_baseline.json
    python -m repro.tools.dsflow src/ --check-dynamic /tmp/lockgraph.json

Where ``dslint`` reasons one statement at a time and the
``DSLOG_RACE_DETECT=1`` detector only sees interleavings the tests happen
to execute, ``dsflow`` builds a module/class-aware call graph over the
analyzed tree, computes a per-function **effect summary** — locks acquired
(resolved through :mod:`repro.tools.lockorder`), blocking I/O
(``fsync``/``flock``/``rename``/``sleep``/socket ops), WAL appends and
truncations, metrics-registry mutations, escaping exceptions — and
propagates the summaries to a fixpoint through call chains, callback
parameters (``manifest_chunk(write_blob)``), thread targets
(``threading.Thread(target=...)``, ``pool.submit(...)``) and
method-object aliases (``_wal_emit = DSLog._wal_emit``).  Rule classes:

``lock-order``
    A call path acquires a lock ranked at or below one already held —
    the transitive generalisation of dslint's syntactic rule.
``lock-fsync``
    Blocking I/O reachable while holding any core lock.  The group-commit
    barrier ``commit._flush_mutex`` is exempt by design (it exists to be
    held across the WAL flush); every other deliberate site carries a
    justified pragma.
``wal-lease``
    A public ``core/`` entry point reaches a WAL append/truncate with no
    lease check anywhere on the path.
``lock-cycle``
    A cycle in the static held→acquired lock graph (a latent deadlock
    across thread entry points even when every individual edge is legal).
``registry-lock``
    A ``MetricsRegistry`` instrument-table mutation outside
    ``metrics._lock``.

Any finding can be suppressed on its line (or the line above) with
``# dsflow: ignore[rule]``; a pragma on a blocking op / call site also
stops that fact from propagating to callers, so one pragma at a deliberate
site silences the whole cone above it.  ``--baseline FILE`` fails only on
findings not recorded in the baseline; ``--write-baseline`` records the
current findings.  ``--check-dynamic FILE`` asserts every lock edge the
dynamic detector exported (:func:`repro.tools.racecheck.export_edges`) is
present in the static graph — a dynamic-only edge means the call-graph
builder has a blind spot.
"""

from __future__ import annotations

import argparse
import ast
import json
import os
import re
import sys
import time
from dataclasses import dataclass, field

from . import astcache
from .dslint import _in_dir, _scope_key, iter_py_files
from .findings import finding_dict
from .lockorder import LOCK_ORDER, STATIC_LOCKS

_PRAGMA = re.compile(r"#\s*dsflow:\s*ignore(?:\[(?P<rules>[\w\-, ]+)\])?")
_LOCKISH = re.compile(r"(?:lock|mutex)$", re.IGNORECASE)

RULE_NAMES = (
    "lock-order",
    "lock-fsync",
    "wal-lease",
    "lock-cycle",
    "registry-lock",
)

# dotted call → blocking-I/O kind (the classes of op that serialize a hot
# lock behind disk/kernel latency)
_BLOCKING_CALLS = {
    "os.fsync": "fsync",
    "os.fdatasync": "fsync",
    "fcntl.flock": "flock",
    "fcntl.lockf": "flock",
    "os.rename": "rename",
    "os.replace": "rename",
    "time.sleep": "sleep",
    # network ops that actually block (local lookups like gethostname are
    # trivial syscalls and deliberately absent)
    "socket.create_connection": "socket",
    "socket.getaddrinfo": "socket",
    "socket.gethostbyname": "socket",
}

# method names too generic for receiver-less fallback resolution (every
# list has .append; resolving it to WriteAheadLog.append would poison the
# whole graph)
_GENERIC_METHODS = frozenset(
    {
        "append", "add", "pop", "get", "update", "clear", "remove",
        "extend", "insert", "discard", "setdefault", "items", "keys",
        "values", "copy", "close", "flush", "read", "write", "open",
        "save", "load", "reset", "run", "start", "join", "submit",
        "put", "send", "acquire", "release", "wait", "notify", "index",
        "count", "sort", "split", "strip", "encode", "decode", "format",
        "search", "match", "group", "sub", "findall", "exists", "mkdir",
        "unlink", "name", "stat", "render", "describe", "todo",
    }
)

_LEASE_ATTRS = frozenset(
    {"_lease", "_root_lease", "_presence_lease", "_shard_leases"}
)
_WAL_CLASS = "WriteAheadLog"
_LEASE_CLASS = "WriterLease"
_REGISTRY_CLASS = "MetricsRegistry"
_REGISTRY_ATTRS = frozenset(
    {"_counters", "_gauges", "_histograms", "_collectors"}
)
_DICT_MUTATORS = frozenset(
    {"update", "setdefault", "pop", "popitem", "clear", "append"}
)


@dataclass
class Finding:
    path: str
    line: int
    rule: str
    message: str
    severity: str = "error"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_dict(self) -> dict:
        return finding_dict(
            "dsflow", self.rule, self.severity, self.path, self.line,
            self.message,
        )


@dataclass
class CallSite:
    line: int
    held: tuple
    targets: set
    node: ast.Call
    suppressed: frozenset
    pending_param: str = ""
    skip_self: bool = True


@dataclass
class FuncInfo:
    qual: str
    name: str
    path: str
    scope: str
    stem: str
    lineno: int
    node: ast.AST
    cls: str = ""          # owning (or enclosing, for nested defs) class
    parent: str = ""       # enclosing function qual for nested defs
    is_method: bool = True  # False for nested defs / staticmethods
    is_property: bool = False
    params: list = field(default_factory=list)
    returns: set = field(default_factory=set)
    nested: dict = field(default_factory=dict)   # local def name → qual
    acquires: list = field(default_factory=list)  # (held, lock, line)
    blocking: list = field(default_factory=list)  # (kind, line, held)
    wal_direct: list = field(default_factory=list)  # (kind, line)
    registry_mut: list = field(default_factory=list)  # (line, held)
    raises: set = field(default_factory=set)
    lease_check: bool = False
    intrinsic_wal: str = ""
    calls: list = field(default_factory=list)
    thread_entry: bool = False


@dataclass
class ClassInfo:
    name: str
    stem: str
    qual: str
    scope: str
    bases: list = field(default_factory=list)
    methods: dict = field(default_factory=dict)     # name → func qual
    aliases: dict = field(default_factory=dict)     # name → borrowed qual
    properties: set = field(default_factory=set)
    attr_types: dict = field(default_factory=dict)  # attr → set of types
    # attr → (storing function qual, param name): callbacks kept on the
    # instance (``self._on_load = on_load``), resolved against the
    # functions bound to that param at construction sites
    callback_attrs: dict = field(default_factory=dict)


@dataclass
class ModuleInfo:
    path: str
    scope: str
    stem: str
    tree: ast.Module
    imports_ext: dict = field(default_factory=dict)   # name → dotted module
    module_aliases: dict = field(default_factory=dict)  # name → module stem
    from_names: dict = field(default_factory=dict)    # name → (stem, orig)
    functions: dict = field(default_factory=dict)     # name → qual
    classes: dict = field(default_factory=dict)       # name → ClassInfo
    pragmas: dict = field(default_factory=dict)       # line → set|None


def _pragma_map(source: str) -> dict:
    out: dict = {}
    for lineno, line in enumerate(source.splitlines(), start=1):
        m = _PRAGMA.search(line)
        if m:
            rules = m.group("rules")
            out[lineno] = (
                {r.strip() for r in rules.split(",")} if rules else None
            )
    return out


def _suppressed_rules(pragmas: dict, line: int) -> frozenset:
    """Rules suppressed at ``line`` (its own pragma or the line above's).
    A blanket pragma suppresses every rule."""
    out: set = set()
    for at in (line, line - 1):
        rules = pragmas.get(at, ())
        if rules is None:
            return frozenset(RULE_NAMES)
        out.update(rules)
    return frozenset(out)


def _ann_types(node) -> set:
    """Class names named by an annotation expression (``X``, ``"X"``,
    ``X | None``, ``Optional[X]``, ``list[X]`` → ``list:X``, …)."""
    if node is None:
        return set()
    if isinstance(node, ast.Name):
        return set() if node.id == "None" else {node.id}
    if isinstance(node, ast.Constant):
        if isinstance(node.value, str):
            try:
                return _ann_types(ast.parse(node.value, mode="eval").body)
            except SyntaxError:
                return set()
        return set()
    if isinstance(node, ast.Attribute):
        return {node.attr}
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
        return _ann_types(node.left) | _ann_types(node.right)
    if isinstance(node, ast.Subscript):
        base = node.value
        inner = _ann_types(node.slice)
        if isinstance(node.slice, ast.Tuple):
            inner = set()
            for elt in node.slice.elts:
                inner |= _ann_types(elt)
        if isinstance(base, ast.Name):
            if base.id == "Optional":
                return inner
            if base.id in ("list", "List", "Sequence", "Iterable",
                           "tuple", "Tuple", "set", "Set", "frozenset"):
                return {f"list:{t}" for t in inner if ":" not in t}
            if base.id in ("dict", "Dict", "Mapping", "MutableMapping"):
                # value type only (keys are never receivers here)
                vals = (
                    _ann_types(node.slice.elts[-1])
                    if isinstance(node.slice, ast.Tuple) and node.slice.elts
                    else inner
                )
                return {f"list:{t}" for t in vals if ":" not in t}
        return set()
    return set()


def _elem_types(types: set) -> set:
    return {t.split(":", 1)[1] for t in types if t.startswith("list:")}


class Analysis:
    """The result of one :func:`analyze_paths` run."""

    def __init__(self, lock_order, static_locks, reentrant, hot_locks):
        self.lock_order = lock_order
        self.static_locks = static_locks
        self.reentrant = set(reentrant)
        self.hot_locks = set(hot_locks)
        self.modules: dict = {}
        self.functions: dict = {}
        self.classes_by_name: dict = {}
        self.findings: list = []
        # (held, acquired) → (path, line, chain tuple)
        self.lock_edges: dict = {}
        self.stats: dict = {}

    def rank(self, name: str):
        return self.lock_order.get(name)

    def static_edges(self) -> set:
        return set(self.lock_edges)

    def check_dynamic(self, edges) -> list:
        """Findings for dynamically observed edges missing from the static
        graph.  ``edges`` is an iterable of ``{"held", "acquired",
        "where"}`` records (see :func:`repro.tools.racecheck.export_edges`).
        Only edges between *declared* locks are checked — tests mint
        scratch locks with arbitrary names the static pass can't know."""
        static = self.static_edges()
        out = []
        for rec in edges:
            held, acq = rec.get("held", ""), rec.get("acquired", "")
            if held not in self.lock_order or acq not in self.lock_order:
                continue
            if held == acq or (held, acq) in static:
                continue
            out.append(
                Finding(
                    rec.get("where", "?"),
                    0,
                    "dynamic-uncovered",
                    f"dynamic lock edge {held} -> {acq} (seen at "
                    f"{rec.get('where', '?')}) is missing from the static "
                    "lock graph; the call-graph builder has a blind spot",
                )
            )
        return out

    def to_json(self) -> dict:
        return {
            "tool": "dsflow",
            "findings": [f.to_dict() for f in self.findings],
            "lock_edges": sorted(
                [list(k) for k in self.lock_edges], key=tuple
            ),
            "functions": len(self.functions),
            "stats": dict(self.stats),
        }


class _Engine:
    def __init__(self, analysis: Analysis):
        self.a = analysis
        self.param_bindings: dict = {}   # (func qual, param) → set of quals
        self.t_acq: dict = {}    # qual → {lock: (line, next qual|None)}
        self.t_block: dict = {}  # qual → {kind: (line, next qual|None)}
        self.u_wal: dict = {}    # qual → (line, next qual|None, kind)

    # ------------------------------------------------------------------ #
    # phase 1: index modules, classes, functions
    # ------------------------------------------------------------------ #
    def index_module(self, path: str) -> None:
        parsed = astcache.parse(path)
        scope = _scope_key(path)
        stem = os.path.splitext(os.path.basename(path))[0]
        mod = ModuleInfo(path, scope, stem, parsed.tree)
        mod.pragmas = _pragma_map(parsed.source)
        self.a.modules[stem] = mod
        for node in parsed.tree.body:
            if isinstance(node, ast.Import):
                for alias in node.names:
                    local = alias.asname or alias.name.split(".")[0]
                    mod.imports_ext[local] = alias.name
                    mod.module_aliases[local] = alias.name.split(".")[-1]
            elif isinstance(node, ast.ImportFrom):
                src = node.module or ""
                for alias in node.names:
                    local = alias.asname or alias.name
                    if node.level and not node.module:
                        # from . import wal
                        mod.module_aliases[local] = alias.name
                    else:
                        mod.from_names[local] = (
                            src.split(".")[-1], alias.name
                        )
                        mod.imports_ext[local] = f"{src}.{alias.name}"
        self._index_body(mod, parsed.tree.body, stem, None, None)

    def _index_body(self, mod, body, prefix, cls, parent_fn):
        for node in body:
            if isinstance(node, ast.ClassDef):
                ci = ClassInfo(
                    node.name, mod.stem, f"{prefix}.{node.name}", mod.scope
                )
                for base in node.bases:
                    if isinstance(base, ast.Name):
                        ci.bases.append(base.id)
                    elif isinstance(base, ast.Attribute):
                        ci.bases.append(base.attr)
                mod.classes[node.name] = ci
                self.a.classes_by_name.setdefault(node.name, []).append(ci)
                self._index_class_body(mod, node, ci)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._index_func(mod, node, prefix, cls, parent_fn)

    def _index_class_body(self, mod, node, ci):
        for st in node.body:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fi = self._index_func(mod, st, ci.qual, ci, None)
                ci.methods[st.name] = fi.qual
                if fi.is_property:
                    ci.properties.add(st.name)
            elif isinstance(st, ast.Assign) and len(st.targets) == 1:
                tgt = st.targets[0]
                if isinstance(tgt, ast.Name):
                    ref = st.value
                    if (
                        isinstance(ref, ast.Call)
                        and isinstance(ref.func, ast.Name)
                        and ref.func.id == "staticmethod"
                        and ref.args
                    ):
                        ref = ref.args[0]
                    if isinstance(ref, ast.Attribute) and isinstance(
                        ref.value, ast.Name
                    ):
                        # `_wal_emit = DSLog._wal_emit` — borrowed method
                        ci.aliases[tgt.id] = (ref.value.id, ref.attr)
            elif isinstance(st, ast.AnnAssign) and isinstance(
                st.target, ast.Name
            ):
                ci.attr_types.setdefault(st.target.id, set()).update(
                    _ann_types(st.annotation)
                )

    def _index_func(self, mod, node, prefix, cls, parent_fn) -> FuncInfo:
        qual = f"{prefix}.{node.name}"
        decorators = set()
        for dec in node.decorator_list:
            if isinstance(dec, ast.Name):
                decorators.add(dec.id)
            elif isinstance(dec, ast.Attribute):
                decorators.add(dec.attr)
        args = node.args
        params = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
        fi = FuncInfo(
            qual=qual,
            name=node.name,
            path=mod.path,
            scope=mod.scope,
            stem=mod.stem,
            lineno=node.lineno,
            node=node,
            cls=(cls.name if cls else (parent_fn.cls if parent_fn else "")),
            parent=(parent_fn.qual if parent_fn else ""),
            is_method=(cls is not None and "staticmethod" not in decorators),
            is_property=(
                bool({"property", "cached_property"} & decorators)
            ),
            params=params,
            returns=_ann_types(node.returns),
        )
        self.a.functions[qual] = fi
        if parent_fn is not None:
            parent_fn.nested[node.name] = qual
        elif cls is None:
            mod.functions[node.name] = qual
        if cls is not None and cls.name == _WAL_CLASS:
            if node.name == "append":
                fi.intrinsic_wal = "wal-append"
            elif node.name in ("checkpoint", "repair"):
                fi.intrinsic_wal = "wal-truncate"
        # nested defs (and defs inside methods) become their own functions
        for st in ast.walk(node):
            if (
                isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef))
                and st is not node
                and self._direct_parent_func(node, st)
            ):
                self._index_func(mod, st, qual, None, fi)
        return fi

    @staticmethod
    def _direct_parent_func(parent, child) -> bool:
        """True when ``child`` def's nearest enclosing def is ``parent``."""
        stack = [(parent, iter(ast.iter_child_nodes(parent)))]
        while stack:
            node, it = stack[-1]
            nxt = next(it, None)
            if nxt is None:
                stack.pop()
                continue
            if nxt is child:
                return node is parent
            if isinstance(nxt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                stack.append((nxt, iter(ast.iter_child_nodes(nxt))))
            else:
                stack.append((node, iter(ast.iter_child_nodes(nxt))))
        return False

    # ------------------------------------------------------------------ #
    # phase 2: resolve class aliases + attribute types + relatedness
    # ------------------------------------------------------------------ #
    def link_classes(self) -> None:
        for mod in self.a.modules.values():
            for ci in mod.classes.values():
                resolved = {}
                for name, (src_cls, attr) in ci.aliases.items():
                    owner = self._class_by_name(mod, src_cls)
                    if owner is not None and attr in owner.methods:
                        resolved[name] = owner.methods[attr]
                ci.aliases = resolved
        # borrowed-method relatedness: `self.x()` inside DSLog code may run
        # with a ShardedDSLog receiver when Sharded borrows DSLog methods
        self._borrowers: dict = {}
        for mod in self.a.modules.values():
            for ci in mod.classes.values():
                for target in ci.aliases.values():
                    owner = self.a.functions.get(target)
                    if owner is not None and owner.cls:
                        self._borrowers.setdefault(owner.cls, set()).add(
                            ci.name
                        )
        # self-attribute types from every method body
        for mod in self.a.modules.values():
            for ci in mod.classes.values():
                for qual in ci.methods.values():
                    fi = self.a.functions.get(qual)
                    if fi is None:
                        continue
                    for st in ast.walk(fi.node):
                        tgt = None
                        ann = None
                        value = None
                        if isinstance(st, ast.Assign) and len(st.targets) == 1:
                            tgt, value = st.targets[0], st.value
                        elif isinstance(st, ast.AnnAssign):
                            tgt, ann, value = st.target, st.annotation, st.value
                        if not (
                            isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"
                        ):
                            continue
                        types = ci.attr_types.setdefault(tgt.attr, set())
                        if ann is not None:
                            types.update(_ann_types(ann))
                        if isinstance(value, ast.Call):
                            c = self._call_ctor_class(mod, value)
                            if c:
                                types.add(c)
                        # a parameter stashed on the instance: the attr
                        # inherits the param's annotated types, and later
                        # ``self._attr()`` calls dispatch to whatever
                        # callables construction sites bound to the param
                        if (
                            isinstance(value, ast.Name)
                            and value.id in fi.params
                        ):
                            ci.callback_attrs.setdefault(
                                tgt.attr, (fi.qual, value.id)
                            )
                            fargs = fi.node.args
                            for a in (
                                fargs.posonlyargs + fargs.args
                                + fargs.kwonlyargs
                            ):
                                if (
                                    a.arg == value.id
                                    and a.annotation is not None
                                ):
                                    types.update(_ann_types(a.annotation))

    def _call_ctor_class(self, mod, call) -> str:
        fn = call.func
        name = None
        if isinstance(fn, ast.Name):
            name = fn.id
        elif isinstance(fn, ast.Attribute):
            name = fn.attr
        if name and name in self.a.classes_by_name:
            return name
        return ""

    def _class_by_name(self, mod, name: str):
        if name in mod.classes:
            return mod.classes[name]
        if name in mod.from_names:
            stem, orig = mod.from_names[name]
            src = self.a.modules.get(stem)
            if src is not None and orig in src.classes:
                return src.classes[orig]
        hits = self.a.classes_by_name.get(name, [])
        return hits[0] if len(hits) == 1 else None

    def _subclasses(self, name: str) -> set:
        out = set()
        for cname, infos in self.a.classes_by_name.items():
            for ci in infos:
                if name in ci.bases:
                    out.add(cname)
                    out |= self._subclasses(cname) if cname != name else set()
        return out

    def _resolve_method(self, ci: ClassInfo, m: str, depth: int = 0) -> str:
        if depth > 8 or ci is None:
            return ""
        if m in ci.methods:
            return ci.methods[m]
        if m in ci.aliases:
            return ci.aliases[m]
        mod = self.a.modules.get(ci.stem)
        for base in ci.bases:
            bci = self._class_by_name(mod, base) if mod else None
            if bci is not None and bci is not ci:
                got = self._resolve_method(bci, m, depth + 1)
                if got:
                    return got
        return ""

    # ------------------------------------------------------------------ #
    # phase 3: per-function fact collection
    # ------------------------------------------------------------------ #
    def collect_all(self) -> None:
        for fi in list(self.a.functions.values()):
            _FactCollector(self, fi).run()

    # ------------------------------------------------------------------ #
    # phase 4: callback-parameter binding fixpoint
    # ------------------------------------------------------------------ #
    def bind_params(self) -> None:
        for _ in range(6):
            changed = self._bind_round()
            if not changed:
                break

    def _bind_round(self) -> bool:
        changed = False
        # collect bindings from every resolved call's function-ref args
        for fi in self.a.functions.values():
            for cs in fi.calls:
                for target in list(cs.targets):
                    ti = self.a.functions.get(target)
                    if ti is None:
                        continue
                    params = list(ti.params)
                    if (
                        cs.skip_self
                        and ti.is_method
                        and params
                        and params[0] in ("self", "cls")
                    ):
                        params = params[1:]
                    mod = self.a.modules.get(fi.stem)
                    for i, arg in enumerate(cs.node.args):
                        if i >= len(params):
                            break
                        ref = self._func_ref(fi, mod, arg)
                        if ref and ref not in self.param_bindings.setdefault(
                            (ti.qual, params[i]), set()
                        ):
                            self.param_bindings[(ti.qual, params[i])].add(ref)
                            changed = True
                    for kw in cs.node.keywords:
                        if kw.arg is None or kw.arg not in ti.params:
                            continue
                        ref = self._func_ref(fi, mod, kw.value)
                        if ref and ref not in self.param_bindings.setdefault(
                            (ti.qual, kw.arg), set()
                        ):
                            self.param_bindings[(ti.qual, kw.arg)].add(ref)
                            changed = True
        # resolve pending param-name calls against the bindings
        for fi in self.a.functions.values():
            for cs in fi.calls:
                if not cs.pending_param:
                    continue
                if "::" in cs.pending_param:
                    # attribute-stored callback: bound at the storing
                    # function (usually __init__), not at this caller
                    key = tuple(cs.pending_param.split("::", 1))
                else:
                    key = (fi.qual, cs.pending_param)
                bound = self.param_bindings.get(key, ())
                for ref in bound:
                    if ref not in cs.targets:
                        cs.targets.add(ref)
                        changed = True
        return changed

    def _func_ref(self, fi: FuncInfo, mod, arg) -> str:
        """The function qual an argument expression refers to, if any."""
        if isinstance(arg, ast.Name):
            if arg.id in fi.nested:
                return fi.nested[arg.id]
            parent = self.a.functions.get(fi.parent)
            if parent is not None and arg.id in parent.nested:
                return parent.nested[arg.id]
            if mod is not None and arg.id in mod.functions:
                return mod.functions[arg.id]
        if isinstance(arg, ast.Attribute) and isinstance(arg.value, ast.Name):
            if arg.value.id in ("self", "cls") and fi.cls:
                ci = self._class_by_name(
                    self.a.modules.get(fi.stem), fi.cls
                )
                if ci is not None:
                    return self._resolve_method(ci, arg.attr)
        return ""

    # ------------------------------------------------------------------ #
    # phase 5: effect fixpoint
    # ------------------------------------------------------------------ #
    def propagate(self) -> None:
        for fi in self.a.functions.values():
            acq = {}
            for held, lock, line in fi.acquires:
                acq.setdefault(lock, (line, None))
            self.t_acq[fi.qual] = acq
            blk = {}
            for kind, line, _held in fi.blocking:
                blk.setdefault(kind, (line, None))
            self.t_block[fi.qual] = blk
            if fi.intrinsic_wal:
                self.u_wal[fi.qual] = (fi.lineno, None, fi.intrinsic_wal)
            for kind, line in fi.wal_direct:
                self.u_wal.setdefault(fi.qual, (line, None, kind))
        for _ in range(64):
            changed = False
            for fi in self.a.functions.values():
                acq = self.t_acq[fi.qual]
                blk = self.t_block[fi.qual]
                for cs in fi.calls:
                    for t in cs.targets:
                        if t not in self.t_acq:
                            continue
                        for lock in self.t_acq[t]:
                            if lock not in acq:
                                acq[lock] = (cs.line, t)
                                changed = True
                        if "lock-fsync" not in cs.suppressed:
                            for kind in self.t_block[t]:
                                if kind not in blk:
                                    blk[kind] = (cs.line, t)
                                    changed = True
                        if "wal-lease" not in cs.suppressed:
                            w = self.u_wal.get(t)
                            ti = self.a.functions.get(t)
                            if (
                                w is not None
                                and ti is not None
                                and not ti.lease_check
                                and fi.qual not in self.u_wal
                            ):
                                self.u_wal[fi.qual] = (cs.line, t, w[2])
                                changed = True
            if not changed:
                break

    def _chain(self, start: str, key, table) -> list:
        names = [start]
        cur = start
        for _ in range(25):
            entry = table.get(cur, {}).get(key) if key is not None else (
                table.get(cur)
            )
            if entry is None:
                break
            nxt = entry[1]
            if nxt is None:
                break
            names.append(nxt)
            cur = nxt
        return names

    # ------------------------------------------------------------------ #
    # phase 6: rules
    # ------------------------------------------------------------------ #
    def report(self) -> None:
        findings: list = []
        self._rule_lock_order(findings)
        self._rule_lock_fsync(findings)
        self._rule_wal_lease(findings)
        self._rule_lock_cycle(findings)
        self._rule_registry_lock(findings)
        # line-level pragma filter (same semantics as dslint: own line or
        # the line above)
        out = []
        seen = set()
        for f in findings:
            mod = self._module_for_path(f.path)
            if mod is not None:
                sup = _suppressed_rules(mod.pragmas, f.line)
                if f.rule in sup:
                    continue
            key = (f.path, f.line, f.rule, f.message)
            if key in seen:
                continue
            seen.add(key)
            out.append(f)
        out.sort(key=lambda f: (f.path, f.line, f.rule))
        self.a.findings.extend(out)

    def _module_for_path(self, path: str):
        for mod in self.a.modules.values():
            if mod.path == path:
                return mod
        return None

    def _add_edge(self, held: str, acquired: str, path, line, chain) -> None:
        if held == acquired and held in self.a.reentrant:
            return
        self.a.lock_edges.setdefault(
            (held, acquired), (path, line, tuple(chain))
        )

    def _rule_lock_order(self, findings: list) -> None:
        a = self.a
        for fi in a.functions.values():
            for held, lock, line in fi.acquires:
                for h in held:
                    self._add_edge(h, lock, fi.path, line, (fi.qual,))
                    self._rank_check(findings, fi, h, lock, line, (fi.qual,))
            for cs in fi.calls:
                if not cs.held:
                    continue
                for t in cs.targets:
                    for lock in self.t_acq.get(t, ()):
                        chain = [fi.qual] + self._chain(t, lock, self.t_acq)
                        for h in cs.held:
                            self._add_edge(h, lock, fi.path, cs.line, chain)
                            self._rank_check(
                                findings, fi, h, lock, cs.line, chain
                            )

    def _rank_check(self, findings, fi, held, lock, line, chain) -> None:
        a = self.a
        rh, rl = a.rank(held), a.rank(lock)
        if rh is None or rl is None:
            return
        if held == lock and held in a.reentrant:
            return
        if rl <= rh:
            via = " -> ".join(chain)
            findings.append(
                Finding(
                    fi.path,
                    line,
                    "lock-order",
                    f"acquires {lock} (rank {rl}) while holding {held} "
                    f"(rank {rh}) via {via}",
                )
            )

    def _rule_lock_fsync(self, findings: list) -> None:
        hot = self.a.hot_locks
        for fi in self.a.functions.values():
            for kind, line, held in fi.blocking:
                for h in held:
                    if h in hot:
                        findings.append(
                            Finding(
                                fi.path,
                                line,
                                "lock-fsync",
                                f"blocking {kind} while holding {h} in "
                                f"{fi.qual}",
                            )
                        )
            for cs in fi.calls:
                if "lock-fsync" in cs.suppressed:
                    continue
                hl = [h for h in cs.held if h in hot]
                if not hl:
                    continue
                for t in cs.targets:
                    for kind in self.t_block.get(t, ()):
                        chain = [fi.qual] + self._chain(
                            t, kind, self.t_block
                        )
                        via = " -> ".join(chain)
                        for h in hl:
                            findings.append(
                                Finding(
                                    fi.path,
                                    cs.line,
                                    "lock-fsync",
                                    f"blocking {kind} reachable while "
                                    f"holding {h} via {via}",
                                )
                            )

    def _rule_wal_lease(self, findings: list) -> None:
        for fi in self.a.functions.values():
            if (
                fi.name.startswith("_")
                or fi.parent
                or not _in_dir(fi.scope, "core")
                or fi.cls == _WAL_CLASS
                or fi.lease_check
            ):
                continue
            w = self.u_wal.get(fi.qual)
            if w is None:
                continue
            chain = [fi.qual] + self._chain(fi.qual, None, self.u_wal)[1:]
            via = " -> ".join(chain)
            findings.append(
                Finding(
                    fi.path,
                    fi.lineno,
                    "wal-lease",
                    f"public entry {fi.qual} reaches a {w[2]} with no "
                    f"lease check on the path ({via})",
                )
            )

    def _rule_lock_cycle(self, findings: list) -> None:
        adj: dict = {}
        for (h, acq) in self.a.lock_edges:
            if h != acq:
                adj.setdefault(h, set()).add(acq)
        seen_cycles = set()
        WHITE, GREY, BLACK = 0, 1, 2
        colour: dict = {}

        def visit(node, path):
            colour[node] = GREY
            path.append(node)
            for nxt in sorted(adj.get(node, ())):
                c = colour.get(nxt, WHITE)
                if c == GREY:
                    loop = path[path.index(nxt):]
                    lo = loop.index(min(loop))
                    canon = tuple(loop[lo:] + loop[:lo])
                    if canon in seen_cycles:
                        continue
                    seen_cycles.add(canon)
                    src = self.a.lock_edges.get(
                        (node, nxt)
                    ) or ("?", 0, ())
                    findings.append(
                        Finding(
                            src[0],
                            src[1],
                            "lock-cycle",
                            "static lock-graph cycle: "
                            + " -> ".join(canon + (canon[0],)),
                        )
                    )
                elif c == WHITE and nxt in adj:
                    visit(nxt, path)
                else:
                    colour.setdefault(nxt, BLACK)
            path.pop()
            colour[node] = BLACK

        for node in sorted(adj):
            if colour.get(node, WHITE) == WHITE:
                visit(node, [])

    def _rule_registry_lock(self, findings: list) -> None:
        for fi in self.a.functions.values():
            lock = self.a.static_locks.get((fi.stem, "_lock"), "metrics._lock")
            for line, held in fi.registry_mut:
                if lock not in held:
                    findings.append(
                        Finding(
                            fi.path,
                            line,
                            "registry-lock",
                            f"registry mutation in {fi.qual} outside "
                            f"{lock}",
                        )
                    )


class _FactCollector:
    """Collects one function's direct facts + call sites, tracking the
    held-lock set through ``with`` regions."""

    def __init__(self, eng: _Engine, fi: FuncInfo):
        self.eng = eng
        self.a = eng.a
        self.fi = fi
        self.mod = eng.a.modules.get(fi.stem)
        self.env: dict = {}

    def run(self) -> None:
        self._build_env()
        body = getattr(self.fi.node, "body", [])
        self._walk_body(body, ())

    # -- local type environment -------------------------------------- #
    def _build_env(self) -> None:
        fi = self.fi
        env = self.env
        if fi.cls:
            env["self"] = {fi.cls}
            env["cls"] = {fi.cls}
        args = fi.node.args
        for a in args.posonlyargs + args.args + args.kwonlyargs:
            if a.annotation is not None:
                env[a.arg] = _ann_types(a.annotation)
        own = self._own_statements()
        for _ in range(3):
            for st in own:
                if isinstance(st, ast.Assign) and len(st.targets) == 1:
                    tgt = st.targets[0]
                    if isinstance(tgt, ast.Name):
                        t = self._infer(st.value)
                        if t:
                            env.setdefault(tgt.id, set()).update(t)
                elif isinstance(st, ast.AnnAssign) and isinstance(
                    st.target, ast.Name
                ):
                    env.setdefault(st.target.id, set()).update(
                        _ann_types(st.annotation)
                    )
                elif isinstance(st, (ast.For, ast.AsyncFor)) and isinstance(
                    st.target, ast.Name
                ):
                    elems = _elem_types(self._infer(st.iter))
                    if elems:
                        env.setdefault(st.target.id, set()).update(elems)

    def _own_statements(self) -> list:
        """Statements belonging to this function (not nested defs)."""
        out = []
        stack = list(getattr(self.fi.node, "body", []))
        while stack:
            st = stack.pop()
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue
            out.append(st)
            for child in ast.iter_child_nodes(st):
                if isinstance(child, ast.stmt):
                    stack.append(child)
                elif isinstance(child, (ast.excepthandler, ast.match_case)):
                    stack.extend(
                        c for c in ast.iter_child_nodes(child)
                        if isinstance(c, ast.stmt)
                    )
        return out

    def _infer(self, expr, depth: int = 0) -> set:
        if depth > 6 or expr is None:
            return set()
        if isinstance(expr, ast.Name):
            return set(self.env.get(expr.id, ()))
        if isinstance(expr, ast.Call):
            fn = expr.func
            if isinstance(fn, ast.Name):
                if fn.id == "cls":
                    return set(self.env.get("cls", ()))
                ci = self._local_class(fn.id)
                if ci is not None:
                    return {ci.name}
                target = self._name_func(fn.id)
                ti = self.a.functions.get(target) if target else None
                if ti is not None:
                    return {t for t in ti.returns}
            elif isinstance(fn, ast.Attribute):
                out = set()
                recv = self._infer(fn.value, depth + 1)
                if isinstance(fn.value, ast.Name):
                    owner = self._local_class(fn.value.id)
                    if owner is not None:
                        recv = recv | {owner.name}
                for t in recv:
                    if ":" in t:
                        continue
                    ci = self._local_class(t)
                    if ci is None:
                        continue
                    q = self.eng._resolve_method(ci, fn.attr)
                    ti = self.a.functions.get(q) if q else None
                    if ti is not None:
                        out |= ti.returns
                if out:
                    return out
                ci = self._local_class(fn.attr)
                if ci is not None:
                    return {ci.name}
            return set()
        if isinstance(expr, ast.Attribute):
            out = set()
            for t in self._infer(expr.value, depth + 1):
                ci = self._local_class(t)
                if ci is not None:
                    out |= ci.attr_types.get(expr.attr, set())
            return out
        if isinstance(expr, ast.Subscript):
            return _elem_types(self._infer(expr.value, depth + 1))
        if isinstance(expr, (ast.ListComp, ast.GeneratorExp, ast.SetComp)):
            saved = {}
            for gen in expr.generators:
                if isinstance(gen.target, ast.Name):
                    elems = _elem_types(self._infer(gen.iter, depth + 1))
                    saved[gen.target.id] = self.env.get(gen.target.id)
                    if elems:
                        self.env[gen.target.id] = elems
            elt = self._infer(expr.elt, depth + 1)
            for k, v in saved.items():
                if v is None:
                    self.env.pop(k, None)
                else:
                    self.env[k] = v
            return {f"list:{t}" for t in elt if ":" not in t}
        if isinstance(expr, ast.IfExp):
            return self._infer(expr.body, depth + 1) | self._infer(
                expr.orelse, depth + 1
            )
        if isinstance(expr, ast.BoolOp):
            out = set()
            for v in expr.values:
                out |= self._infer(v, depth + 1)
            return out
        if isinstance(expr, (ast.Await, ast.Starred)):
            return self._infer(expr.value, depth + 1)
        if isinstance(expr, (ast.List, ast.Tuple)):
            out = set()
            for elt in expr.elts:
                out |= self._infer(elt, depth + 1)
            return {f"list:{t}" for t in out if ":" not in t}
        return set()

    def _local_class(self, name: str):
        if self.mod is None:
            hits = self.a.classes_by_name.get(name, [])
            return hits[0] if len(hits) == 1 else None
        return self.eng._class_by_name(self.mod, name)

    def _name_func(self, name: str) -> str:
        fi = self.fi
        if name in fi.nested:
            return fi.nested[name]
        parent = self.a.functions.get(fi.parent)
        if parent is not None and name in parent.nested:
            return parent.nested[name]
        if self.mod is not None:
            if name in self.mod.functions:
                return self.mod.functions[name]
            if name in self.mod.from_names:
                stem, orig = self.mod.from_names[name]
                src = self.a.modules.get(stem)
                if src is not None and orig in src.functions:
                    return src.functions[orig]
        return ""

    # -- statement walk with held-lock tracking ------------------------ #
    def _walk_body(self, stmts, held) -> None:
        for st in stmts:
            self._walk_stmt(st, held)

    def _walk_stmt(self, st, held) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            return
        if isinstance(st, (ast.With, ast.AsyncWith)):
            inner = held
            for item in st.items:
                self._visit_expr(item.context_expr, inner)
                lock = self._lock_of(item.context_expr)
                if lock is not None:
                    self.fi.acquires.append((inner, lock, st.lineno))
                    inner = inner + (lock,)
            self._walk_body(st.body, inner)
            return
        if isinstance(st, ast.Raise):
            exc = st.exc
            if isinstance(exc, ast.Call):
                exc = exc.func
            if isinstance(exc, ast.Name):
                self.fi.raises.add(exc.id)
            elif isinstance(exc, ast.Attribute):
                self.fi.raises.add(exc.attr)
        if isinstance(st, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            self._check_registry_assign(st, held)
        for _name, value in ast.iter_fields(st):
            vals = value if isinstance(value, list) else [value]
            for v in vals:
                if isinstance(v, ast.stmt):
                    self._walk_stmt(v, held)
                elif isinstance(v, ast.expr):
                    self._visit_expr(v, held)
                elif isinstance(v, ast.excepthandler):
                    if v.type is not None:
                        self._visit_expr(v.type, held)
                    self._walk_body(v.body, held)
                elif isinstance(v, ast.match_case):
                    if v.guard is not None:
                        self._visit_expr(v.guard, held)
                    self._walk_body(v.body, held)

    def _visit_expr(self, expr, held) -> None:
        if expr is None:
            return
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                self._handle_call(node, held)
            elif isinstance(node, ast.Attribute):
                if node.attr in _LEASE_ATTRS:
                    self.fi.lease_check = True
                if isinstance(node.ctx, ast.Load):
                    self._maybe_property_edge(node, held)

    def _suppressed(self, line: int) -> frozenset:
        if self.mod is None:
            return frozenset()
        return _suppressed_rules(self.mod.pragmas, line)

    def _lock_of(self, expr):
        attr = None
        if isinstance(expr, ast.Attribute) and _LOCKISH.search(expr.attr):
            attr = expr.attr
        elif isinstance(expr, ast.Name) and _LOCKISH.search(expr.id):
            attr = expr.id
        if attr is None:
            return None
        return self.a.static_locks.get(
            (self.fi.stem, attr), f"{self.fi.stem}.{attr}"
        )

    def _dotted(self, func) -> str:
        if isinstance(func, ast.Attribute) and isinstance(
            func.value, ast.Name
        ):
            base = func.value.id
            if self.mod is not None and base in self.mod.imports_ext:
                return f"{self.mod.imports_ext[base]}.{func.attr}"
            return f"{base}.{func.attr}"
        if isinstance(func, ast.Name):
            if self.mod is not None and func.id in self.mod.imports_ext:
                return self.mod.imports_ext[func.id]
            return func.id
        return ""

    def _handle_call(self, node: ast.Call, held) -> None:
        sup = self._suppressed(node.lineno)
        dotted = self._dotted(node.func)
        kind = _BLOCKING_CALLS.get(dotted)
        if kind is not None and "lock-fsync" not in sup:
            self.fi.blocking.append((kind, node.lineno, held))
        # reentrant-lock mints teach the analysis which names are RLocks
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "new_rlock"
            or isinstance(node.func, ast.Name)
            and node.func.id == "new_rlock"
        ):
            if node.args and isinstance(node.args[0], ast.Constant):
                if isinstance(node.args[0].value, str):
                    self.a.reentrant.add(node.args[0].value)
        targets, pending, skip_self = self._resolve_call(node)
        # thread / executor entry points: the callable argument is an edge
        extra = self._spawn_target(node)
        if extra:
            targets |= extra
            for q in extra:
                ti = self.a.functions.get(q)
                if ti is not None:
                    ti.thread_entry = True
        if targets or pending:
            self.fi.calls.append(
                CallSite(
                    node.lineno, held, targets, node, sup, pending, skip_self
                )
            )
        self._check_lease_call(targets)
        self._check_wal_recover(node, targets, sup)
        self._check_registry_call(node, held)

    def _spawn_target(self, node: ast.Call) -> set:
        fn = node.func
        name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else ""
        )
        out = set()
        if name == "Thread":
            for kw in node.keywords:
                if kw.arg == "target":
                    ref = self.eng._func_ref(self.fi, self.mod, kw.value)
                    if ref:
                        out.add(ref)
        elif name == "submit" and node.args:
            ref = self.eng._func_ref(self.fi, self.mod, node.args[0])
            if ref:
                out.add(ref)
        return out

    def _resolve_call(self, node: ast.Call):
        fn = node.func
        targets: set = set()
        pending = ""
        skip_self = True
        if isinstance(fn, ast.Name):
            skip_self = False
            q = self._name_func(fn.id)
            if q:
                targets.add(q)
            else:
                ci = self._local_class(fn.id)
                if ci is not None:
                    init = self.eng._resolve_method(ci, "__init__")
                    if init:
                        targets.add(init)
                        # ctor args align with __init__ params[1:]
                        skip_self = True
                elif fn.id in self.fi.params:
                    pending = fn.id
        elif isinstance(fn, ast.Attribute):
            m = fn.attr
            # module-alias call: wal.some_func(...)
            if isinstance(fn.value, ast.Name) and self.mod is not None:
                alias = self.mod.module_aliases.get(fn.value.id)
                src = self.a.modules.get(alias) if alias else None
                if src is not None:
                    if m in src.functions:
                        targets.add(src.functions[m])
                        return targets, pending, False
                    if m in src.classes:
                        init = self.eng._resolve_method(
                            src.classes[m], "__init__"
                        )
                        if init:
                            targets.add(init)
                    # ctor args align with __init__ params[1:]
                    return targets, pending, True
            recv = self._infer(fn.value)
            classes = {t for t in recv if ":" not in t}
            # ClassName.method(...) — unbound call through the class object
            if isinstance(fn.value, ast.Name):
                ci = self._local_class(fn.value.id)
                if ci is not None:
                    classes.add(ci.name)
                    skip_self = False
            if "self" == getattr(fn.value, "id", None) or "cls" == getattr(
                fn.value, "id", None
            ):
                # borrowed-method receivers: DSLog code may run with a
                # ShardedDSLog self when Sharded aliases DSLog methods
                classes |= self.eng._borrowers.get(self.fi.cls, set())
            resolved = set()
            for cname in set(classes):
                for sub in {cname} | self.eng._subclasses(cname):
                    ci = self._local_class(sub)
                    if ci is not None:
                        q = self.eng._resolve_method(ci, m)
                        if q:
                            resolved.add(q)
            if not resolved and not classes:
                resolved |= self._fallback_by_name(m)
            if (
                not resolved
                and isinstance(fn.value, ast.Name)
                and fn.value.id == "self"
                and self.fi.cls
            ):
                # instance-attribute callback: self._loader() dispatches
                # to the functions construction sites bound to the param
                owner = self._local_class(self.fi.cls)
                cb = (
                    owner.callback_attrs.get(m) if owner is not None
                    else None
                )
                if cb is not None:
                    pending = f"{cb[0]}::{cb[1]}"
            targets |= resolved
        return targets, pending, skip_self

    def _fallback_by_name(self, m: str) -> set:
        if m in _GENERIC_METHODS or m.startswith("__"):
            return set()
        owners = []
        for infos in self.a.classes_by_name.values():
            for ci in infos:
                if m in ci.methods:
                    owners.append(ci.methods[m])
        return set(owners) if len(owners) == 1 else set()

    def _maybe_property_edge(self, node: ast.Attribute, held) -> None:
        recv = self._infer(node.value)
        for t in recv:
            if ":" in t:
                continue
            ci = self._local_class(t)
            if ci is not None and node.attr in ci.properties:
                self.fi.calls.append(
                    CallSite(
                        node.lineno,
                        held,
                        {ci.methods[node.attr]},
                        ast.Call(
                            func=node, args=[], keywords=[],
                        ),
                        self._suppressed(node.lineno),
                    )
                )

    def _check_lease_call(self, targets: set) -> None:
        for q in targets:
            ti = self.a.functions.get(q)
            if ti is None:
                continue
            if ti.name == "_ensure_shard_lease" or (
                ti.cls == _LEASE_CLASS and ti.name in ("acquire", "held")
            ):
                self.fi.lease_check = True

    def _check_wal_recover(self, node: ast.Call, targets, sup) -> None:
        if "wal-lease" in sup:
            return
        for q in targets:
            ti = self.a.functions.get(q)
            if ti is None or ti.cls != _WAL_CLASS or ti.name != "recover":
                continue
            for kw in node.keywords:
                if (
                    kw.arg == "truncate"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True
                ):
                    self.fi.wal_direct.append(("wal-truncate", node.lineno))

    def _is_registry_attr(self, expr) -> bool:
        # __init__ mutates freely: the registry is not yet published to
        # any other thread while its constructor runs
        return (
            self.fi.cls == _REGISTRY_CLASS
            and self.fi.name != "__init__"
            and isinstance(expr, ast.Attribute)
            and isinstance(expr.value, ast.Name)
            and expr.value.id == "self"
            and expr.attr in _REGISTRY_ATTRS
        )

    def _check_registry_assign(self, st, held) -> None:
        targets = (
            st.targets if isinstance(st, ast.Assign) else [st.target]
        )
        for tgt in targets:
            probe = tgt.value if isinstance(tgt, ast.Subscript) else tgt
            if self._is_registry_attr(probe):
                if "registry-lock" not in self._suppressed(st.lineno):
                    self.fi.registry_mut.append((st.lineno, held))

    def _check_registry_call(self, node: ast.Call, held) -> None:
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr in _DICT_MUTATORS
            and self._is_registry_attr(node.func.value)
        ):
            if "registry-lock" not in self._suppressed(node.lineno):
                self.fi.registry_mut.append((node.lineno, held))


# -------------------------------------------------------------------------- #
# public API
# -------------------------------------------------------------------------- #


def analyze_paths(
    paths,
    lock_order=None,
    static_locks=None,
    reentrant=None,
    hot_locks=None,
) -> Analysis:
    """Run the full analysis over ``paths`` (files or directories).

    The lock tables default to :mod:`repro.tools.lockorder`; tests inject
    fixture tables.  ``hot_locks`` defaults to every ranked lock except
    ``commit._flush_mutex`` — the group-commit barrier exists precisely to
    be held across the WAL flush, so blocking I/O under it is its job, not
    a finding."""
    lo = dict(LOCK_ORDER if lock_order is None else lock_order)
    sl = dict(STATIC_LOCKS if static_locks is None else static_locks)
    hot = (
        set(lo) - {"commit._flush_mutex"} if hot_locks is None else
        set(hot_locks)
    )
    analysis = Analysis(lo, sl, set(reentrant or ()), hot)
    eng = _Engine(analysis)
    t0 = time.perf_counter()
    files = [
        p for p in iter_py_files(paths)
        if os.path.basename(p) != "__init__.py" or os.path.getsize(p) > 0
    ]
    for path in files:
        try:
            eng.index_module(path)
        except (SyntaxError, OSError) as exc:
            analysis.findings.append(
                Finding(path, 0, "parse", str(exc))
            )
    t1 = time.perf_counter()
    eng.link_classes()
    eng.collect_all()
    t2 = time.perf_counter()
    eng.bind_params()
    eng.propagate()
    t3 = time.perf_counter()
    eng.report()
    t4 = time.perf_counter()
    analysis.stats = {
        "files": len(files),
        "functions": len(analysis.functions),
        "lock_edges": len(analysis.lock_edges),
        "parse_s": round(t1 - t0, 4),
        "collect_s": round(t2 - t1, 4),
        "fixpoint_s": round(t3 - t2, 4),
        "rules_s": round(t4 - t3, 4),
    }
    return analysis


def _baseline_key(f: Finding) -> tuple:
    return (f.rule, _scope_key(f.path), f.message)


def load_baseline(path: str) -> set:
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    out = set()
    for rec in data.get("findings", []):
        out.add((rec["rule"], rec["path"], rec["message"]))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tools.dsflow",
        description="interprocedural lock/effect analysis for the DSLog "
        "core",
    )
    ap.add_argument("paths", nargs="*", help="files or directories")
    ap.add_argument("--json", action="store_true", help="machine-readable")
    ap.add_argument("--baseline", help="known-findings file; fail only on "
                    "new findings")
    ap.add_argument("--write-baseline", help="record current findings")
    ap.add_argument("--check-dynamic", help="racecheck edge export to "
                    "cross-check against the static graph")
    ap.add_argument("--stats", action="store_true", help="phase timings to "
                    "stderr")
    args = ap.parse_args(argv)
    if not args.paths:
        ap.error("no paths given")
    analysis = analyze_paths(args.paths)
    if args.check_dynamic:
        try:
            with open(args.check_dynamic, encoding="utf-8") as fh:
                dyn = json.load(fh)
        except (OSError, ValueError) as exc:
            print(f"dsflow: cannot read {args.check_dynamic}: {exc}",
                  file=sys.stderr)
            return 2
        analysis.findings.extend(
            analysis.check_dynamic(dyn.get("edges", []))
        )
    findings = analysis.findings
    if args.write_baseline:
        with open(args.write_baseline, "w", encoding="utf-8") as fh:
            json.dump(
                {
                    "findings": [
                        {
                            "rule": f.rule,
                            "path": _scope_key(f.path),
                            "message": f.message,
                        }
                        for f in findings
                    ]
                },
                fh,
                indent=2,
            )
            fh.write("\n")
    if args.baseline:
        try:
            known = load_baseline(args.baseline)
        except (OSError, ValueError, KeyError) as exc:
            print(f"dsflow: cannot read baseline: {exc}", file=sys.stderr)
            return 2
        findings = [f for f in findings if _baseline_key(f) not in known]
    if args.stats:
        print(f"dsflow stats: {analysis.stats}", file=sys.stderr)
    if args.json:
        report = analysis.to_json()
        report["findings"] = [f.to_dict() for f in findings]
        print(json.dumps(report, indent=2))
    else:
        for f in findings:
            print(f)
        print(f"dsflow: {len(findings)} finding(s), "
              f"{len(analysis.lock_edges)} lock edge(s), "
              f"{len(analysis.functions)} function(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
