"""Shared single-parse AST cache for the static-analysis tools.

``dslint`` and ``dsflow`` both walk every file under ``src/``; parsing is
the dominant cost of a lint run and each tool used to re-read and re-parse
independently.  This module parses each file exactly once per content
version — entries are keyed by ``(st_mtime_ns, st_size)`` so an edited
file re-parses and an unchanged file never does — and additionally
precomputes a node index (``type → [nodes]``) so rules iterate only the
node types they care about instead of re-walking the whole tree per rule.
"""

from __future__ import annotations

import ast
import os


class ParsedFile:
    """One parsed source file plus a lazily built per-type node index."""

    __slots__ = ("path", "source", "tree", "_nodes", "_by_type")

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path
        self.source = source
        self.tree = tree
        self._nodes: list[ast.AST] | None = None
        self._by_type: dict[type, list[ast.AST]] | None = None

    @property
    def nodes(self) -> list[ast.AST]:
        """Every node in the tree, walked exactly once and memoised."""
        if self._nodes is None:
            self._nodes = list(ast.walk(self.tree))
        return self._nodes

    def by_type(self, *types: type) -> list[ast.AST]:
        """All nodes whose exact class is one of ``types`` (no subclassing:
        the index is keyed on ``type(node)``, which is what ``ast`` rules
        match in practice)."""
        if self._by_type is None:
            index: dict[type, list[ast.AST]] = {}
            for node in self.nodes:
                index.setdefault(type(node), []).append(node)
            self._by_type = index
        out: list[ast.AST] = []
        for t in types:
            out.extend(self._by_type.get(t, ()))
        return out


# path → (stat key, ParsedFile)
_CACHE: dict[str, tuple[tuple[int, int], ParsedFile]] = {}


def parse(path: str) -> ParsedFile:
    """Parse ``path`` (or return the cached parse if unchanged on disk).

    Raises ``SyntaxError`` / ``OSError`` like ``ast.parse`` / ``open``;
    failures are never cached.
    """
    st = os.stat(path)
    key = (st.st_mtime_ns, st.st_size)
    hit = _CACHE.get(path)
    if hit is not None and hit[0] == key:
        return hit[1]
    with open(path, encoding="utf-8") as f:
        source = f.read()
    parsed = ParsedFile(path, source, ast.parse(source, filename=path))
    _CACHE[path] = (key, parsed)
    return parsed


def clear() -> None:
    """Drop the cache (tests; long-lived tool processes)."""
    _CACHE.clear()
