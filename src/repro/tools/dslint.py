"""``dslint`` — AST lint pass for DSLog project invariants (layer 1).

Usage::

    python -m repro.tools.dslint src/            # lint a tree, exit 1 on findings
    python -m repro.tools.dslint --list-rules
    python -m repro.tools.dslint --json src/

The rules encode invariants the type system can't express:

``lock-context``
    Inside ``core/``, locks are only ever taken via ``with`` — explicit
    ``.acquire()`` / ``.release()`` on a lock-like attribute is an error
    (a raised exception between the two leaks the lock forever).
``lock-order``
    Syntactically nested ``with`` acquisitions must respect the declared
    rank table (``repro.tools.lockorder``); a ``with`` on a lock-like
    attribute that is *not* in the table is itself a finding (the table
    must stay complete to mean anything).
``lock-new``
    ``core/`` constructs locks only through ``repro.core._locks`` (so the
    dynamic race detector can substitute instrumented locks); direct
    ``threading.Lock()`` / ``threading.RLock()`` calls are errors outside
    ``_locks.py``.
``atomic-manifest``
    In the persistence modules (``core/catalog.py``, ``core/shard.py``)
    every *text*-mode write must go through ``_atomic_write`` (temp file +
    fsync + rename) — a plain ``open(path, "w")`` can tear a manifest.
``fsync-blob``
    In the same modules, a function that opens a file in ``"wb"`` mode must
    also fsync it before returning (blobs are referenced by a manifest that
    becomes visible atomically; the blob must hit stable storage first).
``bare-except``
    No ``except:`` without an exception type in ``core/``, ``kernels/``,
    ``tools/``.
``mutable-default``
    No mutable default arguments (``[]``, ``{}``, ``set()``, …) in
    ``core/``, ``kernels/``, ``tools/``.
``metric-registry``
    In ``core/``, instrument state lives in the store's
    :class:`~repro.obs.metrics.MetricsRegistry`; writing through a
    legacy stats-dict attribute (``x.io_stats[...] = ...``,
    ``x.stats[...] += n``, ``x._io.update(...)``) bypasses the
    registry's lock and its exporters.  Mutate via ``metrics.inc`` /
    ``observe`` / ``set_gauge`` instead (``hop_stats`` is exempt: it is
    the planner's lock-guarded EMA table, not an instrument dict).
``int32-cast``
    In the kernel packers (``core/query.py``, ``kernels/``), a function
    performing ``.astype(np.int32)`` / ``.astype("int32")`` must reference
    one of the overflow guards (``_require_int32`` / ``fits_int32`` /
    ``int32_safe``) so the cast can never silently wrap.

Any finding can be suppressed on its line with ``# dslint: ignore[rule]``
(or a blanket ``# dslint: ignore``).  Rules are pluggable: call
:func:`register` with an object exposing ``name``, ``applies(scope)`` and
``check(ctx)`` before invoking :func:`lint_paths`.
"""

from __future__ import annotations

import argparse
import ast
import json
import os
import re
import sys
import time
from dataclasses import dataclass
from typing import Iterable, Iterator

from . import astcache
from .lockorder import STATIC_LOCKS, rank

_PRAGMA = re.compile(r"#\s*dslint:\s*ignore(?:\[(?P<rules>[\w\-, ]+)\])?")
_LOCKISH = re.compile(r"(?:lock|mutex)$", re.IGNORECASE)


@dataclass
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class Context:
    """One file being linted: the shared parse (AST + node index) and the
    pragma map.  Rules query :meth:`nodes` instead of re-walking the tree,
    so one ``ast.walk`` serves every rule (``repro.tools.astcache`` owns
    the parse, so ``dsflow`` runs over the same trees for free)."""

    def __init__(self, path: str, scope: str, parsed: astcache.ParsedFile):
        self.path = path
        self.scope = scope  # normalized repo-relative key, e.g. repro/core/wal.py
        self.parsed = parsed
        self.source = parsed.source
        self.tree = parsed.tree
        self.ignores: dict[int, set[str] | None] = {}
        for lineno, line in enumerate(parsed.source.splitlines(), start=1):
            m = _PRAGMA.search(line)
            if m:
                rules = m.group("rules")
                self.ignores[lineno] = (
                    {r.strip() for r in rules.split(",")} if rules else None
                )

    def nodes(self, *types: type) -> tuple:
        """All nodes of the given exact AST classes (cached index)."""
        return self.parsed.by_type(*types)

    def suppressed(self, line: int, rule: str) -> bool:
        # a pragma suppresses its own line and the line directly below it
        # (for statements too long to carry a trailing comment)
        for at in (line, line - 1):
            if at in self.ignores:
                rules = self.ignores[at]
                if rules is None or rule in rules:
                    return True
        return False

    @property
    def module_stem(self) -> str:
        return os.path.splitext(os.path.basename(self.path))[0]

    def functions(self) -> Iterator[ast.AST]:
        yield from self.nodes(ast.FunctionDef, ast.AsyncFunctionDef)


def _in_dir(scope: str, *dirs: str) -> bool:
    parts = scope.split("/")
    return any(d in parts[:-1] for d in dirs)


def _is_lockish_expr(node: ast.expr) -> str | None:
    """The attribute name if ``node`` looks like a lock attribute access."""
    if isinstance(node, ast.Attribute) and _LOCKISH.search(node.attr):
        return node.attr
    if isinstance(node, ast.Name) and _LOCKISH.search(node.id):
        return node.id
    return None


# --------------------------------------------------------------------------
# rules
# --------------------------------------------------------------------------

RULES: list = []


def register(rule) -> None:
    RULES.append(rule)


def _rule(cls):
    register(cls())
    return cls


@_rule
class LockContextRule:
    name = "lock-context"

    def applies(self, scope: str) -> bool:
        return _in_dir(scope, "core") and not scope.endswith("_locks.py")

    def check(self, ctx: Context) -> Iterator[Finding]:
        for node in ctx.nodes(ast.Call):
            if not isinstance(node.func, ast.Attribute):
                continue
            if node.func.attr not in ("acquire", "release"):
                continue
            if _is_lockish_expr(node.func.value) is None:
                continue
            yield Finding(
                ctx.path,
                node.lineno,
                self.name,
                f"explicit .{node.func.attr}() on "
                f"{ast.unparse(node.func.value)}; acquire locks with "
                "'with' so exceptions cannot leak them",
            )


@_rule
class LockOrderRule:
    name = "lock-order"

    def applies(self, scope: str) -> bool:
        return _in_dir(scope, "core") and not scope.endswith("_locks.py")

    def _lock_name(self, ctx: Context, item: ast.withitem) -> tuple[str | None, str | None]:
        """(declared name, attr) for a with-item that acquires a lock."""
        expr = item.context_expr
        attr = _is_lockish_expr(expr)
        if attr is None:
            return None, None
        return STATIC_LOCKS.get((ctx.module_stem, attr)), attr

    def check(self, ctx: Context) -> Iterator[Finding]:
        findings: list[Finding] = []

        def walk(node: ast.AST, held: tuple[tuple[str, int], ...]) -> None:
            for child in ast.iter_child_nodes(node):
                inner = held
                if isinstance(child, ast.With):
                    for item in child.items:
                        declared, attr = self._lock_name(ctx, item)
                        if attr is None:
                            continue
                        if declared is None:
                            findings.append(
                                Finding(
                                    ctx.path,
                                    child.lineno,
                                    self.name,
                                    f"lock-like attribute {attr!r} is not in "
                                    "the declared lock-order table "
                                    "(repro.tools.lockorder); declare it or "
                                    "rename it",
                                )
                            )
                            continue
                        my_rank = rank(declared)
                        for held_name, held_rank in inner:
                            if my_rank is not None and my_rank <= held_rank:
                                findings.append(
                                    Finding(
                                        ctx.path,
                                        child.lineno,
                                        self.name,
                                        f"acquires {declared} (rank {my_rank}) "
                                        f"inside {held_name} (rank "
                                        f"{held_rank}); declared order is "
                                        "violated",
                                    )
                                )
                        if my_rank is not None:
                            inner = inner + ((declared, my_rank),)
                # function boundaries reset the held set: the static pass
                # only reasons about *syntactic* nesting (the dynamic layer
                # covers cross-call nesting)
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                    walk(child, ())
                else:
                    walk(child, inner)

        walk(ctx.tree, ())
        yield from findings


@_rule
class LockNewRule:
    name = "lock-new"

    def applies(self, scope: str) -> bool:
        return _in_dir(scope, "core") and not scope.endswith("_locks.py")

    def check(self, ctx: Context) -> Iterator[Finding]:
        for node in ctx.nodes(ast.Call):
            fn = node.func
            if (
                isinstance(fn, ast.Attribute)
                and fn.attr in ("Lock", "RLock")
                and isinstance(fn.value, ast.Name)
                and fn.value.id == "threading"
            ):
                yield Finding(
                    ctx.path,
                    node.lineno,
                    self.name,
                    f"direct threading.{fn.attr}() in core/; mint locks via "
                    "repro.core._locks so the race detector can instrument "
                    "them",
                )


@_rule
class MetricRegistryRule:
    name = "metric-registry"

    # legacy instrument-dict attribute names; hop_stats is deliberately
    # absent (the planner's EMA table is guarded state, not a counter)
    _STATS_ATTRS = frozenset({"io_stats", "_io", "stats"})
    _MUTATORS = frozenset({"update", "setdefault", "pop", "popitem", "clear"})

    def applies(self, scope: str) -> bool:
        return _in_dir(scope, "core")

    def _stats_attr(self, node: ast.expr) -> str | None:
        if isinstance(node, ast.Attribute) and node.attr in self._STATS_ATTRS:
            return node.attr
        return None

    def check(self, ctx: Context) -> Iterator[Finding]:
        for node in ctx.nodes(ast.Assign, ast.AugAssign, ast.AnnAssign, ast.Call):
            targets: list[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            for tgt in targets:
                if not isinstance(tgt, ast.Subscript):
                    continue
                attr = self._stats_attr(tgt.value)
                if attr is not None:
                    yield Finding(
                        ctx.path,
                        node.lineno,
                        self.name,
                        f"direct write to {ast.unparse(tgt.value)}[...] "
                        "bypasses the metrics registry; use "
                        "metrics.inc/observe/set_gauge (the legacy "
                        f"{attr!r} surface is a read-only view)",
                    )
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in self._MUTATORS
                and self._stats_attr(node.func.value) is not None
            ):
                yield Finding(
                    ctx.path,
                    node.lineno,
                    self.name,
                    f".{node.func.attr}() on "
                    f"{ast.unparse(node.func.value)} bypasses the metrics "
                    "registry; use metrics.inc/observe/set_gauge",
                )


def _open_mode(node: ast.Call) -> str | None:
    """The literal mode string of an ``open()`` call, if discernible."""
    if not (isinstance(node.func, ast.Name) and node.func.id == "open"):
        return None
    mode: ast.expr | None = None
    if len(node.args) >= 2:
        mode = node.args[1]
    for kw in node.keywords:
        if kw.arg == "mode":
            mode = kw.value
    if mode is None:
        return "r"
    if isinstance(mode, ast.Constant) and isinstance(mode.value, str):
        return mode.value
    return None


@_rule
class AtomicManifestRule:
    name = "atomic-manifest"

    def applies(self, scope: str) -> bool:
        return scope.endswith(("core/catalog.py", "core/shard.py"))

    def check(self, ctx: Context) -> Iterator[Finding]:
        for fn in ctx.functions():
            if fn.name == "_atomic_write":
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                mode = _open_mode(node)
                if mode is None or "b" in mode or not any(c in mode for c in "wax"):
                    continue
                yield Finding(
                    ctx.path,
                    node.lineno,
                    self.name,
                    f"text-mode write (open mode {mode!r}) outside "
                    "_atomic_write; manifests must be written via temp file "
                    "+ fsync + atomic rename",
                )


@_rule
class FsyncBlobRule:
    name = "fsync-blob"

    def applies(self, scope: str) -> bool:
        return scope.endswith(("core/catalog.py", "core/shard.py"))

    def check(self, ctx: Context) -> Iterator[Finding]:
        for fn in ctx.functions():
            writes: list[int] = []
            fsyncs = False
            for node in ast.walk(fn):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and node is not fn:
                    continue  # nested defs are visited on their own
                if isinstance(node, ast.Call):
                    mode = _open_mode(node)
                    if mode is not None and "b" in mode and any(c in mode for c in "wax"):
                        writes.append(node.lineno)
                    if (
                        isinstance(node.func, ast.Attribute)
                        and node.func.attr == "fsync"
                    ):
                        fsyncs = True
            if writes and not fsyncs:
                for line in writes:
                    yield Finding(
                        ctx.path,
                        line,
                        self.name,
                        f"binary write in {fn.name}() without an fsync; "
                        "manifest-referenced blobs must be durable before "
                        "the manifest rename publishes them",
                    )


@_rule
class BareExceptRule:
    name = "bare-except"

    def applies(self, scope: str) -> bool:
        return _in_dir(scope, "core", "kernels", "tools")

    def check(self, ctx: Context) -> Iterator[Finding]:
        for node in ctx.nodes(ast.ExceptHandler):
            if node.type is None:
                yield Finding(
                    ctx.path,
                    node.lineno,
                    self.name,
                    "bare 'except:' swallows KeyboardInterrupt/SystemExit; "
                    "name the exceptions",
                )


@_rule
class MutableDefaultRule:
    name = "mutable-default"

    _MUTABLE_CALLS = ("list", "dict", "set", "bytearray")

    def applies(self, scope: str) -> bool:
        return _in_dir(scope, "core", "kernels", "tools")

    def _is_mutable(self, node: ast.expr) -> bool:
        if isinstance(node, (ast.List, ast.Dict, ast.Set)):
            return True
        return (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id in self._MUTABLE_CALLS
            and not node.args
            and not node.keywords
        )

    def check(self, ctx: Context) -> Iterator[Finding]:
        for fn in ctx.functions():
            for default in list(fn.args.defaults) + [
                d for d in fn.args.kw_defaults if d is not None
            ]:
                if self._is_mutable(default):
                    yield Finding(
                        ctx.path,
                        default.lineno,
                        self.name,
                        f"mutable default argument in {fn.name}(); use None "
                        "and construct inside the body",
                    )


@_rule
class Int32CastRule:
    name = "int32-cast"

    _GUARDS = ("_require_int32", "fits_int32", "int32_safe")

    def applies(self, scope: str) -> bool:
        return scope.endswith("core/query.py") or _in_dir(scope, "kernels")

    def _is_i32_cast(self, node: ast.Call) -> bool:
        if not (isinstance(node.func, ast.Attribute) and node.func.attr == "astype"):
            return False
        if not node.args:
            return False
        arg = node.args[0]
        if isinstance(arg, ast.Constant) and arg.value == "int32":
            return True
        return (
            isinstance(arg, ast.Attribute)
            and arg.attr == "int32"
            and isinstance(arg.value, ast.Name)
            and arg.value.id in ("np", "numpy", "jnp")
        )

    def check(self, ctx: Context) -> Iterator[Finding]:
        for fn in ctx.functions():
            names = {
                n.id for n in ast.walk(fn) if isinstance(n, ast.Name)
            } | {
                n.attr for n in ast.walk(fn) if isinstance(n, ast.Attribute)
            }
            guarded = any(g in names for g in self._GUARDS)
            if guarded:
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) and self._is_i32_cast(node):
                    yield Finding(
                        ctx.path,
                        node.lineno,
                        self.name,
                        f"astype(int32) in {fn.name}() with no overflow "
                        "guard in scope; call _require_int32/fits_int32 "
                        "first (silent wraparound corrupts packed "
                        "coordinates)",
                    )


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------


def _scope_key(path: str) -> str:
    """Repo-relative rule-scoping key: the path from the ``repro`` package
    root if present, else the path as given."""
    norm = path.replace(os.sep, "/")
    parts = norm.split("/")
    if "repro" in parts:
        parts = parts[len(parts) - 1 - parts[::-1].index("repro"):]
    return "/".join(parts)


def iter_py_files(paths: Iterable[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            yield p
        else:
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames if not d.startswith(".")]
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        yield os.path.join(dirpath, fn)


def lint_file(path: str) -> list[Finding]:
    scope = _scope_key(path)
    try:
        ctx = Context(path, scope, astcache.parse(path))
    except SyntaxError as exc:
        return [Finding(path, exc.lineno or 0, "syntax", str(exc))]
    out: list[Finding] = []
    for r in RULES:
        if not r.applies(scope):
            continue
        for finding in r.check(ctx):
            if not ctx.suppressed(finding.line, finding.rule):
                out.append(finding)
    out.sort(key=lambda f: (f.line, f.rule))
    return out


def lint_paths(paths: Iterable[str]) -> list[Finding]:
    out: list[Finding] = []
    for path in iter_py_files(paths):
        out.extend(lint_file(path))
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tools.dslint",
        description="AST lint for DSLog project invariants",
    )
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument("--json", action="store_true", help="machine-readable output")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument(
        "--stats", action="store_true", help="print file/timing stats to stderr"
    )
    args = ap.parse_args(argv)
    if args.list_rules:
        for r in RULES:
            print(r.name)
        return 0
    if not args.paths:
        ap.error("no paths given")
    t0 = time.perf_counter()
    files = list(iter_py_files(args.paths))
    findings = lint_paths(files)
    if args.stats:
        print(
            f"dslint stats: files={len(files)} rules={len(RULES)} "
            f"findings={len(findings)} "
            f"elapsed_s={time.perf_counter() - t0:.4f}",
            file=sys.stderr,
        )
    if args.json:
        print(
            json.dumps(
                [
                    {"path": f.path, "line": f.line, "rule": f.rule, "msg": f.message}
                    for f in findings
                ],
                indent=2,
            )
        )
    else:
        for f in findings:
            print(f)
        print(f"dslint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
