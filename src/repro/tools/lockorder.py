"""The declared lock-order table for ``repro.core``.

Both analysis layers consume this module: ``dslint`` checks syntactically
nested ``with`` acquisitions against it, and ``racecheck`` checks the actual
per-thread acquisition order at runtime.  A thread may only acquire a lock
whose rank is *strictly greater* than every lock it already holds (reentrant
re-acquisition of the same RLock object is exempt).

Rank order (outermost → innermost):

1.  ``shard._shard_load_lock`` — serialises lazy shard materialisation on a
    ``ShardedDSLog``; taken before any per-shard state is touched.
2.  ``views._lock`` — ``ViewManager`` state (materialized views, route
    heat, the answer cache).  Invalidation hooks fire while a shard is
    being absorbed (load lock held), so it nests inside the load lock; view
    composition and blob loads happen *outside* it, so it stays above
    ``table._lock``.
3.  ``table._lock`` — per-``TableHandle`` single-fire load latch; the loader
    may bump store I/O meters, so it sits above the stats locks.
4.  ``commit._flush_mutex`` — the durability barrier: held across "write
    dirty state, then flush the WAL", so it must be *outside* ``wal._lock``.
    This is the one place the code deviates from the naive
    catalog → shard → wal → commit reading of the subsystem layering: the
    commit pipeline is the WAL's *caller* during a flush, never the other
    way round, so commit locks rank above (outside) the WAL lock.
5.  ``commit._lock`` — protects the pipeline's dirty/LSN bookkeeping; nested
    inside ``_flush_mutex`` by ``CommitPipeline._flush_dirty``.
6.  ``wal._lock`` — serialises appends/flushes on one ``WriteAheadLog``.
7.  ``shard._stats_lock`` — ``ShardedDSLog`` I/O + hop-stats meters (leaf).
8.  ``catalog._stats_lock`` — ``DSLog`` I/O + hop-stats meters (leaf).
9.  ``metrics._lock`` — a ``MetricsRegistry``'s instrument table.  Every
    counter/histogram update may fire while any of the locks above is
    held (WAL appends, commit flushes, stats bookkeeping), so the
    registry lock is a leaf below all of them and takes no other lock.
10. ``trace._lock`` — a ``QueryTrace``'s span-attach lock.  Span exit
    reads counter deltas (``metrics._lock``) *before* attaching, so the
    trace lock nests innermost of all.

Lock names are ``"<module stem>.<attribute>"``; every lock constructed via
``repro.core._locks`` carries one.
"""

from __future__ import annotations

LOCK_ORDER: dict[str, int] = {
    "shard._shard_load_lock": 10,
    "views._lock": 15,
    "table._lock": 20,
    "commit._flush_mutex": 30,
    "commit._lock": 40,
    "wal._lock": 50,
    "shard._stats_lock": 60,
    "catalog._stats_lock": 70,
    "metrics._lock": 80,
    "trace._lock": 90,
}

#: (module stem, attribute name) → declared lock name, for the static pass.
#: ``self.log._stats_lock`` inside ``shard.py`` resolves through the module
#: stem, so facade code touching its own stats lock maps correctly.
STATIC_LOCKS: dict[tuple[str, str], str] = {
    ("shard", "_shard_load_lock"): "shard._shard_load_lock",
    ("views", "_lock"): "views._lock",
    ("shard", "_stats_lock"): "shard._stats_lock",
    ("catalog", "_stats_lock"): "catalog._stats_lock",
    # planner accumulates EXPLAIN ANALYZE measurements under the owning
    # store's stats lock (self.log._stats_lock)
    ("planner", "_stats_lock"): "catalog._stats_lock",
    ("table", "_lock"): "table._lock",
    ("wal", "_lock"): "wal._lock",
    ("commit", "_lock"): "commit._lock",
    ("commit", "_flush_mutex"): "commit._flush_mutex",
    ("metrics", "_lock"): "metrics._lock",
    ("trace", "_lock"): "trace._lock",
}


def rank(name: str) -> int | None:
    """Rank of a declared lock name; ``None`` for locks outside the table."""
    return LOCK_ORDER.get(name)
