"""The declared lock-order table for ``repro.core``.

Both analysis layers consume this module: ``dslint`` checks syntactically
nested ``with`` acquisitions against it, and ``racecheck`` checks the actual
per-thread acquisition order at runtime.  A thread may only acquire a lock
whose rank is *strictly greater* than every lock it already holds (reentrant
re-acquisition of the same RLock object is exempt).

Rank order (outermost → innermost):

1.  ``shard._shard_load_lock`` — serialises lazy shard materialisation on a
    ``ShardedDSLog``; taken before any per-shard state is touched.
2.  ``views._lock`` — ``ViewManager`` state (materialized views, route
    heat, the answer cache).  Invalidation hooks fire while a shard is
    being absorbed (load lock held), so it nests inside the load lock; view
    composition and blob loads happen *outside* it, so it stays above
    ``table._lock``.
3.  ``table._lock`` — per-``TableHandle`` single-fire load latch; the loader
    may bump store I/O meters, so it sits above the stats locks.
4.  ``commit._flush_mutex`` — the durability barrier: held across "write
    dirty state, then flush the WAL", so it must be *outside* ``wal._lock``.
    This is the one place the code deviates from the naive
    catalog → shard → wal → commit reading of the subsystem layering: the
    commit pipeline is the WAL's *caller* during a flush, never the other
    way round, so commit locks rank above (outside) the WAL lock.
5.  ``commit._lock`` — protects the pipeline's dirty/LSN bookkeeping; nested
    inside ``_flush_mutex`` by ``CommitPipeline._flush_dirty``.
6.  ``wal._lock`` — serialises appends/flushes on one ``WriteAheadLog``.
7.  ``shard._stats_lock`` — ``ShardedDSLog`` I/O + hop-stats meters (leaf).
8.  ``catalog._stats_lock`` — ``DSLog`` I/O + hop-stats meters (leaf).
9.  ``autotune._lock`` — a ``GeometryTuner``'s winner table.  Measurement
    runs *outside* it (runners execute real workloads that take stats
    locks); the lock only guards table reads/writes, so it is a leaf that
    callers holding any stats lock may still take.
10. ``metrics._lock`` — a ``MetricsRegistry``'s instrument table.  Every
    counter/histogram update may fire while any of the locks above is
    held (WAL appends, commit flushes, stats bookkeeping), so the
    registry lock is a leaf below all of them and takes no other lock.
11. ``trace._lock`` — a ``QueryTrace``'s span-attach lock.  Span exit
    reads counter deltas (``metrics._lock``) *before* attaching, so the
    trace lock nests innermost of all.

Lock names are ``"<module stem>.<attribute>"``; every lock constructed via
``repro.core._locks`` carries one.
"""

from __future__ import annotations

LOCK_ORDER: dict[str, int] = {
    "shard._shard_load_lock": 10,
    "views._lock": 15,
    "table._lock": 20,
    "commit._flush_mutex": 30,
    "commit._lock": 40,
    "wal._lock": 50,
    "shard._stats_lock": 60,
    "catalog._stats_lock": 70,
    "autotune._lock": 75,
    "metrics._lock": 80,
    "trace._lock": 90,
}

#: One-line role per lock, for generated documentation (README table).
LOCK_ROLES: dict[str, str] = {
    "shard._shard_load_lock": "serialises lazy shard materialisation on a `ShardedDSLog`",
    "views._lock": "`ViewManager` state: materialized views, route heat, answer cache",
    "table._lock": "per-`TableHandle` single-fire blob-load latch",
    "commit._flush_mutex": "group-commit durability barrier (held across write-then-flush)",
    "commit._lock": "commit pipeline dirty/LSN bookkeeping",
    "wal._lock": "serialises appends/flushes on one `WriteAheadLog`",
    "shard._stats_lock": "`ShardedDSLog` I/O + hop-stats meters",
    "catalog._stats_lock": "`DSLog` I/O + hop-stats meters",
    "autotune._lock": "`GeometryTuner` winner table (measurement runs outside it)",
    "metrics._lock": "a `MetricsRegistry`'s instrument table (leaf)",
    "trace._lock": "a `QueryTrace`'s span-attach lock (innermost)",
}

#: (module stem, attribute name) → declared lock name, for the static pass.
#: ``self.log._stats_lock`` inside ``shard.py`` resolves through the module
#: stem, so facade code touching its own stats lock maps correctly.
STATIC_LOCKS: dict[tuple[str, str], str] = {
    ("shard", "_shard_load_lock"): "shard._shard_load_lock",
    ("views", "_lock"): "views._lock",
    ("shard", "_stats_lock"): "shard._stats_lock",
    ("catalog", "_stats_lock"): "catalog._stats_lock",
    # planner accumulates EXPLAIN ANALYZE measurements under the owning
    # store's stats lock (self.log._stats_lock)
    ("planner", "_stats_lock"): "catalog._stats_lock",
    ("table", "_lock"): "table._lock",
    ("wal", "_lock"): "wal._lock",
    ("commit", "_lock"): "commit._lock",
    ("commit", "_flush_mutex"): "commit._flush_mutex",
    ("autotune", "_lock"): "autotune._lock",
    ("metrics", "_lock"): "metrics._lock",
    ("trace", "_lock"): "trace._lock",
}


def rank(name: str) -> int | None:
    """Rank of a declared lock name; ``None`` for locks outside the table."""
    return LOCK_ORDER.get(name)


def ranked() -> list[tuple[str, int]]:
    """``(name, rank)`` pairs, outermost (lowest rank) first."""
    return sorted(LOCK_ORDER.items(), key=lambda kv: kv[1])


def markdown_table() -> str:
    """The lock-rank table as GitHub markdown (the README embeds this
    between ``<!-- lockorder:begin -->`` / ``<!-- lockorder:end -->``
    markers; a test regenerates it so the docs can't drift)."""
    lines = ["| Rank | Lock | Guards |", "|-----:|------|--------|"]
    for name, r in ranked():
        role = LOCK_ROLES.get(name, "")
        lines.append(f"| {r} | `{name}` | {role} |")
    return "\n".join(lines)


def main(argv: "list[str] | None" = None) -> int:
    """``python -m repro.tools.lockorder [--markdown|--json]``"""
    import argparse
    import json

    ap = argparse.ArgumentParser(
        prog="python -m repro.tools.lockorder",
        description="print the declared lock-order table",
    )
    ap.add_argument("--markdown", action="store_true", help="README table")
    ap.add_argument("--json", action="store_true", help="machine-readable")
    args = ap.parse_args(argv)
    if args.markdown:
        print(markdown_table())
    elif args.json:
        print(json.dumps({"lock_order": dict(ranked())}, indent=2))
    else:
        for name, r in ranked():
            print(f"{r:>3}  {name}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
