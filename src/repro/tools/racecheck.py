"""Dynamic lock-order / race detector (layer 2 of the analysis subsystem).

Opt-in: when ``DSLOG_RACE_DETECT=1``, ``repro.core._locks`` constructs
:class:`InstrumentedLock` objects instead of plain ``threading`` primitives
and wraps registered shared state (``io_stats``, ``hop_stats``, shard
caches, WAL counters) in :class:`GuardedDict` / :class:`GuardedList`.  The
instrumentation records, per thread:

* the stack of locks currently held, checking each new acquisition against
  the declared rank table in :mod:`repro.tools.lockorder` (acquiring a lock
  ranked at or below one already held is an ordering violation);
* the aggregated held→acquired edge graph across *all* threads, in which a
  cycle means two threads can deadlock even if neither ever violated the
  rank table (the table may be incomplete for unranked locks);
* every mutation of guarded shared state performed while the guarding lock
  is not held by the mutating thread.

Findings are accumulated in a process-global registry — they do **not**
raise at the point of detection (that would perturb the interleaving under
test) — and are asserted empty by the ``race_detector`` pytest fixture's
teardown.  Everything is a no-op unless the env var is set, so production
code paths pay only one ``os.environ`` lookup at *lock construction* time
and zero per-operation cost.
"""

from __future__ import annotations

import os
import threading
import traceback
from typing import Iterator

from .lockorder import rank

_ENV_VAR = "DSLOG_RACE_DETECT"


def detect_enabled() -> bool:
    return os.environ.get(_ENV_VAR, "") not in ("", "0")


# --------------------------------------------------------------------------
# global registry
# --------------------------------------------------------------------------

_registry_lock = threading.Lock()
_violations: list[str] = []
# (held_name, acquired_name) → short provenance string for the first sighting
_edges: dict[tuple[str, str], str] = {}
_tls = threading.local()


def _held_stack() -> list["InstrumentedLock"]:
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


def _caller(depth: int = 3) -> str:
    frame = traceback.extract_stack(limit=depth + 1)[0]
    return f"{os.path.basename(frame.filename)}:{frame.lineno}"


def _record_violation(msg: str) -> None:
    with _registry_lock:
        _violations.append(msg)


def reset() -> None:
    """Drop all accumulated findings and edges (per-test isolation)."""
    with _registry_lock:
        _violations.clear()
        _edges.clear()


def _graph_cycles() -> list[str]:
    """Cycles in the aggregated held→acquired name graph (potential deadlocks)."""
    with _registry_lock:
        edges = dict(_edges)
    adj: dict[str, list[str]] = {}
    for src, dst in edges:
        adj.setdefault(src, []).append(dst)
    WHITE, GREY, BLACK = 0, 1, 2
    colour = {n: WHITE for n in adj}
    cycles: list[str] = []

    def visit(node: str, path: list[str]) -> None:
        colour[node] = GREY
        path.append(node)
        for nxt in adj.get(node, ()):
            if colour.get(nxt, WHITE) == GREY:
                loop = path[path.index(nxt):] + [nxt]
                where = edges.get((node, nxt), "?")
                cycles.append(
                    "lock-cycle: " + " -> ".join(loop) + f" (edge seen at {where})"
                )
            elif colour.get(nxt, WHITE) == WHITE and nxt in adj:
                visit(nxt, path)
            elif colour.get(nxt, WHITE) == WHITE:
                colour[nxt] = BLACK
        path.pop()
        colour[node] = BLACK

    for node in list(adj):
        if colour[node] == WHITE:
            visit(node, [])
    return cycles


def findings() -> list[str]:
    """All findings so far: rank violations, unguarded mutations, cycles."""
    with _registry_lock:
        out = list(_violations)
    out.extend(_graph_cycles())
    return out


def edges() -> dict[tuple[str, str], str]:
    with _registry_lock:
        return dict(_edges)


def export_edges(path: str) -> int:
    """Merge the current acquisition graph into a JSON edge file.

    The file accumulates across test runs (``dsflow --check-dynamic``
    consumes the union), so existing edges are kept and new ones merged in;
    the write is atomic (tmp + rename) because parallel pytest workers may
    export concurrently.  Returns the total edge count written.
    """
    import json

    merged: dict[tuple[str, str], str] = {}
    try:
        with open(path, encoding="utf-8") as f:
            prior = json.load(f)
        for rec in prior.get("edges", ()):
            merged[(rec["held"], rec["acquired"])] = rec.get("where", "?")
    except (OSError, ValueError, KeyError, TypeError):
        pass  # absent or torn file: start fresh
    for (held, acquired), where in edges().items():
        merged.setdefault((held, acquired), where)
    payload = {
        "edges": [
            {"held": h, "acquired": a, "where": w}
            for (h, a), w in sorted(merged.items())
        ]
    }
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)
    return len(merged)


# --------------------------------------------------------------------------
# instrumented locks
# --------------------------------------------------------------------------


class InstrumentedLock:
    """A named, rank-checked wrapper around ``threading.Lock``/``RLock``.

    Supports the subset of the lock API the core uses: ``with``,
    ``acquire``/``release``, ``locked``.  Reentrant acquisition is permitted
    iff the wrapped primitive is an RLock.
    """

    def __init__(self, name: str, *, reentrant: bool = False):
        self.name = name
        self.reentrant = reentrant
        self._inner = threading.RLock() if reentrant else threading.Lock()
        # id(thread) → reentry depth; only ever touched by that thread for
        # its own key, so no extra synchronisation is needed.
        self._depth: dict[int, int] = {}

    # -- bookkeeping ------------------------------------------------------

    def held_by_current_thread(self) -> bool:
        return self._depth.get(threading.get_ident(), 0) > 0

    def _on_acquired(self) -> None:
        tid = threading.get_ident()
        depth = self._depth.get(tid, 0)
        self._depth[tid] = depth + 1
        if depth:  # reentrant re-acquisition: no new edge, no rank check
            return
        stack = _held_stack()
        my_rank = rank(self.name)
        where = _caller(depth=4)
        for held in stack:
            if held is self:
                continue
            with _registry_lock:
                _edges.setdefault((held.name, self.name), where)
            held_rank = rank(held.name)
            if my_rank is None or held_rank is None:
                continue  # unranked: cycle detection still covers it
            if my_rank <= held_rank:
                _record_violation(
                    f"lock-order: acquired {self.name} (rank {my_rank}) while "
                    f"holding {held.name} (rank {held_rank}) at {where}"
                )
        stack.append(self)

    def _on_released(self) -> None:
        tid = threading.get_ident()
        depth = self._depth.get(tid, 0)
        if depth <= 1:
            self._depth.pop(tid, None)
            stack = _held_stack()
            if self in stack:
                stack.remove(self)
        else:
            self._depth[tid] = depth - 1

    # -- lock API ---------------------------------------------------------

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._on_acquired()
        return got

    def release(self) -> None:
        self._on_released()
        self._inner.release()

    def locked(self) -> bool:
        inner_locked = getattr(self._inner, "locked", None)
        if inner_locked is not None:
            return inner_locked()
        return bool(self._depth)

    def __enter__(self) -> "InstrumentedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<InstrumentedLock {self.name} reentrant={self.reentrant}>"


# --------------------------------------------------------------------------
# guarded shared state
# --------------------------------------------------------------------------


def _check_guard(guard: InstrumentedLock | None, label: str, op: str) -> None:
    if guard is None or not detect_enabled():
        return
    if not guard.held_by_current_thread():
        _record_violation(
            f"unguarded-mutation: {op} on {label} without holding "
            f"{guard.name} at {_caller(depth=4)}"
        )


class GuardedDict(dict):
    """A dict that flags mutations performed without its guard lock held.

    Reads are deliberately unchecked: the core's meters tolerate torn reads
    (they are monotone counters / rebuilt-on-save hop stats) and checking
    every read would swamp the report with benign findings.
    """

    def __init__(self, data, guard: InstrumentedLock | None, label: str):
        super().__init__(data)
        self._guard = guard
        self._label = label

    def __setitem__(self, key, value):
        _check_guard(self._guard, self._label, f"__setitem__({key!r})")
        super().__setitem__(key, value)

    def __delitem__(self, key):
        _check_guard(self._guard, self._label, f"__delitem__({key!r})")
        super().__delitem__(key)

    def update(self, *args, **kwargs):
        _check_guard(self._guard, self._label, "update")
        super().update(*args, **kwargs)

    def setdefault(self, key, default=None):
        if key not in self:
            _check_guard(self._guard, self._label, f"setdefault({key!r})")
        return super().setdefault(key, default)

    def pop(self, key, *default):
        _check_guard(self._guard, self._label, f"pop({key!r})")
        return super().pop(key, *default)

    def clear(self):
        _check_guard(self._guard, self._label, "clear")
        super().clear()

    def __reduce__(self):  # keep copy/deepcopy/pickle plain
        return (dict, (dict(self),))


class GuardedList(list):
    """A list that flags item assignment/append without its guard lock held."""

    def __init__(self, data, guard: InstrumentedLock | None, label: str):
        super().__init__(data)
        self._guard = guard
        self._label = label

    def __setitem__(self, index, value):
        _check_guard(self._guard, self._label, f"__setitem__({index!r})")
        super().__setitem__(index, value)

    def append(self, value):
        _check_guard(self._guard, self._label, "append")
        super().append(value)

    def extend(self, values):
        _check_guard(self._guard, self._label, "extend")
        super().extend(values)

    def pop(self, *args):
        _check_guard(self._guard, self._label, "pop")
        return super().pop(*args)

    def __reduce__(self):
        return (list, (list(self),))


def iter_findings() -> Iterator[str]:  # pragma: no cover - convenience
    yield from findings()
