"""Build a small sharded store for smoke checks (CI's fsck target).

Usage::

    python -m repro.tools.mkstore /tmp/store [--shards 4] [--ops 12] [--seed 7]

Opens a ``ShardedDSLog`` durably, ingests a random chain-plus-fan-in DAG of
synthetic lineage (identity / flip / roll / transpose over an 8×8 array),
drops one entry, checkpoints, compacts, runs a probe ``prov_query``, and
closes.  The resulting directory exercises every on-disk structure fsck
verifies: root + shard manifests, blobs and index sidecars, WALs, the
boundary-edge table, and released leases.
"""

from __future__ import annotations

import argparse
import sys

import numpy as np


def build_store(root: str, n_shards: int = 4, n_ops: int = 12, seed: int = 7) -> dict:
    from repro.core.capture import (
        flip_lineage,
        identity_lineage,
        roll_lineage,
        transpose_lineage,
    )
    from repro.core.shard import ShardedDSLog

    shape = (8, 8)
    ops = [
        lambda rng: identity_lineage(shape),
        lambda rng: flip_lineage(shape, int(rng.integers(0, 2))),
        lambda rng: roll_lineage(shape, int(rng.integers(1, 4)), 0),
        lambda rng: transpose_lineage(shape, (1, 0)),
    ]
    rng = np.random.default_rng(seed)
    log = ShardedDSLog.open(root, n_shards=n_shards)
    try:
        names = ["a0"]
        entry_ids = []
        for k in range(n_ops):
            new = f"a{k + 1}"
            rel = ops[int(rng.integers(0, len(ops)))](rng)
            entry_ids.append(log.add_lineage(names[-1], new, rel).lineage_id)
            if k % 3 == 2 and len(names) > 2:
                other = names[int(rng.integers(0, len(names) - 1))]
                rel2 = ops[int(rng.integers(0, len(ops)))](rng)
                entry_ids.append(log.add_lineage(other, new, rel2).lineage_id)
            names.append(new)
        log.save()
        # leave GC work behind, then reclaim it: exercises the vacuum path
        log.drop_lineage(entry_ids[len(entry_ids) // 2])
        log.compact()
        probe = log.prov_query(names[0], names[-1], np.array([[1, 2], [6, 7]]))
        stats = {
            "entries": len(entry_ids) - 1,
            "arrays": len(names),
            "probe_cells": len(probe.cell_set()),
        }
    finally:
        log.close()
    return stats


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tools.mkstore",
        description="build a small sharded store for fsck smoke checks",
    )
    ap.add_argument("root")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--ops", type=int, default=12)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)
    stats = build_store(args.root, args.shards, args.ops, args.seed)
    print(f"mkstore: {args.root}: {stats}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
