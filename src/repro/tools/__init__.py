"""Correctness-analysis tooling for the DSLog store (ISSUE 6).

Three layers, each usable on its own:

* :mod:`repro.tools.dslint` — AST lint pass enforcing project invariants the
  type system can't (context-managed locks, the declared lock order, atomic
  manifest writes, fsynced blob writes, no bare ``except:`` / mutable default
  args / unguarded int32 casts in kernel packers).
  Run as ``python -m repro.tools.dslint src/``.
* :mod:`repro.tools.racecheck` — opt-in dynamic lock-order / race detector.
  Set ``DSLOG_RACE_DETECT=1`` (the ``race_detector`` pytest fixture does) and
  ``repro.core._locks`` hands out instrumented locks that record the
  per-thread acquisition graph plus unguarded mutations of registered shared
  state (``io_stats``, ``hop_stats``, shard caches).
* :mod:`repro.tools.fsck` — deep, non-mutating on-disk verifier.
  Run as ``python -m repro.tools.fsck <store>``.

The declared lock-order table shared by the static and dynamic layers lives
in :mod:`repro.tools.lockorder`.
"""
