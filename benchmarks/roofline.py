"""§Roofline: three-term analysis from the dry-run artifacts.

Per (arch × shape) on the single-pod 16x16 mesh (256 chips):

  compute_s    = HLO_FLOPs_per_device / 197e12         (bf16 peak / chip)
  memory_s     = HLO_bytes_per_device / 819e9          (HBM bandwidth)
  collective_s = collective_bytes_per_device / 50e9    (~1 ICI link)

HLO terms come from the loop-accurate 1L/2L-unrolled extrapolation (see
``launch.dryrun.account_cell``); collective bytes are summed result-buffer
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops in post-SPMD HLO.  MODEL_FLOPS uses 6·N_active·D
(train) or 2·N_active·D (forward-only), giving the "useful fraction" that
catches remat/dispatch/replication waste.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHS, SHAPES

PEAK_FLOPS = 197e12  # bf16 / chip (v5e)
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s ICI per link
CHIPS = 256

__all__ = ["load_records", "analyze", "run_roofline"]


def load_records(root: str = "experiments/dryrun/pod16x16") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(root, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def model_flops_per_device(arch_name: str, shape_name: str) -> float:
    cfg = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    n_active = cfg.active_params_billions() * 1e9
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / CHIPS


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    cost = rec.get("cost_accounted")
    if not isinstance(cost, dict) or "flops" not in cost:
        cost = rec.get("cost_analysis")
        if not isinstance(cost, dict):
            return None
    coll = rec.get("collectives", {})
    coll_bytes = coll.get("total_bytes", 0) if isinstance(coll, dict) else 0
    flops = cost.get("flops", 0.0)
    byts = cost.get("bytes accessed", 0.0)
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"])
    useful = mf / max(flops, 1.0)
    # roofline fraction: useful-math time over the binding term's time
    frac = (mf / PEAK_FLOPS) / max(terms[dominant], 1e-12)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "kind": rec.get("kind", "?"),
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_dev": mf,
        "hlo_flops_dev": flops,
        "useful_flop_ratio": useful,
        "roofline_fraction": frac,
        "state_gb_dev": rec.get("state_bytes_per_device", 0) / 1e9,
        "temp_gb_dev": (
            (rec.get("memory_analysis") or {}).get("temp_size_in_bytes", 0) / 1e9
            if isinstance(rec.get("memory_analysis"), dict)
            else None
        ),
    }


def run_roofline(root="experiments/dryrun/pod16x16", verbose=True,
                 out_md="experiments/roofline.md"):
    rows = [a for a in (analyze(r) for r in load_records(root)) if a]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    if verbose:
        hdr = (f"  {'arch':18s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s}"
               f" {'collect_s':>10s} {'bound':>10s} {'useful':>7s} {'roofl%':>7s}")
        print(hdr)
        for r in rows:
            print(
                f"  {r['arch']:18s} {r['shape']:12s} {r['compute_s']:10.4f}"
                f" {r['memory_s']:10.4f} {r['collective_s']:10.4f}"
                f" {r['dominant']:>10s} {r['useful_flop_ratio']:7.3f}"
                f" {100 * r['roofline_fraction']:7.2f}"
            )
    if out_md:
        os.makedirs(os.path.dirname(out_md), exist_ok=True)
        with open(out_md, "w") as f:
            f.write("| arch | shape | compute_s | memory_s | collective_s | "
                    "bound | useful flop ratio | roofline % | state GB/dev | temp GB/dev |\n")
            f.write("|---|---|---|---|---|---|---|---|---|---|\n")
            for r in rows:
                t = f"{r['temp_gb_dev']:.2f}" if r["temp_gb_dev"] is not None else "-"
                f.write(
                    f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
                    f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                    f"{r['dominant']} | {r['useful_flop_ratio']:.3f} | "
                    f"{100 * r['roofline_fraction']:.2f} | "
                    f"{r['state_gb_dev']:.2f} | {t} |\n"
                )
    return rows
