"""Fig 7 analog: compression latency vs input size for the two extreme
lineage types (one-to-one element-wise; one-axis aggregation)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import capture as C
from repro.core.provrc import compress

from .baselines import FORMATS

__all__ = ["run_fig7"]


def _time(fn, *args) -> float:
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0


def run_fig7(sizes=(10_000, 100_000, 1_000_000), verbose: bool = True):
    rows = []
    for n in sizes:
        side = int(np.sqrt(n))
        for kind, rel in (
            ("elementwise", C.identity_lineage((n,))),
            ("aggregate", C.reduce_lineage((side, side), 1)),
        ):
            raw = rel.rows()
            rec = {"kind": kind, "n_cells": n}
            for fmt, (enc, _) in FORMATS.items():
                rec[fmt + "_s"] = _time(enc, raw)
            rec["provrc_s"] = _time(lambda: compress(rel, method="vector"))
            rec["provrc_gzip_s"] = _time(
                lambda: compress(rel, method="vector").serialize(compress=True)
            )
            rows.append(rec)
            if verbose:
                print(
                    f"  {kind:12s} n={n:9d} "
                    + " ".join(
                        f"{k[:-2]}={rec[k]*1e3:8.1f}ms"
                        for k in rec
                        if k.endswith("_s")
                    ),
                    flush=True,
                )
    return rows
