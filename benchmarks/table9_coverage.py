"""Table IX analog: ProvRC compression + automatic reuse coverage over the
op registry (the paper's 136-op numpy sweep; our registry holds 120+).

Per op: does ProvRC compress to < 50% of the raw file?  Does automatic
prediction discover a dim_sig / gen_sig mapping?  How many *mispredictions*
occur (gen_sig confirmed but wrong at a new shape — the paper's `cross`)?
"""

from __future__ import annotations

import numpy as np

from repro.core.oplib import OPS
from repro.core.provrc import compress
from repro.core.reuse import (
    ReusePredictor,
    generalize,
    instantiate,
    sig_key_dim,
    sig_key_gen,
    tables_equal,
)

__all__ = ["run_table9"]


def _simulate_reuse(spec, n_runs: int = 4):
    """Feed successive captures through the predictor like register_operation
    does; returns (dim_status, gen_status, misprediction)."""
    pred = ReusePredictor(m=1)
    rng = np.random.default_rng(0)
    shapes = list(spec.shapes) * ((n_runs // len(spec.shapes)) + 1)
    mispred = False
    for call, shape in enumerate(shapes[:n_runs]):
        rels = spec.lineage(shape, rng)
        tables = {
            f"{oi}:{ii}": compress(rel, method="vector")
            for (oi, ii), rel in rels.items()
        }
        shapes_token = (shape,)
        dim_key = sig_key_dim(spec.name, (shape,), None)
        gen_key = sig_key_gen(spec.name, None)
        decision = pred.lookup(
            dim_key, gen_key, shapes_token,
            {k: (t.key_shape, t.val_shape) for k, t in tables.items()},
        )
        if decision.reused:
            # check the reused tables against ground truth
            for label, got in decision.tables.items():
                want = tables[label]
                inst = got
                if not tables_equal(inst, want):
                    mispred = True
            continue
        pred.observe(dim_key, gen_key, shapes_token, tables)
    dim_statuses = {
        pred.status(sig_key_dim(spec.name, (s,), None)) for s in spec.shapes
    }
    gen_status = pred.status(sig_key_gen(spec.name, None))
    gen_ok = gen_status == "confirmed"
    # a confirmed gen_sig subsumes dim_sig (shape-based reuse holds a
    # fortiori); without it the gen lookup short-circuits dim confirmation
    dim_ok = "confirmed" in dim_statuses or gen_ok
    return dim_ok, gen_ok, mispred


def run_table9(verbose: bool = True) -> dict:
    rng = np.random.default_rng(0)
    per_cat = {
        "element": {"total": 0, "compressed": 0, "dim": 0, "gen": 0, "err": 0},
        "complex": {"total": 0, "compressed": 0, "dim": 0, "gen": 0, "err": 0},
    }
    for name, spec in sorted(OPS.items()):
        cat = per_cat[spec.category]
        cat["total"] += 1
        rels = spec.lineage(spec.shapes[0], rng)
        raw = sum(rel.nbytes_raw() for rel in rels.values())
        packed = sum(
            compress(rel, method="vector").nbytes() for rel in rels.values()
        )
        if packed < 0.5 * raw:
            cat["compressed"] += 1
        dim_ok, gen_ok, mispred = _simulate_reuse(spec)
        cat["dim"] += dim_ok
        cat["gen"] += gen_ok
        cat["err"] += mispred
    total = {
        k: per_cat["element"][k] + per_cat["complex"][k]
        for k in per_cat["element"]
    }
    result = {**per_cat, "total": total}
    if verbose:
        print("  category   total  provrc<50%   dim_sig   gen_sig   errors")
        for cat in ("element", "complex", "total"):
            r = result[cat]
            print(
                f"  {cat:9s} {r['total']:6d} {r['compressed']:10d}"
                f" {r['dim']:9d} {r['gen']:9d} {r['err']:8d}"
            )
    return result
