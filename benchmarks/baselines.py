"""Storage-format baselines for the compression experiments (paper §VII.B).

Offline re-implementations of the paper's baseline *encoding families* (the
paper used DuckDB/Parquet/TurboPFor binaries; we reproduce the algorithms so
the benchmark runs hermetically — see DESIGN.md §9):

* ``raw``          — row-oriented int64 tuples (Ground-style).
* ``array``        — the numpy array dump (same bytes + header).
* ``parquet_like`` — per-column delta + zigzag + minimal-width bit packing
                     (Parquet PLAIN/DELTA_BINARY_PACKED family).
* ``parquet_gzip`` — zlib over ``parquet_like`` (Parquet-GZip).
* ``rle_like``     — per-column run-length (value, count) pairs, both packed
                     to minimal width (Turbo-RC's RLE + integer coding family).

Each codec returns ``bytes``; ``decode_*`` restores the row matrix (needed
for the query-latency baselines, which must decompress before joining —
that asymmetry vs. DSLog's in-situ processing is the paper's point).
"""

from __future__ import annotations

import io
import zlib

import numpy as np

__all__ = [
    "encode_raw",
    "encode_array",
    "encode_parquet_like",
    "decode_parquet_like",
    "encode_parquet_gzip",
    "decode_parquet_gzip",
    "encode_rle_like",
    "decode_rle_like",
    "FORMATS",
]


def encode_raw(rows: np.ndarray) -> bytes:
    return np.ascontiguousarray(rows.astype(np.int64)).tobytes()


def encode_array(rows: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, rows.astype(np.int64))
    return buf.getvalue()


def _pack_min_width(a: np.ndarray) -> tuple[bytes, str]:
    if a.size == 0:
        return b"", "<i1"
    lo, hi = int(a.min()), int(a.max())
    for dt in ("<i1", "<i2", "<i4", "<i8"):
        info = np.iinfo(np.dtype(dt))
        if info.min <= lo and hi <= info.max:
            return np.ascontiguousarray(a.astype(dt)).tobytes(), dt
    return np.ascontiguousarray(a.astype("<i8")).tobytes(), "<i8"


def encode_parquet_like(rows: np.ndarray) -> bytes:
    """Per column: first value + deltas packed at minimal byte width."""
    rows = rows.astype(np.int64)
    n, c = rows.shape
    buf = io.BytesIO()
    buf.write(np.int64(n).tobytes())
    buf.write(np.int64(c).tobytes())
    for j in range(c):
        col = rows[:, j]
        first = col[:1]
        deltas = np.diff(col)
        payload, dt = _pack_min_width(deltas)
        buf.write(first.tobytes())
        buf.write(dt.encode().ljust(4))
        buf.write(np.int64(len(payload)).tobytes())
        buf.write(payload)
    return buf.getvalue()


def decode_parquet_like(data: bytes) -> np.ndarray:
    off = 0
    n = int(np.frombuffer(data, "<i8", 1, off)[0]); off += 8
    c = int(np.frombuffer(data, "<i8", 1, off)[0]); off += 8
    cols = []
    for _ in range(c):
        first = np.frombuffer(data, "<i8", 1, off)[0]; off += 8
        dt = data[off : off + 4].decode().strip(); off += 4
        nbytes = int(np.frombuffer(data, "<i8", 1, off)[0]); off += 8
        deltas = np.frombuffer(data, dt, count=nbytes // np.dtype(dt).itemsize,
                               offset=off).astype(np.int64)
        off += nbytes
        col = np.concatenate([[first], deltas]).cumsum() if n else np.zeros(0, np.int64)
        cols.append(col[:n])
    return np.stack(cols, axis=1)


def encode_parquet_gzip(rows: np.ndarray) -> bytes:
    return zlib.compress(encode_parquet_like(rows), level=6)


def decode_parquet_gzip(data: bytes) -> np.ndarray:
    return decode_parquet_like(zlib.decompress(data))


def encode_rle_like(rows: np.ndarray) -> bytes:
    rows = rows.astype(np.int64)
    n, c = rows.shape
    buf = io.BytesIO()
    buf.write(np.int64(n).tobytes())
    buf.write(np.int64(c).tobytes())
    for j in range(c):
        col = rows[:, j]
        if n:
            change = np.ones(n, bool)
            change[1:] = col[1:] != col[:-1]
            starts = np.flatnonzero(change)
            vals = col[starts]
            counts = np.diff(np.append(starts, n))
        else:
            vals = counts = np.zeros(0, np.int64)
        for arr in (vals, counts):
            payload, dt = _pack_min_width(arr)
            buf.write(np.int64(arr.size).tobytes())
            buf.write(dt.encode().ljust(4))
            buf.write(np.int64(len(payload)).tobytes())
            buf.write(payload)
    return buf.getvalue()


def decode_rle_like(data: bytes) -> np.ndarray:
    off = 0
    n = int(np.frombuffer(data, "<i8", 1, off)[0]); off += 8
    c = int(np.frombuffer(data, "<i8", 1, off)[0]); off += 8
    cols = []
    for _ in range(c):
        parts = []
        for _ in range(2):
            size = int(np.frombuffer(data, "<i8", 1, off)[0]); off += 8
            dt = data[off : off + 4].decode().strip(); off += 4
            nbytes = int(np.frombuffer(data, "<i8", 1, off)[0]); off += 8
            parts.append(
                np.frombuffer(data, dt, count=size, offset=off).astype(np.int64)
            )
            off += nbytes
        vals, counts = parts
        cols.append(np.repeat(vals, counts)[:n])
    return np.stack(cols, axis=1) if cols else np.zeros((n, 0), np.int64)


FORMATS = {
    "raw": (encode_raw, None),
    "array": (encode_array, None),
    "parquet_like": (encode_parquet_like, decode_parquet_like),
    "parquet_gzip": (encode_parquet_gzip, decode_parquet_gzip),
    "rle_like": (encode_rle_like, decode_rle_like),
}
