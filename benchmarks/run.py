"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (one per measured cell) plus a
human-readable narration to stderr-adjacent stdout sections.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only table7,...]
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")


def _emit_json(name: str, rows) -> None:
    """Write an ablation's raw rows to ``BENCH_<name>.json`` at repo root."""
    out = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        f"BENCH_{name}.json",
    )
    with open(out, "w") as fh:
        json.dump(rows, fh, indent=2, default=str)
    print(f"# wrote {out}", flush=True)


def bench_table7(quick: bool) -> None:
    from .table7_compression import run_table7

    print("# Table VII — compression ratio per format", flush=True)
    rows = run_table7(scale=0.25 if quick else 1.0)
    for r in rows:
        _emit(f"table7/{r['op']}/provrc", r["provrc_s"] * 1e6,
              f"bytes={r['provrc']};ratio_pct={r['ratio_provrc_pct']:.5f}")
        _emit(f"table7/{r['op']}/provrc_gzip", r["provrc_gzip_s"] * 1e6,
              f"bytes={r['provrc_gzip']}")
        _emit(f"table7/{r['op']}/parquet_like", r["parquet_like_s"] * 1e6,
              f"bytes={r['parquet_like']}")
        _emit(f"table7/{r['op']}/beats_closest", 0.0,
              f"x{r['beats_closest_x']:.0f}")


def bench_fig7(quick: bool) -> None:
    from .fig7_latency import run_fig7

    print("# Fig 7 — compression latency vs input size", flush=True)
    sizes = (10_000, 100_000) if quick else (10_000, 100_000, 1_000_000)
    for r in run_fig7(sizes):
        for k in r:
            if k.endswith("_s"):
                _emit(f"fig7/{r['kind']}/n{r['n_cells']}/{k[:-2]}",
                      r[k] * 1e6, "")


def bench_fig89(quick: bool) -> None:
    from .fig89_query import run_fig89

    print("# Figs 8/9 — multi-hop query latency vs selectivity", flush=True)
    rows = run_fig89(n_random=2 if quick else 6)
    for r in rows:
        for m, t in r.items():
            if m in ("workflow", "selectivity"):
                continue
            _emit(f"fig89/{r['workflow']}/sel{r['selectivity']}/{m}",
                  t * 1e6, "")


def bench_index(quick: bool) -> None:
    from .fig89_query import run_index_ablation

    print("# Indexed vs dense θ-join (selective queries, large table)",
          flush=True)
    rows = run_index_ablation(n_rows=10_000 if quick else 20_000)
    for r in rows:
        for m in ("dense_s", "index_cold_s", "index_s", "batch_s", "auto_s"):
            _emit(f"index/n{r['n_rows']}/sel{r['selectivity']}/{m[:-2]}",
                  r[m] * 1e6, f"speedup_x={r['speedup']:.1f}")


def bench_shard(quick: bool) -> None:
    from .fig89_query import run_shard_ablation

    print("# Shard ablation — 1/4/8-shard stores on a wide fan-in DAG",
          flush=True)
    rows = run_shard_ablation(
        side=64 if quick else 96, smoke=_SMOKE,
    )
    _emit_json("shard", rows)
    for r in rows:
        _emit(
            f"shard/side{r['side']}/b{r['branches']}/n{r['n_shards']}/plan",
            r["plan_s"] * 1e6,
            f"exchanges={r['exchanges']};boxes={r['boxes_exchanged']}",
        )
        _emit(
            f"shard/side{r['side']}/b{r['branches']}/n{r['n_shards']}/query",
            r["query_s"] * 1e6, "",
        )
        _emit(
            f"shard/side{r['side']}/b{r['branches']}/n{r['n_shards']}/save",
            0.0,
            f"incr_bytes={r['incr_bytes']};full_bytes={r['full_bytes']};"
            f"incr_manifests={r['incr_manifests']}",
        )
        _emit(
            f"shard/side{r['side']}/b{r['branches']}/n{r['n_shards']}/reload",
            0.0,
            f"shards={r['reload_shards']};"
            f"tables={r['reload_tables']}of{r['total_tables']}",
        )


def bench_wal(quick: bool) -> None:
    from .fig89_query import run_wal_ablation

    print("# WAL ingest ablation — sync saves vs group commit, writer "
          "scaling, parallel execution", flush=True)
    rows = run_wal_ablation(smoke=_SMOKE)
    _emit_json("wal", rows)
    for r in rows:
        if r["kind"] == "modes":
            for m in ("sync_save", "wal_sync", "wal_group"):
                _emit(
                    f"wal/modes/n{r['n_entries']}/{m}", r[f"{m}_s"] * 1e6,
                    f"entries_per_s={r['n_entries'] / r[f'{m}_s']:.0f}",
                )
            _emit(f"wal/modes/n{r['n_entries']}/speedup", 0.0,
                  f"group_vs_sync_save_x={r['group_vs_sync_save_x']:.1f}")
        elif r["kind"] == "writers":
            _emit(
                f"wal/writers/{r['n_writers']}", r["ingest_s"] * 1e6,
                f"total={r['total_entries']};"
                f"entries_per_s={r['entries_per_s']:.0f}",
            )
        elif r["kind"] == "exec":
            _emit("wal/exec/serial", r["serial_s"] * 1e6, "")
            _emit("wal/exec/parallel4", r["parallel_s"] * 1e6,
                  f"speedup_x={r['speedup']:.2f}")


def bench_accel(quick: bool) -> None:
    from .fig89_query import run_accel_ablation

    print("# Accelerator batched execution — per-hop join loop vs packed "
          "frontiers, serial vs parallel=4, launch layouts", flush=True)
    rows = run_accel_ablation(smoke=_SMOKE)
    for r in rows:
        if r["kind"] == "exec":
            tag = f"accel/b{r['branches']}/h{r['hops']}/q{r['n_cells']}"
            _emit(f"{tag}/perhop", r["perhop_s"] * 1e6, "")
            _emit(
                f"{tag}/batched", r["batched_s"] * 1e6,
                f"speedup_x={r['batched_speedup']:.2f};"
                f"joins_per_launch={r['joins_per_launch']:.1f}",
            )
            _emit(
                f"{tag}/parallel4", r["parallel_s"] * 1e6,
                f"scaling_x={r['parallel_speedup']:.2f}",
            )
            if _SMOKE:
                # CI gate: packed frontier execution must not lose to the
                # per-hop loop (results are asserted bit-identical inside
                # the ablation itself), and the tile meters must show the
                # block-diagonal schedule skipping cross-product tiles
                assert r["batched_speedup"] >= 1.0, (
                    f"batched execution slower than the per-hop loop: "
                    f"{r['batched_speedup']:.2f}x"
                )
                assert r["batch_tiles_skipped"] > 0, (
                    "batched execution never skipped a cross-product tile "
                    "— block-diagonal accounting is not engaged"
                )
        elif r["kind"] == "layout":
            tag = f"accel/layout/k{r['segments']}/{r['geometry']}"
            _emit(f"{tag}/dense", r["dense_s"] * 1e6,
                  f"cross_tiles={r['cross_tiles']}")
            _emit(
                f"{tag}/blockdiag", r["blockdiag_s"] * 1e6,
                f"speedup_x={r['blockdiag_speedup']:.2f};"
                f"tiles_visited={r['tiles_visited']};"
                f"tiles_skipped={r['tiles_skipped']}",
            )
            if _SMOKE:
                # CI gate (ISSUE 8): on a ≥16-segment frontier the
                # block-diagonal schedule must clearly beat the masked
                # cross-product launch (bit-identity vs the per-segment
                # oracle is asserted inside the ablation itself)
                assert r["blockdiag_speedup"] >= 1.5, (
                    f"block-diagonal launch only "
                    f"{r['blockdiag_speedup']:.2f}x over the masked "
                    f"cross-product on a {r['segments']}-segment frontier"
                )
                assert r["tiles_skipped"] > 0, "no tiles skipped"
    _emit_json("accel", rows)


def bench_views(quick: bool) -> None:
    from .fig89_query import run_views_ablation

    print("# Materialized views + answer cache — hot-route repeats, cold vs "
          "warm, mid-run mutation", flush=True)
    rows = run_views_ablation(smoke=_SMOKE)
    _emit_json("views", rows)
    for r in rows:
        tag = f"views/h{r['hops']}/q{r['n_cells']}"
        _emit(f"{tag}/cold", r["cold_s"] * 1e6, "")
        _emit(
            f"{tag}/warm", r["warm_s"] * 1e6,
            f"view_speedup_x={r['view_speedup']:.1f};"
            f"materialized={r['views_materialized']};"
            f"invalidated={r['views_invalidated']}",
        )
        _emit(
            f"{tag}/cached", r["cache_s"] * 1e6,
            f"cache_speedup_x={r['cache_speedup']:.1f};"
            f"hits={r['cache_hits']}",
        )
        # CI gate: a heat-admitted view must beat the plain planner by a
        # wide margin (bit-identity is asserted inside the ablation)
        assert r["view_speedup"] >= 3.0, (
            f"materialized view too slow vs cold planner: "
            f"{r['view_speedup']:.2f}x (need >= 3x)"
        )


def bench_dag(quick: bool) -> None:
    from .fig89_query import run_dag_ablation

    print("# DAG queries — planner-merged diamond vs naive per-path union",
          flush=True)
    rows = run_dag_ablation(side=64 if quick else 96)
    _emit_json("dag", rows)
    for r in rows:
        _emit(
            f"dag/side{r['side']}/b{r['branches']}/planner",
            r["planner_s"] * 1e6,
            f"speedup_x={r['speedup']:.1f};"
            f"lazy_reload={r['loaded_tables']}of{r['total_tables']}",
        )
        _emit(f"dag/side{r['side']}/b{r['branches']}/naive",
              r["naive_s"] * 1e6, "")


def bench_table9(quick: bool) -> None:
    from .table9_coverage import run_table9

    print("# Table IX — op coverage of compression + reuse", flush=True)
    res = run_table9()
    for cat in ("element", "complex", "total"):
        r = res[cat]
        _emit(f"table9/{cat}", 0.0,
              f"total={r['total']};compressed={r['compressed']};"
              f"dim={r['dim']};gen={r['gen']};errors={r['err']}")


def bench_roofline(quick: bool) -> None:
    from .roofline import run_roofline

    print("# Roofline — per (arch x shape) from dry-run artifacts", flush=True)
    try:
        rows = run_roofline()
    except Exception as e:
        print(f"roofline unavailable (run launch.dryrun first): {e}")
        return
    for r in rows:
        _emit(
            f"roofline/{r['arch']}/{r['shape']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"bound={r['dominant']};roofline_pct={100 * r['roofline_fraction']:.2f};"
            f"useful={r['useful_flop_ratio']:.3f}",
        )


def bench_kernels(quick: bool) -> None:
    """Production hot-pass throughput (numpy path) + kernel validation note."""
    import time

    import numpy as np

    from repro.core.capture import identity_lineage
    from repro.core.provrc import compress

    print("# Kernel-path throughput (CPU production path; Pallas kernels "
          "validated under interpret=True in tests)", flush=True)
    n = 200_000 if quick else 1_000_000
    rel = identity_lineage((n,))
    t0 = time.perf_counter()
    compress(rel, method="vector")
    dt = time.perf_counter() - t0
    _emit("kernels/encode_1m_rows", dt * 1e6, f"rows_per_s={n / dt:.0f}")


BENCHES = {
    "table7": bench_table7,
    "fig7": bench_fig7,
    "fig89": bench_fig89,
    "index": bench_index,
    "dag": bench_dag,
    "views": bench_views,
    "shard": bench_shard,
    "wal": bench_wal,
    "accel": bench_accel,
    "table9": bench_table9,
    "roofline": bench_roofline,
    "kernels": bench_kernels,
}

# set by main(); benches that support an extra-small CI mode consult it
_SMOKE = False


def main() -> None:
    global _SMOKE
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized runs (implies --quick where supported)")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    _SMOKE = args.smoke
    names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for nm in names:
        BENCHES[nm](args.quick or args.smoke)


if __name__ == "__main__":
    main()
