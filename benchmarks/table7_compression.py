"""Table VII analog: storage size per format over the 12-operation workload.

Workload categories match the paper exactly:
  * 6 numpy data-independent ops (Negative, Addition, Aggregate, Repetition,
    Matrix*Vector, Matrix*Matrix),
  * 2 value-dependent numpy ops (Sort — the ProvRC worst case — ImgFilter),
  * 2 explainable-AI captures (Lime / DRISE statistical analogs),
  * 2 relational ops (Group-By, Inner-Join).

Reported: absolute bytes per format + ratio vs Raw, plus the headline
"ProvRC beats the closest baseline by NNNx" numbers.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import capture as C
from repro.core.provrc import compress
from repro.core.relation import LineageRelation

from .baselines import FORMATS

__all__ = ["build_workload", "run_table7"]


def build_workload(scale: float = 1.0) -> dict[str, LineageRelation]:
    """scale=1.0 → 1M-cell arrays for the element-wise ops (paper-sized)."""
    n1m = int(1_000_000 * scale)
    side = int(np.sqrt(n1m))
    rng = np.random.default_rng(0)
    w: dict[str, LineageRelation] = {}
    w["Negative"] = C.identity_lineage((n1m,))
    # Addition has two input relations; paper stores both — concatenate sizes
    w["Addition"] = C.identity_lineage((n1m,))  # per-operand (reported x2)
    w["Aggregate"] = C.reduce_lineage((side, side), (0, 1))
    w["Repetition"] = C.tile_lineage((side, side), (2, 2))
    mv_m = int(1000 * max(scale, 0.05))
    w["Matrix*Vector"] = C.matmul_lineage(mv_m, 1000, 1)[0]
    # the paper's 1000x1000 matmul has 1e9 lineage rows (40 GB raw); we cap
    # the uncompressed materialization at 200^3 = 8M rows — the ProvRC
    # result is 1 row either way, so only the Raw column scales
    mm = max(64, int(200 * min(1.0, max(scale, 0.03)) ** (1 / 3)))
    w["Matrix*Matrix"] = C.matmul_lineage(mm, mm, mm)[0]
    w["Sort"] = C.sort_lineage(rng.random(max(1000, n1m // 4)))
    w["ImgFilter"] = C.conv2d_lineage(
        max(64, side // 2), max(64, side // 2), 3, 3
    )
    w["Lime"] = C.xai_bipartite_lineage((416, 416), n_out=1, n_patches=40,
                                        patch=32, seed=1)
    w["DRISE"] = C.xai_bipartite_lineage((416, 416), n_out=5, n_patches=12,
                                         patch=24, seed=2)
    n_rows = max(2000, n1m // 20)
    keys = rng.integers(0, 50, n_rows)
    w["GroupBy"] = C.group_by_lineage(keys, 8)
    lk = rng.integers(0, n_rows // 2, n_rows // 2)
    rk = rng.integers(0, n_rows // 2, n_rows // 2)
    w["InnerJoin"] = C.inner_join_lineage(lk, rk, 4, 4)[0]
    return w


def run_table7(scale: float = 1.0, verbose: bool = True) -> list[dict]:
    rows = []
    for name, rel in build_workload(scale).items():
        raw_rows = rel.rows()
        rec = {"op": name, "n_rows": rel.n_rows}
        for fmt, (enc, _dec) in FORMATS.items():
            t0 = time.perf_counter()
            blob = enc(raw_rows)
            rec[fmt] = len(blob)
            rec[fmt + "_s"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        table = compress(rel, method="vector")
        rec["provrc"] = table.nbytes()
        rec["provrc_s"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        rec["provrc_gzip"] = table.nbytes_gzip()
        rec["provrc_gzip_s"] = rec["provrc_s"] + time.perf_counter() - t0
        rec["ratio_provrc_pct"] = 100.0 * rec["provrc"] / rec["raw"]
        best_baseline = min(
            rec[f] for f in ("parquet_like", "parquet_gzip", "rle_like")
        )
        rec["beats_closest_x"] = best_baseline / max(
            min(rec["provrc"], rec["provrc_gzip"]), 1
        )
        rows.append(rec)
        if verbose:
            print(
                f"  {name:14s} raw={rec['raw']/1e6:9.2f}MB "
                f"parquet={rec['parquet_like']/1e6:8.2f}MB "
                f"pq-gz={rec['parquet_gzip']/1e6:8.2f}MB "
                f"rle={rec['rle_like']/1e6:8.2f}MB "
                f"provrc={rec['provrc']/1e3:9.2f}KB "
                f"({rec['ratio_provrc_pct']:.4f}%)  "
                f"x{rec['beats_closest_x']:.0f} vs best baseline",
                flush=True,
            )
    return rows
