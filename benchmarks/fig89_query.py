"""Figs 8/9 analog: multi-hop forward-query latency vs selectivity.

Workflows: image-like (5 steps), relational-like (5 steps), ResNet-block
(7 steps), and randomly generated numpy pipelines (5 and 10 ops).

Methods:
  * ``dslog``         — in-situ θ-joins over ProvRC tables (this paper),
  * ``dslog_nomerge`` — ablation without the between-hop row merge,
  * ``raw``           — hash-join over uncompressed rows,
  * ``parquet_like``  — decode the columnar blobs, then hash-join,
  * ``rle_like``      — decode RLE blobs, then hash-join,
  * ``array``         — vectorized equality scan (np.isin) per hop.

``run_dag_ablation`` extends the figure beyond the paper: a diamond
pipeline (fan-out, fan-in, shared heavy tail) queried through the
cost-based planner (one plan over the DAG, frontiers merged at the fan-in
array) vs the naive per-path union (one path query per simple path, results
unioned), plus the lazy-persistence measurement: reloading the catalog and
counting how many table blobs one query actually deserializes.
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import capture as C
from repro.core.catalog import DSLog
from repro.core.provrc import compress
from repro.core.query import QueryBox, merge_boxes, theta_join, theta_join_batch
from repro.core.relation import LineageRelation

from .baselines import (
    decode_parquet_like,
    decode_rle_like,
    encode_parquet_like,
    encode_rle_like,
)

__all__ = [
    "build_workflows",
    "run_fig89",
    "run_index_ablation",
    "run_dag_ablation",
    "run_shard_ablation",
    "run_wal_ablation",
    "run_accel_ablation",
]


# --------------------------------------------------------------------------- #
# Workflow construction
# --------------------------------------------------------------------------- #
def _image_workflow(side=256):
    h = side
    rels = [
        C.slice_lineage((h, h), (0, 0), (h, h), (2, 2)),
        C.identity_lineage((h // 2, h // 2)),
        C.transpose_lineage((h // 2, h // 2), (1, 0)),
        C.flip_lineage((h // 2, h // 2), 1),
        C.reduce_lineage((h // 2, h // 2), 1),
    ]
    return "image", rels


def _relational_workflow(n=20_000):
    rng = np.random.default_rng(3)
    lk = rng.integers(0, n // 2, n)
    rk = rng.integers(0, n // 2, n // 2)
    join_l, _ = C.inner_join_lineage(lk, rk, 3, 2)
    n_out = join_l.out_shape[0]
    rels = [
        join_l,
        C.identity_lineage(join_l.out_shape),            # filter NaN (pass)
        C.reduce_lineage(join_l.out_shape, 1),           # add two columns
        C.identity_lineage((n_out,)),                    # one-hot core dep
        C.identity_lineage((n_out,)),                    # add constant
    ]
    return "relational", rels


def _resnet_workflow(side=128):
    s = side
    rels = [
        C.conv2d_lineage(s, s, 3, 3),
        C.identity_lineage((s - 2, s - 2)),
        C.conv2d_lineage(s - 2, s - 2, 3, 3),
        C.identity_lineage((s - 4, s - 4)),
        C.conv2d_lineage(s - 4, s - 4, 3, 3),
        C.identity_lineage((s - 6, s - 6)),
        C.reduce_lineage((s - 6, s - 6), (0, 1)),
    ]
    return "resnet", rels


_RANDOM_OPS = [
    lambda shape, rng: ("neg", C.identity_lineage(shape)),
    lambda shape, rng: ("exp", C.identity_lineage(shape)),
    lambda shape, rng: ("clip", C.identity_lineage(shape)),
    lambda shape, rng: ("flip", C.flip_lineage(shape, 0)),
    lambda shape, rng: ("roll", C.roll_lineage(shape, int(rng.integers(1, 5)), 0)),
    lambda shape, rng: (
        "transpose",
        C.transpose_lineage(shape, tuple(reversed(range(len(shape))))),
    ),
    lambda shape, rng: (
        "reshape",
        C.reshape_lineage(shape, (int(np.prod(shape)),)),
    ),
    lambda shape, rng: ("sort", C.sort_lineage(rng.random(shape), axis=-1)),
]


def _random_workflow(n_ops: int, seed: int, n_cells: int = 40_000):
    rng = np.random.default_rng(seed)
    side = int(np.sqrt(n_cells))
    shape = (side, side)
    rels = []
    for _ in range(n_ops):
        name, rel = _RANDOM_OPS[int(rng.integers(0, len(_RANDOM_OPS)))](shape, rng)
        rels.append(rel)
        shape = rel.out_shape
    return f"random{n_ops}_s{seed}", rels


def build_workflows(n_random: int = 6):
    flows = [_image_workflow(), _relational_workflow(), _resnet_workflow()]
    for seed in range(n_random):
        flows.append(_random_workflow(5, seed))
    for seed in range(n_random // 2):
        flows.append(_random_workflow(10, 100 + seed))
    return flows


# --------------------------------------------------------------------------- #
# Query engines
# --------------------------------------------------------------------------- #
def _ravel(idx, shape):
    return np.ravel_multi_index(idx.T, shape)


def _forward_join_rows(rels, query_cells):
    """Hash-join forward propagation over uncompressed row matrices."""
    cur = _ravel(query_cells, rels[0].in_shape)
    for rel in rels:
        in_r = _ravel(rel.in_idx, rel.in_shape)
        out_r = _ravel(rel.out_idx, rel.out_shape)
        mask = np.isin(in_r, cur)
        cur = np.unique(out_r[mask])
    return cur


def _forward_array_scan(rels, query_cells):
    """Vectorized equality scan per query cell (the Array baseline)."""
    cur = _ravel(query_cells, rels[0].in_shape)
    for rel in rels:
        in_r = _ravel(rel.in_idx, rel.in_shape)
        out_r = _ravel(rel.out_idx, rel.out_shape)
        hits = np.zeros(in_r.shape[0], bool)
        for batch_start in range(0, cur.size, 1000):
            q = cur[batch_start : batch_start + 1000]
            hits |= (in_r[:, None] == q[None, :]).any(axis=1)
        cur = np.unique(out_r[hits])
    return cur


def run_fig89(selectivities=(0.001, 0.01, 0.1), n_random: int = 6,
              verbose: bool = True):
    rows = []
    for wf_name, rels in build_workflows(n_random):
        # ingest once per workflow
        log = DSLog(store_forward=True)
        names = [f"{wf_name}_a0"]
        log.define_array(names[0], rels[0].in_shape)
        encoded_pq, encoded_rle, raw_blobs = [], [], []
        for k, rel in enumerate(rels):
            names.append(f"{wf_name}_a{k + 1}")
            log.define_array(names[k + 1], rel.out_shape)
            log.register_operation(
                f"{wf_name}_op{k}", [names[k]], [names[k + 1]],
                capture=lambda r=rel: {(0, 0): r}, reuse=False,
            )
            raw = rel.rows()
            raw_blobs.append((raw, rel))
            encoded_pq.append(encode_parquet_like(raw))
            encoded_rle.append(encode_rle_like(raw))

        in_shape = rels[0].in_shape
        n_cells = int(np.prod(in_shape))
        for sel in selectivities:
            k = max(1, int(n_cells * sel))
            flat = np.arange(n_cells)[: k]
            cells = np.stack(np.unravel_index(flat, in_shape), axis=1)

            timings = {}
            t0 = time.perf_counter()
            res_dslog = log.prov_query(names, cells)
            timings["dslog"] = time.perf_counter() - t0

            t0 = time.perf_counter()
            log.prov_query(names, cells, merge=False)
            timings["dslog_nomerge"] = time.perf_counter() - t0

            t0 = time.perf_counter()
            want = _forward_join_rows(rels, cells)
            timings["raw"] = time.perf_counter() - t0

            t0 = time.perf_counter()
            decoded = [decode_parquet_like(b) for b in encoded_pq]
            _forward_join_rows(rels, cells)
            timings["parquet_like"] = time.perf_counter() - t0

            t0 = time.perf_counter()
            decoded = [decode_rle_like(b) for b in encoded_rle]
            _forward_join_rows(rels, cells)
            timings["rle_like"] = time.perf_counter() - t0

            if n_cells <= 70_000 and k <= 5000:
                t0 = time.perf_counter()
                _forward_array_scan(rels, cells)
                timings["array"] = time.perf_counter() - t0

            # correctness: in-situ result == oracle
            got = {
                int(np.ravel_multi_index(c, rels[-1].out_shape))
                for c in res_dslog.cells()
            }
            assert got == set(want.tolist()), f"{wf_name} sel={sel} mismatch"

            rec = {"workflow": wf_name, "selectivity": sel, **timings}
            rows.append(rec)
            if verbose:
                print(
                    f"  {wf_name:16s} sel={sel:6.3f} "
                    + " ".join(f"{m}={t*1e3:8.2f}ms" for m, t in timings.items()),
                    flush=True,
                )
    return rows


# --------------------------------------------------------------------------- #
# Indexed vs dense θ-join ablation (the query-engine routing heuristic)
# --------------------------------------------------------------------------- #
def _scatter_table(n_rows: int, seed: int = 0):
    """A poorly-compressible (near one row per pair) table: the worst case
    for the dense all-pairs join and the target case for the index."""
    rng = np.random.default_rng(seed)
    side = n_rows  # ~unique out cells, so compression cannot merge rows
    o = np.stack([np.arange(n_rows), rng.integers(0, 64, n_rows)], axis=1)
    i = np.stack([rng.permutation(n_rows)], axis=1)
    rel = LineageRelation((side, 64), (side,), o, i).canonical()
    return compress(rel)


# --------------------------------------------------------------------------- #
# DAG-query ablation: planner-merged execution vs naive per-path union
# --------------------------------------------------------------------------- #
def _build_diamond(side: int, branches: int, root: str | None = None, log=None):
    """src fans out to ``branches`` rolled copies, they fan back into one
    array, and a conv tail (the heavy tables) runs to the output:

        src → m0..m{B-1} → mid → t → out

    The tail is shared by every simple path, so the naive per-path union
    re-executes its expensive hops once per branch; the planner walks it
    once with the branch frontiers merged at ``mid``.  Pass ``log`` to
    build the same wide fan-in DAG into a different store (the shard
    ablation feeds ``ShardedDSLog`` instances through here).
    """
    if log is None:
        log = DSLog(root=root, store_forward=True)
    shape = (side, side)
    log.define_array("src", shape)
    mids = [f"m{b}" for b in range(branches)]
    for m in mids:
        log.define_array(m, shape)
    log.define_array("mid", shape)
    log.register_operation(
        "fanout", ["src"], mids,
        capture=lambda: {
            (b, 0): C.roll_lineage(shape, b + 1, 0) for b in range(branches)
        },
        reuse=False,
    )
    log.register_operation(
        "combine", mids, ["mid"],
        capture=lambda: {
            (0, b): C.identity_lineage(shape) for b in range(branches)
        },
        reuse=False,
    )
    log.define_array("t", (side - 2, side - 2))
    log.define_array("out", (side - 4, side - 4))
    log.register_operation(
        "conv_a", ["mid"], ["t"],
        capture=lambda: {(0, 0): C.conv2d_lineage(side, side, 3, 3)},
        reuse=False,
    )
    log.register_operation(
        "conv_b", ["t"], ["out"],
        capture=lambda: {(0, 0): C.conv2d_lineage(side - 2, side - 2, 3, 3)},
        reuse=False,
    )
    return log


def run_dag_ablation(
    side: int = 96,
    branches: int = 4,
    n_queries: int = 8,
    repeats: int = 3,
    verbose: bool = True,
) -> list[dict]:
    """Planner-ordered, frontier-merged DAG execution vs per-path union,
    plus the lazy-reload blob count.

    Returns one record with ``planner_s``, ``naive_s``, the speedup, the
    number of simple paths, and ``loaded/total`` table-blob counts for a
    reloaded catalog answering one tail query.
    """
    log = _build_diamond(side, branches)
    rng = np.random.default_rng(7)
    picks = rng.choice(side * side, size=n_queries * 4, replace=False)
    cells = np.stack(np.unravel_index(picks, (side, side)), axis=1)
    queries = [cells[k * 4 : (k + 1) * 4] for k in range(n_queries)]
    paths = log.graph.simple_paths("src", "out")
    assert len(paths) == branches

    def time_of(fn, n=repeats):
        best = float("inf")
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    def run_planner():
        return log.prov_query_batch("src", "out", queries)

    def run_naive():
        per_path = [log.prov_query_batch(p, queries) for p in paths]
        out = []
        for k in range(n_queries):
            lo = np.concatenate([r[k].lo for r in per_path])
            hi = np.concatenate([r[k].hi for r in per_path])
            out.append(merge_boxes(QueryBox(per_path[0][k].shape, lo, hi)))
        return out

    planner_res = run_planner()
    naive_res = run_naive()
    for p, n in zip(planner_res, naive_res):
        assert p.cell_set() == n.cell_set(), "planner != per-path union"
    planner_s = time_of(run_planner)
    naive_s = time_of(run_naive)

    # lazy persistence: a reloaded catalog deserializes only what one tail
    # query touches (the two conv hops), never the branch tables
    with tempfile.TemporaryDirectory() as d:
        log_disk = _build_diamond(side, branches, root=d)
        log_disk.save()
        reloaded = DSLog.load(d)
        reloaded.prov_query("out", "mid", cells[:2])
        loaded = reloaded.io_stats["tables_loaded"]
        total = sum(
            1 + e.has_forward for e in reloaded.lineage.values()
        )
        assert loaded < total, "lazy reload touched every blob"

    rec = {
        "side": side,
        "branches": branches,
        "n_paths": len(paths),
        "planner_s": planner_s,
        "naive_s": naive_s,
        "speedup": naive_s / planner_s if planner_s > 0 else float("inf"),
        "loaded_tables": loaded,
        "total_tables": total,
    }
    if verbose:
        print(
            f"  dag_ablation side={side} branches={branches} "
            f"planner={planner_s*1e3:8.2f}ms naive={naive_s*1e3:8.2f}ms "
            f"speedup={rec['speedup']:4.1f}x "
            f"lazy_reload={loaded}/{total} blobs",
            flush=True,
        )
    return [rec]


# --------------------------------------------------------------------------- #
# Shard ablation: 1 vs 4 vs 8 shards on the wide fan-in DAG
# --------------------------------------------------------------------------- #
def run_shard_ablation(
    side: int = 96,
    branches: int = 8,
    shard_counts=(1, 4, 8),
    n_queries: int = 8,
    repeats: int = 3,
    smoke: bool = False,
    verbose: bool = True,
) -> list[dict]:
    """Plan/query latency, incremental-save bytes, and partial-reload blob
    counts for the same wide fan-in DAG stored under 1/4/8 shards.

    Per shard count the record carries:

    * ``plan_s`` / ``query_s`` — cross-shard planning and batched execution
      latency (results asserted equal to the single-store oracle),
    * ``exchanges`` / ``boxes_exchanged`` — boundary traffic of one batch,
    * ``incr_bytes`` / ``full_bytes`` — bytes written by an incremental
      ``save()`` after touching ONE shard vs the initial full save (only
      dirty shard manifests rewrite, so incr shrinks as N grows),
    * ``reload_shards`` / ``reload_tables`` — how many shard manifests and
      table blobs one tail query forces a freshly loaded store to read.

    ``smoke=True`` shrinks everything for CI.
    """
    from repro.core.shard import ShardedDSLog

    if smoke:
        side, branches, n_queries, repeats = 32, 4, 4, 1
        shard_counts = tuple(n for n in shard_counts if n <= 4) or (1, 2)

    oracle = _build_diamond(side, branches)
    rng = np.random.default_rng(11)
    picks = rng.choice(side * side, size=n_queries * 4, replace=False)
    cells = np.stack(np.unravel_index(picks, (side, side)), axis=1)
    queries = [cells[k * 4 : (k + 1) * 4] for k in range(n_queries)]
    want = [r.cell_set() for r in oracle.prov_query_batch("src", "out", queries)]

    def time_of(fn, n=repeats):
        best = float("inf")
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    rows = []
    for n_shards in shard_counts:
        log = _build_diamond(
            side, branches, log=ShardedDSLog(n_shards=n_shards, store_forward=True)
        )
        got = log.prov_query_batch("src", "out", queries)
        assert [r.cell_set() for r in got] == want, f"{n_shards}-shard mismatch"
        boxes_one_batch = log.io_stats["boxes_exchanged"]  # one execution's
        plan = log.planner.plan("src", ["out"])
        plan_s = time_of(lambda: log.planner.plan("src", ["out"]))
        query_s = time_of(lambda: log.prov_query_batch("src", "out", queries))

        with tempfile.TemporaryDirectory() as d:
            disk = _build_diamond(
                side, branches, log=ShardedDSLog(n_shards=n_shards, root=d)
            )
            disk.save()
            full_bytes = disk.io_stats["bytes_written"]
            total_tables = sum(
                1 + e.has_forward for e in disk.lineage.values()
            )
            before = dict(disk.io_stats)
            # touch exactly one shard: a new entry hanging off the output
            out_shape = disk.arrays["out"].shape
            disk.add_lineage("out", "post", C.identity_lineage(out_shape))
            disk.save()
            after = disk.io_stats
            incr_bytes = after["bytes_written"] - before["bytes_written"]
            incr_manifests = (
                after["manifests_written"] - before["manifests_written"]
            )
            reloaded = ShardedDSLog.load(d)
            reloaded.prov_query("out", "mid", cells[:2])
            reload_shards = reloaded.io_stats["shards_loaded"]
            reload_tables = reloaded.io_stats["tables_loaded"]
            assert reload_tables < total_tables, "partial reload touched all blobs"

        rec = {
            "side": side,
            "branches": branches,
            "n_shards": n_shards,
            "plan_s": plan_s,
            "query_s": query_s,
            "exchanges": len(plan.exchanges),
            "boxes_exchanged": boxes_one_batch,
            "full_bytes": full_bytes,
            "incr_bytes": incr_bytes,
            "incr_manifests": incr_manifests,
            "reload_shards": reload_shards,
            "reload_tables": reload_tables,
            "total_tables": total_tables,
        }
        rows.append(rec)
        if verbose:
            print(
                f"  shard_ablation n={n_shards} plan={plan_s*1e3:7.2f}ms "
                f"query={query_s*1e3:8.2f}ms exch={rec['exchanges']:2d} "
                f"incr_save={incr_bytes}B/{incr_manifests}man "
                f"(full={full_bytes}B) "
                f"reload={reload_shards}sh/{reload_tables}of"
                f"{total_tables}tables",
                flush=True,
            )
    return rows


# --------------------------------------------------------------------------- #
# WAL ingest ablation: synchronous saves vs group commit, writer scaling,
# parallel vs serial sub-plan execution
# --------------------------------------------------------------------------- #
_WAL_WORKER = """
import os, sys, time
import numpy as np
from repro.core.shard import ShardedDSLog
from repro.core.capture import identity_lineage

root, writer, n, side = sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4])
log = ShardedDSLog.open(root, exclusive=False)
go = os.path.join(root, "go")
deadline = time.time() + 60
while not os.path.exists(go):
    if time.time() > deadline:
        raise SystemExit("rendezvous timed out")
    time.sleep(0.001)
rel = identity_lineage((side, side))
t0 = time.perf_counter()
prev = f"w{writer}c0"
for k in range(1, n + 1):
    log.add_lineage(prev, f"w{writer}c{k}", rel)
    prev = f"w{writer}c{k}"
log.commit()  # durability barrier ends the measured ingest window
dt = time.perf_counter() - t0
with open(os.path.join(root, f"elapsed_{writer}.txt"), "w") as f:
    f.write(repr(dt))
log.close()
"""


def _spawn_writers(root: str, n_writers: int, per_writer: int, side: int):
    import subprocess
    import sys as _sys

    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    procs = [
        subprocess.Popen(
            [_sys.executable, "-c", _WAL_WORKER, root, str(i),
             str(per_writer), str(side)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )
        for i in range(n_writers)
    ]
    time.sleep(0.3)  # both sides of the rendezvous are polling now
    t0 = time.perf_counter()
    with open(os.path.join(root, "go"), "w") as f:
        f.write("go")
    for p in procs:
        _, err = p.communicate(timeout=600)
        if p.returncode != 0:
            raise RuntimeError(err.decode())
    wall = time.perf_counter() - t0
    # the measured window is each writer's ingest (go -> commit); the
    # slowest writer bounds the aggregate throughput
    ingest = max(
        float(open(os.path.join(root, f"elapsed_{i}.txt")).read())
        for i in range(n_writers)
    )
    return wall, ingest


def run_wal_ablation(
    n_entries: int = 200,
    writer_counts=(1, 2, 4),
    side: int = 32,
    smoke: bool = False,
    verbose: bool = True,
) -> list[dict]:
    """Ingest durability ablation (ISSUE 4 acceptance measurement).

    * **Single-writer modes** — the same ``n_entries``-long chain ingested
      with (a) a synchronous ``save()`` after every entry (the only
      durability the store had before the WAL), (b) WAL with per-record
      fsync, (c) WAL with group commit.  Group commit must beat per-entry
      synchronous saves on entries/sec.
    * **Writer scaling** — the same *total* entry count split across
      1/2/4 concurrent writer processes ingesting into disjoint shards
      under writer-mode leases.
    * **Query execution** — serial vs ``parallel=4`` batched execution of
      a wide fan-in DAG on a 4-shard store (non-dependent sub-plans run on
      the thread pool).
    """
    import tempfile as _tmp

    from repro.core.catalog import DSLog
    from repro.core.shard import AffinityShardPolicy, ShardedDSLog

    if smoke:
        n_entries, writer_counts, side = 30, (1, 2), 16
    rows: list[dict] = []
    rel = C.identity_lineage((side, side))

    def ingest_chain(log, n, commit_every=None):
        prev = "c0"
        for k in range(1, n + 1):
            log.add_lineage(prev, f"c{k}", rel)
            if commit_every is not None and k % commit_every == 0:
                log.save()
            prev = f"c{k}"

    # -- single-writer durability modes --------------------------------- #
    modes = {}
    with _tmp.TemporaryDirectory() as d:
        log = DSLog(root=d, store_forward=False)
        t0 = time.perf_counter()
        ingest_chain(log, n_entries, commit_every=1)  # save per entry
        modes["sync_save"] = time.perf_counter() - t0
    for mode in ("sync", "group"):
        with _tmp.TemporaryDirectory() as d:
            log = DSLog.open(d, durability=mode, store_forward=False)
            t0 = time.perf_counter()
            ingest_chain(log, n_entries)
            log.commit()  # durability barrier: fair comparison point
            modes[f"wal_{mode}"] = time.perf_counter() - t0
            log.close()
    rec = {
        "kind": "modes",
        "n_entries": n_entries,
        **{f"{m}_s": s for m, s in modes.items()},
        "group_vs_sync_save_x": modes["sync_save"] / modes["wal_group"],
    }
    rows.append(rec)
    if verbose:
        print(
            f"  wal_ablation n={n_entries} "
            + " ".join(
                f"{m}={n_entries / s:8.0f}ent/s" for m, s in modes.items()
            )
            + f" group_commit_speedup={rec['group_vs_sync_save_x']:.1f}x",
            flush=True,
        )
    assert rec["group_vs_sync_save_x"] > 1.0, (
        "group commit must beat per-entry synchronous saves"
    )

    # -- concurrent writer scaling (processes, disjoint shards) ---------- #
    for w in writer_counts:
        per_writer = max(1, n_entries // w)
        with _tmp.TemporaryDirectory() as d:
            pins = {
                f"w{i}c{k}": i
                for i in range(w)
                for k in range(per_writer + 1)
            }
            with ShardedDSLog.open(
                d, max(w, 1), policy=AffinityShardPolicy(max(w, 1), pins)
            ):
                pass
            wall, ingest = _spawn_writers(d, w, per_writer, side)
            total = per_writer * w
            with ShardedDSLog.open(d) as folded:  # fold + sanity check
                assert len(folded._lid_shard) == total
        rec = {
            "kind": "writers",
            "n_writers": w,
            "total_entries": total,
            "wall_s": wall,
            "ingest_s": ingest,
            "entries_per_s": total / ingest,
        }
        rows.append(rec)
        if verbose:
            print(
                f"  wal_ablation writers={w} total={total} "
                f"ingest={ingest * 1e3:8.1f}ms (wall={wall * 1e3:7.1f}ms) "
                f"throughput={rec['entries_per_s']:8.0f}ent/s",
                flush=True,
            )

    # -- parallel vs serial sub-plan execution --------------------------- #
    qside = max(side, 48) if not smoke else 32
    log = _build_diamond(
        qside, 8 if not smoke else 4,
        log=ShardedDSLog(n_shards=4, store_forward=True),
    )
    rng = np.random.default_rng(5)
    picks = rng.choice(qside * qside, size=32, replace=False)
    cells = np.stack(np.unravel_index(picks, (qside, qside)), axis=1)
    queries = [cells[k * 4 : (k + 1) * 4] for k in range(8)]
    serial_res = log.prov_query_batch("src", "out", queries)
    par_res = log.prov_query_batch("src", "out", queries, parallel=4)
    assert [r.cell_set() for r in serial_res] == [
        r.cell_set() for r in par_res
    ]

    def time_of(fn, n=3):
        best = float("inf")
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    serial_s = time_of(
        lambda: log.prov_query_batch("src", "out", queries)
    )
    par_s = time_of(
        lambda: log.prov_query_batch("src", "out", queries, parallel=4)
    )
    rec = {
        "kind": "exec",
        "serial_s": serial_s,
        "parallel_s": par_s,
        "speedup": serial_s / par_s if par_s > 0 else float("inf"),
    }
    rows.append(rec)
    if verbose:
        print(
            f"  wal_ablation exec serial={serial_s * 1e3:8.2f}ms "
            f"parallel4={par_s * 1e3:8.2f}ms "
            f"speedup={rec['speedup']:.2f}x",
            flush=True,
        )
    return rows


def run_index_ablation(
    n_rows: int = 20_000,
    selectivities=(0.0005, 0.001, 0.01),
    n_queries: int = 16,
    repeats: int = 3,
    verbose: bool = True,
):
    """Time ``theta_join`` dense vs indexed (and the batched API) on one
    large compressed table, at selectivities ≤1% of the key space.

    Returns one record per selectivity with ``dense_s``, ``index_s`` (index
    prebuilt — the amortized regime), ``index_cold_s`` (includes one index
    build), ``batch_s``, and the dense/indexed speedup.
    """
    table = _scatter_table(n_rows)
    key_side = table.key_shape[0]
    rng = np.random.default_rng(1)
    rows = []
    for sel in selectivities:
        k = max(1, int(key_side * sel))
        queries = []
        for _ in range(n_queries):
            # k scattered key rows (≤ sel of the key space): stays k boxes
            # after merging, so the dense join pays k × n_rows per query
            picks = np.sort(rng.choice(key_side, size=k, replace=False))
            lo = np.stack([picks, np.zeros(k, np.int64)], axis=1)
            hi = np.stack([picks, np.full(k, 63, np.int64)], axis=1)
            queries.append(QueryBox(table.key_shape, lo, hi))

        def time_of(fn, n=repeats):
            best = float("inf")
            for _ in range(n):
                t0 = time.perf_counter()
                fn()
                best = min(best, time.perf_counter() - t0)
            return best

        dense_s = time_of(
            lambda: [theta_join(q, table, path="dense") for q in queries]
        )
        table.invalidate_index()
        index_cold_s = time_of(
            lambda: [theta_join(q, table, path="index") for q in queries], n=1
        )
        index_s = time_of(
            lambda: [theta_join(q, table, path="index") for q in queries]
        )
        batch_s = time_of(lambda: theta_join_batch(queries, table, path="index"))
        # routing sanity: auto must pick the fast side for selective queries
        auto_s = time_of(lambda: [theta_join(q, table) for q in queries])
        for q in queries[:2]:
            assert (
                theta_join(q, table, path="index").cell_set()
                == theta_join(q, table, path="dense").cell_set()
            )
        rec = {
            "n_rows": table.n_rows,
            "selectivity": sel,
            "dense_s": dense_s,
            "index_cold_s": index_cold_s,
            "index_s": index_s,
            "batch_s": batch_s,
            "auto_s": auto_s,
            "speedup": dense_s / index_s if index_s > 0 else float("inf"),
        }
        rows.append(rec)
        if verbose:
            print(
                f"  index_ablation n_rows={table.n_rows} sel={sel:7.4f} "
                f"dense={dense_s*1e3:8.2f}ms index={index_s*1e3:8.2f}ms "
                f"batch={batch_s*1e3:8.2f}ms auto={auto_s*1e3:8.2f}ms "
                f"speedup={rec['speedup']:5.1f}x",
                flush=True,
            )
    return rows


# --------------------------------------------------------------------------- #
# Batched accelerator execution: per-hop join loop vs packed frontiers
# --------------------------------------------------------------------------- #
def _permutation_lineage(shape, rng) -> LineageRelation:
    """A random bijection between two same-shape arrays.

    Poorly compressible on purpose (≈ one table row per cell): each hop of
    the accel DAG is then a *small dense* θ-join — under
    ``INDEX_MIN_ROWS`` the router always evaluates the all-pairs mask, the
    exact per-hop inner loop batched frontier execution packs.
    """
    n = int(np.prod(shape))
    cells = np.stack(
        np.unravel_index(np.arange(n), shape), axis=1
    ).astype(np.int64)
    perm = rng.permutation(n)
    return LineageRelation(shape, shape, cells, cells[perm]).canonical()


def _build_accel_dag(shape, branches: int, hops: int, seed: int = 0):
    """``src`` fans out to ``branches`` independent permutation chains of
    ``hops`` tables each, all fanning back into ``out``:

        src → b{b}h0 → … → b{b}h{H-1} → out      (for each branch b)

    Every hop's table is a fresh random bijection, so each plan wave holds
    ``branches`` small dense joins — the workload the batched executor
    packs into one blocked evaluation and the per-hop loop dispatches one
    at a time.
    """
    rng = np.random.default_rng(seed)
    log = DSLog(store_forward=True)
    log.define_array("src", shape)
    log.define_array("out", shape)
    for b in range(branches):
        prev = "src"
        for h in range(hops):
            name = f"b{b}h{h}"
            log.define_array(name, shape)
            log.add_lineage(prev, name, _permutation_lineage(shape, rng))
            prev = name
        log.add_lineage(prev, "out", _permutation_lineage(shape, rng))
    return log


def _ragged_frontier(k: int, row_lo: int, row_hi: int, n_attrs: int,
                     seed: int = 0):
    """``k`` independent interval-overlap joins with ragged row counts.

    The segment shapes a multi-branch plan wave hands the batched
    executor: every segment a different (nq, nr), boxes overlapping
    sparsely so the pair lists are non-trivial on both layouts.
    """
    rng = np.random.default_rng(seed)
    segs = []
    for _ in range(k):
        nq = int(rng.integers(row_lo, row_hi))
        nr = int(rng.integers(row_lo, row_hi))
        q_lo = rng.integers(0, 512, size=(nq, n_attrs)).astype(np.int64)
        r_lo = rng.integers(0, 512, size=(nr, n_attrs)).astype(np.int64)
        q_hi = q_lo + rng.integers(1, 48, size=(nq, n_attrs))
        r_hi = r_lo + rng.integers(1, 48, size=(nr, n_attrs))
        segs.append((q_lo, q_hi, r_lo, r_hi))
    return segs


def run_accel_ablation(
    shape=(32, 31),
    branches: int = 20,
    hops: int = 2,
    n_cells: int = 330,
    repeats: int = 9,
    smoke: bool = False,
    verbose: bool = True,
) -> list[dict]:
    """Batched frontier execution vs the per-hop join loop (ISSUE 5 + 8).

    The DAG's hops are small dense joins (permutation tables under the
    index threshold) — the regime where dispatching one tiny mask
    evaluation per hop loses to packing a whole plan frontier into one
    blocked int32 evaluation.  Measures, over the same query batch
    (median of ``repeats`` runs — this box's timing noise is large):

    * ``perhop_s``   — serial per-hop loop (``batched=False``),
    * ``batched_s``  — serial packed frontier execution,
    * ``parallel_s`` — packed execution with ``parallel=4`` (the wave's
      mask evaluations split across workers, clamped to real cores; the
      twin's numpy inner loops release the GIL, so they overlap on CPU),

    asserts all three produce bit-identical results, and reports the
    io_stats batching meters (including the block-diagonal tile meters).

    A second record (``kind="layout"``, ISSUE 8) measures the kernel
    launch layouts head-to-head on a large ragged frontier: one masked
    cross-product launch vs the block-diagonal tile schedule, same
    segments, pair lists asserted bit-identical to each other and to a
    per-segment dense oracle.
    """
    if smoke:
        shape, branches, hops, n_cells, repeats = (24, 22), 10, 2, 192, 5
    log = _build_accel_dag(shape, branches, hops)
    # this ablation measures the join *engines* — disable the view/answer
    # cache layer, which would otherwise serve every repeat after the first
    # warmup query and time nothing but cache lookups
    log.views.enabled = False
    rng = np.random.default_rng(7)
    n = int(np.prod(shape))
    flat = rng.choice(n, size=n_cells, replace=False)
    cells = np.stack(np.unravel_index(flat, shape), axis=1)

    def run(label, **kw):
        res = log.prov_query("src", "out", cells, **kw)
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = log.prov_query("src", "out", cells, **kw)
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2], res

    perhop_s, want = run("perhop", batched=False)
    base = dict(log.io_stats)
    batched_s, got_b = run("batched", batched=True)
    # run() issues one warmup query before the `repeats` timed ones, and
    # every call dispatches the same launches
    queries_run = repeats + 1
    launches = log.io_stats["kernel_launches"] - base["kernel_launches"]
    packed = log.io_stats["joins_packed"] - base["joins_packed"]
    parallel_s, got_p = run("parallel", batched=True, parallel=4)
    for got in (got_b, got_p):
        assert got.lo.tobytes() == want.lo.tobytes(), "engine results differ"
        assert got.hi.tobytes() == want.hi.tobytes(), "engine results differ"

    total_hops = branches * (hops + 1)
    rec = {
        "kind": "exec",
        "shape": shape,
        "branches": branches,
        "hops": total_hops,
        "n_cells": n_cells,
        "perhop_s": perhop_s,
        "batched_s": batched_s,
        "parallel_s": parallel_s,
        "batched_speedup": perhop_s / batched_s,
        "parallel_speedup": batched_s / parallel_s,
        "launches_per_query": launches / queries_run,
        "joins_per_launch": packed / max(launches, 1),
        "batch_tiles_visited": log.io_stats["batch_tiles_visited"],
        "batch_tiles_skipped": log.io_stats["batch_tiles_skipped"],
    }
    if verbose:
        print(
            f"  accel_ablation {branches}x{hops + 1} hops "
            f"perhop={perhop_s * 1e3:7.1f}ms batched={batched_s * 1e3:7.1f}ms "
            f"parallel4={parallel_s * 1e3:7.1f}ms "
            f"batched={rec['batched_speedup']:4.2f}x "
            f"par={rec['parallel_speedup']:4.2f}x "
            f"joins/launch={rec['joins_per_launch']:4.1f}",
            flush=True,
        )
    return [rec, _run_layout_ablation(smoke=smoke, verbose=verbose)]


def _run_layout_ablation(smoke: bool = False, verbose: bool = True) -> dict:
    """Masked cross-product launch vs the block-diagonal tile schedule.

    One large ragged frontier (≥16 segments), both launch layouts forced
    through :func:`repro.kernels.ops.segmented_range_join_pairs` under the
    interpreter, pair lists asserted bit-identical to each other and to a
    per-segment ``range_join_pairs`` oracle.  The interpreter charges every
    scheduled tile, so the time ratio tracks the tile ratio — the same
    quantity that sets real-accelerator cost, reported alongside as
    ``tiles_visited`` / ``tiles_skipped``.
    """
    from repro.kernels.ops import range_join_pairs, segmented_range_join_pairs

    k, row_lo, row_hi, repeats = (16, 64, 160, 3) if smoke else (24, 96, 224, 5)
    block_q = block_r = 128
    segs = _ragged_frontier(k, row_lo, row_hi, n_attrs=2, seed=11)

    def run(layout):
        pairs, info = segmented_range_join_pairs(
            segs, block_q=block_q, block_r=block_r, interpret=True,
            layout=layout,
        )
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            pairs, info = segmented_range_join_pairs(
                segs, block_q=block_q, block_r=block_r, interpret=True,
                layout=layout,
            )
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2], pairs, info

    dense_s, dense_pairs, dense_info = run("dense")
    diag_s, diag_pairs, diag_info = run("blockdiag")
    for s, (q_lo, q_hi, r_lo, r_hi) in enumerate(segs):
        want = range_join_pairs(q_lo, q_hi, r_lo, r_hi, interpret=True)
        for label, got in (("dense", dense_pairs[s]), ("blockdiag", diag_pairs[s])):
            assert np.array_equal(got[0], want[0]) and np.array_equal(
                got[1], want[1]
            ), f"{label} layout pairs differ from per-segment oracle (seg {s})"
    rec = {
        "kind": "layout",
        "segments": k,
        "rows": int(dense_info["rows"]),
        "geometry": f"{block_q}x{block_r}",
        "dense_s": dense_s,
        "blockdiag_s": diag_s,
        "blockdiag_speedup": dense_s / diag_s,
        "tiles_visited": int(diag_info["tiles_visited"]),
        "tiles_skipped": int(diag_info["tiles_skipped"]),
        "cross_tiles": int(dense_info["tiles_visited"]),
    }
    if verbose:
        print(
            f"  layout_ablation k={k} rows={rec['rows']} "
            f"dense={dense_s * 1e3:7.1f}ms blockdiag={diag_s * 1e3:7.1f}ms "
            f"speedup={rec['blockdiag_speedup']:4.2f}x "
            f"tiles={rec['tiles_visited']}/{rec['cross_tiles']} "
            f"(skipped {rec['tiles_skipped']})",
            flush=True,
        )
    return rec


def _build_view_chain(shape, hops: int, seed: int = 0):
    """One hot linear route ``a0 → a1 → … → aH`` of random bijections.

    Composing the whole route stays one bijection (≈ one row per cell), so
    a materialized view collapses ``hops`` θ-joins into one — the workload
    the answer cache and view shortcut are built for.
    """
    rng = np.random.default_rng(seed)
    logs = []
    rels = [_permutation_lineage(shape, rng) for _ in range(hops)]
    for _ in range(2):
        log = DSLog()
        log.define_array("a0", shape)
        for h, rel in enumerate(rels):
            log.define_array(f"a{h + 1}", shape)
            log.add_lineage(f"a{h}", f"a{h + 1}", rel)
        logs.append(log)
    return logs


def run_views_ablation(
    shape=(48, 48),
    hops: int = 8,
    n_cells: int = 64,
    repeats: int = 9,
    smoke: bool = False,
    verbose: bool = True,
) -> list[dict]:
    """Materialized views + answer cache vs the plain planner (ISSUE 7).

    A hot route of ``hops`` bijection tables, queried backward with varying
    cells.  Measures, as medians over ``repeats`` runs:

    * ``cold_s``  — plain planner (views disabled): full multi-hop plan,
      one θ-join per hop, every query,
    * ``warm_s``  — heat-admitted materialized view: two-node plan over the
      composed route table, one θ-join (fresh cells each run, so the
      answer cache never fires),
    * ``cache_s`` — identical repeated query served from the cell-level
      answer cache, no planning at all,

    then mutates an entry mid-route (``mark_dirty``), checks the view and
    its answers die precisely, and lets the next hot streak re-materialize.
    Every timed answer is asserted bit-identical against the cold store.
    """
    if smoke:
        shape, hops, n_cells, repeats = (32, 32), 10, 48, 7
    warm_log, cold_log = _build_view_chain(shape, hops)
    cold_log.views.enabled = False
    src, dst = f"a{hops}", "a0"
    rng = np.random.default_rng(11)
    n = int(np.prod(shape))

    def fresh_cells():
        flat = rng.choice(n, size=n_cells, replace=False)
        return np.stack(np.unravel_index(flat, shape), axis=1)

    def identical(a, b, ctx):
        assert a.shape == b.shape, ctx
        assert a.lo.tobytes() == b.lo.tobytes(), ctx
        assert a.hi.tobytes() == b.hi.tobytes(), ctx

    # warm-up: varying cells miss the answer cache, build route heat, and
    # admit the composed view; every answer checked against the cold store
    for i in range(6):
        cells = fresh_cells()
        identical(warm_log.prov_query(src, dst, cells),
                  cold_log.prov_query(src, dst, cells), f"warmup {i}")
    assert warm_log.io_stats["views_materialized"] == 1, "no view admitted"

    queries = [fresh_cells() for _ in range(repeats)]
    cold_ts, warm_ts = [], []
    for i, cells in enumerate(queries):
        t0 = time.perf_counter()
        want = cold_log.prov_query(src, dst, cells)
        cold_ts.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        got = warm_log.prov_query(src, dst, cells)
        warm_ts.append(time.perf_counter() - t0)
        identical(got, want, f"timed {i}")
    cold_s = sorted(cold_ts)[len(cold_ts) // 2]
    warm_s = sorted(warm_ts)[len(warm_ts) // 2]

    # hot-route repeats: the identical query comes straight from the cache
    repeat_cells = queries[-1]
    base_hits = warm_log.io_stats["cache_hits"]
    cache_ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        got = warm_log.prov_query(src, dst, repeat_cells)
        cache_ts.append(time.perf_counter() - t0)
    cache_s = sorted(cache_ts)[len(cache_ts) // 2]
    assert warm_log.io_stats["cache_hits"] - base_hits == repeats
    identical(got, cold_log.prov_query(src, dst, repeat_cells), "cached")

    # mid-run mutation: precise invalidation, then re-materialization
    lid = warm_log.by_pair[(f"a{hops // 2}", f"a{hops // 2 + 1}")][0]
    warm_log.mark_dirty(lid)
    cold_log.mark_dirty(lid)
    assert warm_log.io_stats["views_invalidated"] == 1
    for i in range(6):
        cells = fresh_cells()
        identical(warm_log.prov_query(src, dst, cells),
                  cold_log.prov_query(src, dst, cells), f"post-dirty {i}")
    assert warm_log.io_stats["views_materialized"] == 2, "no re-admission"

    rec = {
        "shape": shape,
        "hops": hops,
        "n_cells": n_cells,
        "cold_s": cold_s,
        "warm_s": warm_s,
        "cache_s": cache_s,
        "view_speedup": cold_s / warm_s,
        "cache_speedup": cold_s / cache_s,
        "views_materialized": warm_log.io_stats["views_materialized"],
        "views_invalidated": warm_log.io_stats["views_invalidated"],
        "cache_hits": warm_log.io_stats["cache_hits"],
    }
    if verbose:
        print(
            f"  views_ablation {hops} hops "
            f"cold={cold_s * 1e3:7.2f}ms warm={warm_s * 1e3:7.2f}ms "
            f"cache={cache_s * 1e3:7.2f}ms "
            f"view={rec['view_speedup']:5.1f}x "
            f"cache={rec['cache_speedup']:5.1f}x",
            flush=True,
        )
    return [rec]
