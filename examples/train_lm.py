"""End-to-end driver: train a ~100M-param decoder LM for a few hundred steps
on CPU with checkpointing + straggler watchdog + loss-curve report.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch.train import train_loop
from repro.optim.adamw import AdamWConfig

LM100M = ArchConfig(
    name="lm-100m",
    family="dense",
    n_layers=8,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab=32000,
    remat="nothing",
    source="example driver",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/lm100m_ckpt")
    args = ap.parse_args()
    print(f"params: {LM100M.params_billions() * 1000:.0f}M")
    shape = ShapeConfig("train", args.seq_len, args.global_batch, "train")
    _, history = train_loop(
        LM100M,
        shape,
        steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
        opt_cfg=AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
    )
    k = max(5, len(history) // 10)
    print(
        f"loss: first-{k}-avg {sum(history[:k]) / k:.3f} -> "
        f"last-{k}-avg {sum(history[-k:]) / k:.3f} "
        f"({'DECREASED' if history and sum(history[-k:]) < sum(history[:k]) else 'FLAT'})"
    )


if __name__ == "__main__":
    main()
