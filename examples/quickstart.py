"""Quickstart: DSLog lineage storage, compression, and in-situ queries.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import numpy as np

from repro.core import DSLog
from repro.core.capture import identity_lineage, matmul_lineage, reduce_lineage

# A tiny array workflow:  X --(normalize)--> Y --(Y @ W)--> Z --(rowsum)--> S
log = DSLog()
log.define_array("X", (1024, 64))
log.define_array("Y", (1024, 64))
log.define_array("Z", (1024, 16))
log.define_array("S", (1024,))

log.register_operation(
    "normalize", ["X"], ["Y"], capture=lambda: {(0, 0): identity_lineage((1024, 64))}
)
rel_y, rel_w = matmul_lineage(1024, 64, 16)
log.register_operation(
    "project", ["Y"], ["Z"], capture=lambda: {(0, 0): rel_y}
)
log.register_operation(
    "rowsum", ["Z"], ["S"], capture=lambda: {(0, 0): reduce_lineage((1024, 16), 1)}
)

raw_bytes = sum(
    e.backward.decompress().nbytes_raw() for e in log.lineage.values()
)
print(f"stored lineage: {log.storage_bytes()} bytes "
      f"(raw rows would be {raw_bytes} bytes, "
      f"{raw_bytes / log.storage_bytes():.0f}x larger)")

# Backward: which input cells fed S[7]?
back = log.prov_query(["S", "Z", "Y", "X"], np.array([[7]]))
print(f"S[7] depends on {back.n_cells()} cells of X "
      f"(expected 64): boxes={back.n_rows}")

# Forward: where does X[3, 5] flow?
fwd = log.prov_query(["X", "Y", "Z", "S"], np.array([[3, 5]]))
print(f"X[3,5] influences cells of S: {sorted(fwd.cell_set())}")

# Graph form: no hand-spelled path — the planner routes over the lineage
# DAG itself, picking the cheapest stored materialization per hop.
auto = log.prov_query("X", "S", np.array([[3, 5]]))
assert auto.cell_set() == fwd.cell_set()
plan = log.planner.plan("X", ["S"])
print("planner route:\n" + plan.describe())

# Reuse: run the same normalize on new arrays of a DIFFERENT shape —
# after one confirming call, capture is bypassed via index reshaping.
for i, shape in enumerate([(512, 32), (2048, 128), (99, 7)]):
    a, b = f"A{i}", f"B{i}"
    log.define_array(a, shape)
    log.define_array(b, shape)
    rec = log.register_operation(
        "normalize", [a], [b],
        capture=(lambda s=shape: {(0, 0): identity_lineage(s)})
        if i < 2 else None,  # third call: no capture available at all
    )
    print(f"normalize on {shape}: reused={rec.reused}")

# Durable persistence: DSLog.open is the context-managed writer — ingest
# is write-ahead logged (group commit), a second concurrent open raises
# LeaseHeldError, and the with-exit checkpoints (incremental save + log
# truncation).  A reloaded catalog deserializes blobs lazily — only what a
# query touches — and, after a crash, replays the WAL tail on load.
with tempfile.TemporaryDirectory() as d:
    with DSLog.open(d) as disk:
        for name, shape in log.arrays.items():
            disk.define_array(name, shape.shape)
        disk.register_operation(
            "normalize", ["X"], ["Y"],
            capture=lambda: {(0, 0): identity_lineage((1024, 64))},
        )
        disk.register_operation(
            "project", ["Y"], ["Z"], capture=lambda: {(0, 0): rel_y}
        )
    reloaded = DSLog.load(d)
    reloaded.prov_query("Z", "Y", np.array([[7, 3]]))
    print(
        f"reloaded catalog answered a 1-hop query after deserializing "
        f"{reloaded.io_stats['tables_loaded']} of "
        f"{sum(1 + e.has_forward for e in reloaded.lineage.values())} "
        f"table blobs"
    )
