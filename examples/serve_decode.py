"""Batched serving demo: prefill a prompt batch, greedy-decode new tokens
through the KV/SSM caches (works for dense, SWA, MoE, hybrid, SSM archs).

    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-780m
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.launch.serve import generate
from repro.models.model import init_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()
    cfg = get_arch(args.arch).reduced()
    if cfg.encoder_only:
        raise SystemExit("encoder-only arch has no decode path")
    key = jax.random.PRNGKey(0)
    params, _ = init_model(key, cfg)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab, dtype=jnp.int32
    )
    t0 = time.time()
    out = generate(cfg, params, prompts, args.new_tokens)
    dt = time.time() - t0
    print(f"[{cfg.name}] generated {out.shape[0]}x{args.new_tokens} tokens "
          f"in {dt:.2f}s ({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print(out[:, args.prompt_len:])


if __name__ == "__main__":
    main()
