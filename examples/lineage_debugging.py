"""Training-data forensics with DSLog: find which corpus documents shaped a
given training shard row, across the pipeline chain, without decompression.

This is the paper's use case embedded in the training framework: the
pipeline logs per-step lineage into DSLog; the shuffle gather is
value-dependent (captured each step) while the shard/microbatch slices hit
``dim_sig`` reuse after one confirmation — per-step lineage cost collapses
to (gather rows) only.

Stores are opened with the durable, context-managed form (``with
DSLog.open(root) as log``): the writer lease makes a second concurrent
open an error instead of silent manifest corruption, every ingest is
write-ahead logged with group commit, and leaving the ``with`` block
checkpoints (incremental save + log truncation) and releases the lease.

    PYTHONPATH=src python examples/lineage_debugging.py
"""

import tempfile

import numpy as np

from repro.core.catalog import DSLog
from repro.core.commit import LeaseHeldError
from repro.data.pipeline import PipelineConfig, TokenPipeline

cfg = PipelineConfig(vocab=32000, seq_len=64, global_batch=16, seed=42,
                     n_source_rows=100_000)

with tempfile.TemporaryDirectory() as root:
    with DSLog.open(root) as log:
        pipe = TokenPipeline(cfg, data_shards=4, shard_id=0, dslog=log)
        for _ in range(4):
            pipe.next_batch()

        n_reused = sum(1 for op in log.ops if op.reused)
        print(f"registered {len(log.ops)} pipeline ops; {n_reused} served by "
              f"reuse (capture bypassed)")
        print(f"total lineage storage: {log.storage_bytes() / 1024:.1f} KiB")

        # the lease protocol makes the old double-open bug an error: a
        # second writer on the same root is refused while this one is live
        try:
            DSLog.open(root)
            raise AssertionError("double-open must raise")
        except LeaseHeldError as e:
            print(f"second writer refused while store is open: {e}")

        # ---- backward query: which corpus doc produced shard row 2, token
        # 10, at step 3?  Graph form: the planner routes shard -> batch ->
        # corpus over the lineage DAG itself — no hand-spelled path. -------
        res = log.prov_query("shard_s3_k0", "corpus", np.array([[2, 10]]))
        docs = sorted({c[0] for c in res.cell_set()})
        truth = pipe.source_rows_for_step(3)[2]
        print(f"shard_s3_k0[2, 10] came from corpus doc(s) {docs} "
              f"(ground truth: {truth})")
        assert docs == [int(truth)]
        # the explicit-path form (paper §V) answers identically
        via_path = log.prov_query(
            ["shard_s3_k0", "batch_s3", "corpus"], np.array([[2, 10]])
        )
        assert via_path.cell_set() == res.cell_set()

        # ---- forward query: a suspect document — which rows of data shard
        # 0 did it touch in step 3?  (shard 0 holds global batch rows 0-3.)
        suspect = int(pipe.source_rows_for_step(3)[2])
        fwd = log.prov_query("corpus", "shard_s3_k0", np.array([[suspect, 0]]))
        rows = sorted({c[0] for c in fwd.cell_set()})
        print(f"corpus doc {suspect} touched shard-0 rows {rows} "
              f"(expected [2])")
        assert rows == [2]
        answer = res.cell_set()
    # with-exit: checkpointed + lease released — reopening now works
    with DSLog.open(root) as again:
        assert again.prov_query(
            "shard_s3_k0", "corpus", np.array([[2, 10]])
        ).cell_set() == answer
    print("reopened after close: checkpointed state answers identically")

# ---- the same forensics on a sharded store: DSLog's surface is unchanged,
# so the pipeline logs into a 4-shard ShardedDSLog as-is; queries whose
# route crosses shard boundaries ship merged-box frontiers between the
# per-shard sub-plans. ------------------------------------------------------
from repro.core.shard import ShardedDSLog

with tempfile.TemporaryDirectory() as sroot:
    with ShardedDSLog.open(sroot, 4) as slog:
        spipe = TokenPipeline(cfg, data_shards=4, shard_id=0, dslog=slog)
        for _ in range(4):
            spipe.next_batch()

        sres = slog.prov_query("shard_s3_k0", "corpus", np.array([[2, 10]]))
        assert sres.cell_set() == answer  # == the single-store answer
        plan = slog.planner.plan("shard_s3_k0", ["corpus"])
        print(
            f"sharded store: {len(slog.lineage)} entries over "
            f"{slog.n_shards} shards, {len(slog.sgraph.boundary)} boundary "
            f"edges; query plan touches shards {plan.shards_touched()} with "
            f"{len(plan.exchanges)} boundary exchanges "
            f"({slog.io_stats['boxes_exchanged']} boxes shipped so far)"
        )
