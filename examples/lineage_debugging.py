"""Training-data forensics with DSLog: find which corpus documents shaped a
given training shard row, across the pipeline chain, without decompression.

This is the paper's use case embedded in the training framework: the
pipeline logs per-step lineage into DSLog; the shuffle gather is
value-dependent (captured each step) while the shard/microbatch slices hit
``dim_sig`` reuse after one confirmation — per-step lineage cost collapses
to (gather rows) only.

    PYTHONPATH=src python examples/lineage_debugging.py
"""

import numpy as np

from repro.core.catalog import DSLog
from repro.data.pipeline import PipelineConfig, TokenPipeline

log = DSLog()
cfg = PipelineConfig(vocab=32000, seq_len=64, global_batch=16, seed=42,
                     n_source_rows=100_000)
pipe = TokenPipeline(cfg, data_shards=4, shard_id=0, dslog=log)

for _ in range(4):
    pipe.next_batch()

n_reused = sum(1 for op in log.ops if op.reused)
print(f"registered {len(log.ops)} pipeline ops; {n_reused} served by reuse "
      f"(capture bypassed)")
print(f"total lineage storage: {log.storage_bytes() / 1024:.1f} KiB")

# ---- backward query: which corpus doc produced shard row 2, token 10, at
# step 3?  Graph form: the planner routes shard → batch → corpus over the
# lineage DAG itself — no hand-spelled path. -------------------------------
res = log.prov_query("shard_s3_k0", "corpus", np.array([[2, 10]]))
docs = sorted({c[0] for c in res.cell_set()})
truth = pipe.source_rows_for_step(3)[2]
print(f"shard_s3_k0[2, 10] came from corpus doc(s) {docs} "
      f"(ground truth: {truth})")
assert docs == [int(truth)]
# the explicit-path form (paper §V) answers identically
via_path = log.prov_query(
    ["shard_s3_k0", "batch_s3", "corpus"], np.array([[2, 10]])
)
assert via_path.cell_set() == res.cell_set()

# ---- forward query: a suspect document — which rows of data shard 0 did
# it touch in step 3?  (shard 0 holds global batch rows 0-3.)  The corpus
# fans out to every step's batch; the planner narrows to the one route that
# reaches the queried shard. ------------------------------------------------
suspect = int(pipe.source_rows_for_step(3)[2])
fwd = log.prov_query("corpus", "shard_s3_k0", np.array([[suspect, 0]]))
rows = sorted({c[0] for c in fwd.cell_set()})
print(f"corpus doc {suspect} touched shard-0 rows {rows} (expected [2])")
assert rows == [2]

# ---- the same forensics on a sharded store: DSLog's surface is unchanged,
# so the pipeline logs into a 4-shard ShardedDSLog as-is; queries whose
# route crosses shard boundaries ship merged-box frontiers between the
# per-shard sub-plans. ------------------------------------------------------
from repro.core.shard import ShardedDSLog

slog = ShardedDSLog(n_shards=4)
spipe = TokenPipeline(cfg, data_shards=4, shard_id=0, dslog=slog)
for _ in range(4):
    spipe.next_batch()

sres = slog.prov_query("shard_s3_k0", "corpus", np.array([[2, 10]]))
assert sres.cell_set() == res.cell_set()  # == the single-store answer
plan = slog.planner.plan("shard_s3_k0", ["corpus"])
print(
    f"sharded store: {len(slog.lineage)} entries over "
    f"{slog.n_shards} shards, {len(slog.sgraph.boundary)} boundary edges; "
    f"query plan touches shards {plan.shards_touched()} with "
    f"{len(plan.exchanges)} boundary exchanges "
    f"({slog.io_stats['boxes_exchanged']} boxes shipped so far)"
)
