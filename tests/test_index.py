"""Interval-index query path: indexed == dense, batch == loop, invalidation."""

import os
import tempfile

import numpy as np
import pytest

from repro.core import capture as C
from repro.core.catalog import DSLog
from repro.core.index import IntervalIndex, ragged_ranges
from repro.core.provrc import compress, compress_both
from repro.core.query import (
    QueryBox,
    theta_join,
    theta_join_batch,
    theta_join_inverse,
)
from repro.core.relation import LineageRelation


def _random_relation(rng, l, m, n):
    oshape = tuple(int(rng.integers(2, 7)) for _ in range(l))
    ishape = tuple(int(rng.integers(2, 7)) for _ in range(m))
    o = np.stack([rng.integers(0, s, n) for s in oshape], axis=1)
    i = np.stack([rng.integers(0, s, n) for s in ishape], axis=1)
    return LineageRelation(oshape, ishape, o, i).canonical()


# --------------------------------------------------------------------------- #
# IntervalIndex primitives
# --------------------------------------------------------------------------- #
def test_ragged_ranges():
    owner, pos = ragged_ranges(np.array([2, 5, 5, 0]), np.array([4, 5, 8, 1]))
    np.testing.assert_array_equal(owner, [0, 0, 2, 2, 2, 3])
    np.testing.assert_array_equal(pos, [2, 3, 5, 6, 7, 0])


def test_candidate_pairs_match_dense_oracle():
    rng = np.random.default_rng(7)
    for _ in range(40):
        nq = int(rng.integers(1, 25))
        nr = int(rng.integers(1, 300))
        l = int(rng.integers(1, 4))
        r_lo = rng.integers(0, 80, (nr, l)).astype(np.int64)
        r_hi = r_lo + rng.integers(0, 12, (nr, l))
        q_lo = rng.integers(0, 80, (nq, l)).astype(np.int64)
        q_hi = q_lo + rng.integers(0, 20, (nq, l))
        idx = IntervalIndex(r_lo, r_hi)
        qi, ri = idx.candidate_pairs(q_lo, q_hi)
        ov = np.ones((nq, nr), bool)
        for j in range(l):
            ov &= (q_lo[:, j : j + 1] <= r_hi[None, :, j]) & (
                r_lo[None, :, j] <= q_hi[:, j : j + 1]
            )
        wq, wr = np.nonzero(ov)
        np.testing.assert_array_equal(qi, wq)
        np.testing.assert_array_equal(ri, wr)
        assert idx.estimate_candidates(q_lo, q_hi) >= qi.size


def test_index_serialization_roundtrip():
    rng = np.random.default_rng(11)
    lo = rng.integers(0, 50, (200, 2)).astype(np.int64)
    hi = lo + rng.integers(0, 5, (200, 2))
    idx = IntervalIndex(lo, hi)
    idx2 = IntervalIndex.from_bytes(idx.to_bytes(), lo, hi)
    q_lo = rng.integers(0, 50, (7, 2)).astype(np.int64)
    q_hi = q_lo + 3
    for a, b in zip(idx.candidate_pairs(q_lo, q_hi), idx2.candidate_pairs(q_lo, q_hi)):
        np.testing.assert_array_equal(a, b)


def test_index_rejects_mismatched_table():
    lo = np.zeros((4, 1), np.int64)
    hi = np.ones((4, 1), np.int64)
    blob = IntervalIndex(lo, hi).to_bytes()
    with pytest.raises(ValueError):
        IntervalIndex.from_bytes(blob, np.zeros((5, 1), np.int64), np.ones((5, 1), np.int64))


def test_index_rejects_stale_or_corrupt_permutation():
    rng = np.random.default_rng(21)
    lo = np.sort(rng.integers(0, 1000, (64, 1)).astype(np.int64), axis=0)
    hi = lo + 2
    blob = IntervalIndex(lo, hi).to_bytes()
    # stale: same shape, different (reversed) bounds -> order no longer sorts
    with pytest.raises(ValueError):
        IntervalIndex.from_bytes(blob, lo[::-1].copy(), hi[::-1].copy())
    # corrupt: garbage order values must raise ValueError, not IndexError
    with pytest.raises(ValueError):
        IntervalIndex(lo, hi, order=np.full((1, 64), 9999))
    with pytest.raises(ValueError):
        IntervalIndex(lo, hi, order=np.zeros((1, 64), np.int64))  # not a perm


# --------------------------------------------------------------------------- #
# Indexed vs dense θ-join equivalence
# --------------------------------------------------------------------------- #
def test_indexed_theta_join_equals_dense_random_relations():
    rng = np.random.default_rng(0)
    for trial in range(25):
        l, m = int(rng.integers(1, 3)), int(rng.integers(1, 3))
        rel = _random_relation(rng, l, m, int(rng.integers(1, 80)))
        bwd, fwd = compress_both(rel)
        qo = np.unique(
            np.stack([rng.integers(0, s, 4) for s in rel.out_shape], axis=1), axis=0
        )
        qi = np.unique(
            np.stack([rng.integers(0, s, 4) for s in rel.in_shape], axis=1), axis=0
        )
        q_out = QueryBox.from_cells(rel.out_shape, qo)
        q_in = QueryBox.from_cells(rel.in_shape, qi)
        for fn, q, t in [
            (theta_join, q_out, bwd),
            (theta_join, q_in, fwd),
            (theta_join_inverse, q_in, bwd),
            (theta_join_inverse, q_out, fwd),
        ]:
            indexed = fn(q, t, path="index")
            dense = fn(q, t, path="dense")
            assert indexed.cell_set() == dense.cell_set(), (trial, fn.__name__)
            # merged outputs are canonical: cell-for-cell AND box-for-box
            both_i = np.concatenate([indexed.lo, indexed.hi], axis=1)
            both_d = np.concatenate([dense.lo, dense.hi], axis=1)
            np.testing.assert_array_equal(
                np.unique(both_i, axis=0), np.unique(both_d, axis=0)
            )


def test_auto_path_equals_dense_on_large_table():
    rng = np.random.default_rng(3)
    n = 3000
    o = np.stack([rng.integers(0, 200, n), rng.integers(0, 200, n)], axis=1)
    i = np.stack([rng.integers(0, 300, n)], axis=1)
    rel = LineageRelation((200, 200), (300,), o, i).canonical()
    t = compress(rel)
    assert t.n_rows >= 1024, "table must be large enough to engage the index"
    q = QueryBox.from_range((200, 200), (5, 5), (8, 8))
    assert theta_join(q, t).cell_set() == theta_join(q, t, path="dense").cell_set()


def test_unknown_path_raises():
    t = compress(C.identity_lineage((5,)))
    with pytest.raises(ValueError):
        theta_join(QueryBox.from_cells((5,), np.array([[0]])), t, path="turbo")


def test_symbolic_table_rejected_by_all_joins():
    t = compress(C.identity_lineage((5,)))
    t.key_sym = np.zeros((t.n_rows, 1), np.int8)  # mark axis-0 symbolic
    q_key = QueryBox.from_cells((5,), np.array([[0]]))
    q_val = QueryBox.from_cells((5,), np.array([[0]]))
    with pytest.raises(ValueError, match="symbolic"):
        theta_join(q_key, t)
    with pytest.raises(ValueError, match="symbolic"):
        theta_join_inverse(q_val, t)
    with pytest.raises(ValueError, match="symbolic"):
        theta_join_batch([q_key], t)


# --------------------------------------------------------------------------- #
# Batched API
# --------------------------------------------------------------------------- #
def test_batch_equals_loop_of_singles():
    rng = np.random.default_rng(5)
    rel = _random_relation(rng, 2, 2, 60)
    t = compress(rel)
    queries = []
    for _ in range(6):
        cells = np.stack(
            [rng.integers(0, s, 3) for s in rel.out_shape], axis=1
        )
        queries.append(QueryBox.from_cells(rel.out_shape, cells))
    queries.append(queries[0])  # duplicate query: exercises probe dedup
    queries.append(QueryBox(rel.out_shape, np.zeros((0, 2)), np.zeros((0, 2))))
    for path in ("index", "dense", "auto"):
        batch = theta_join_batch(queries, t, path=path)
        assert len(batch) == len(queries)
        for got, q in zip(batch, queries):
            want = theta_join(q, t)
            assert got.cell_set() == want.cell_set(), path


def test_batch_empty_inputs():
    t = compress(C.identity_lineage((5,)))
    assert theta_join_batch([], t) == []
    q = QueryBox((5,), np.zeros((0, 1)), np.zeros((0, 1)))
    assert theta_join_batch([q, q], t)[0].n_rows == 0


def test_batch_shape_mismatch_raises():
    t = compress(C.identity_lineage((5,)))
    with pytest.raises(ValueError):
        theta_join_batch([QueryBox.from_cells((4,), np.array([[0]]))], t)


# --------------------------------------------------------------------------- #
# Invalidation
# --------------------------------------------------------------------------- #
def test_index_invalidated_on_field_reassignment():
    rel = C.identity_lineage((10,))
    t = compress(rel)
    q = QueryBox.from_cells((10,), np.array([[3]]))
    assert theta_join(q, t, path="index").cell_set() == {(3,)}
    # shift every key interval by one: cell 3 now maps to value 2's row
    t.key_lo = t.key_lo + 1
    t.key_hi = t.key_hi + 1
    assert theta_join(q, t, path="index").cell_set() == \
        theta_join(q, t, path="dense").cell_set()


def test_index_invalidated_after_inplace_mutation():
    rel = C.identity_lineage((10,))
    t = compress(rel)
    q = QueryBox.from_cells((10,), np.array([[3]]))
    stale = theta_join(q, t, path="index").cell_set()
    assert stale == {(3,)}
    t.key_lo += 1
    t.key_hi += 1
    t.invalidate_index()  # in-place writes need the explicit call
    assert theta_join(q, t, path="index").cell_set() == \
        theta_join(q, t, path="dense").cell_set()


def test_select_returns_fresh_cache():
    rng = np.random.default_rng(9)
    rel = _random_relation(rng, 2, 1, 50)
    t = compress(rel)
    assert t.n_rows >= 2
    t.key_index()
    sub = t.select(np.array([0, 1]))
    assert sub.cached_key_index() is None
    assert sub.key_index().n_rows == sub.n_rows


# --------------------------------------------------------------------------- #
# Catalog persistence + batch queries
# --------------------------------------------------------------------------- #
def test_catalog_persists_and_reloads_index():
    with tempfile.TemporaryDirectory() as d:
        log = DSLog(root=d, store_forward=True)
        relXY = C.identity_lineage((6, 3))
        relYZ = C.reduce_lineage((6, 3), 1)
        log.add_lineage("X", "Y", relXY)
        log.add_lineage("Y", "Z", relYZ)
        for e in log.lineage.values():
            e.backward.key_index()  # build → save() must persist it
        log.save()
        assert any(f.endswith(".idx") for f in os.listdir(d))
        log2 = DSLog.load(d)
        e0 = log2.lineage[0]
        assert e0.backward.cached_key_index() is not None
        res = log2.prov_query(["Z", "Y", "X"], np.array([[4]]))
        assert res.cell_set() == {(4, j) for j in range(3)}


def test_prov_query_batch_matches_singles():
    log = DSLog(store_forward=True)
    relXY = C.identity_lineage((6, 3))
    relYZ = C.reduce_lineage((6, 3), 1)
    log.add_lineage("X", "Y", relXY)
    log.add_lineage("Y", "Z", relYZ)
    queries = [np.array([[4]]), np.array([[0]]), np.array([[4]])]
    batch = log.prov_query_batch(["Z", "Y", "X"], queries)
    for got, cells in zip(batch, queries):
        want = log.prov_query(["Z", "Y", "X"], cells)
        assert got.cell_set() == want.cell_set()
    assert log.prov_query_batch(["Z", "Y", "X"], []) == []
