"""Per-arch smoke tests (reduced configs) + numerical equivalence checks:
chunked attention == dot attention, decode path == teacher-forced forward,
SSD chunked scan == naive recurrence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models.attention import attention_block, attn_init
from repro.models.blocks import init_caches
from repro.models.model import decode_step, forward, init_model, lm_loss
from repro.models.ssm import ssm_apply, ssm_decode, ssm_init, ssm_state_shapes

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, b, s):
    if cfg.frontend == "frames":
        return {
            "frames": jax.random.normal(KEY, (b, s, cfg.frontend_dim)),
            "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab),
        }
    if cfg.frontend == "patch":
        return {
            "tokens": jax.random.randint(KEY, (b, s - cfg.frontend_len), 0, cfg.vocab),
            "patch_embeds": jax.random.normal(KEY, (b, cfg.frontend_len, cfg.d_model)),
        }
    return {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_forward_and_trainstep(name):
    """One forward + one grad step on the reduced config: shapes + finite."""
    cfg = get_arch(name).reduced()
    params, specs = init_model(KEY, cfg)
    b, s = 2, 16
    batch = _batch_for(cfg, b, s)
    logits, aux = jax.jit(lambda p, bt: forward(p, bt, cfg))(params, batch)
    exp_s = s if cfg.frontend != "patch" else s
    assert logits.shape == (b, exp_s, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()

    grads = jax.grad(lambda p: lm_loss(p, batch, cfg)[0])(params)
    gn = jax.tree.reduce(
        lambda a, x: a + float(jnp.abs(x).sum()), grads, 0.0
    )
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_decode(name):
    cfg = get_arch(name).reduced()
    if cfg.encoder_only:
        pytest.skip("encoder-only: no decode")
    params, _ = init_model(KEY, cfg)
    b = 2
    caches = init_caches(cfg, b, 24, jnp.float32)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, caches2 = jax.jit(
        lambda p, t, c: decode_step(p, t, c, jnp.int32(0), cfg)
    )(params, tok, caches)
    assert logits.shape == (b, 1, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()
    # cache was written
    if "k" in caches:
        assert float(jnp.abs(caches2["k"]).sum()) > 0


@pytest.mark.parametrize("name", ["qwen2-0.5b", "gemma3-4b", "mamba2-780m",
                                  "hymba-1.5b", "qwen2-moe-a2.7b"])
def test_decode_matches_forward(name):
    """Greedy stepwise decode logits == teacher-forced forward logits."""
    cfg = get_arch(name).reduced()
    if cfg.moe is not None:
        # equivalence needs a drop-free capacity; production capacity
        # drops are exercised separately (test_moe_balance_aux_positive)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    params, _ = init_model(jax.random.PRNGKey(1), cfg)
    b, s = 2, 16  # divisible by the reduced SSD chunk (8)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    full_logits, _ = forward(params, {"tokens": tokens}, cfg, mode="dot")

    caches = init_caches(cfg, b, s + 1, jnp.float32)
    step_logits = []
    for t in range(s):
        lg, caches = decode_step(
            params, tokens[:, t : t + 1], caches, jnp.int32(t), cfg
        )
        step_logits.append(lg[:, 0])
    step_logits = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(full_logits), rtol=2e-3, atol=2e-3
    )


def test_chunked_attention_equals_dot():
    cfg = get_arch("qwen2-0.5b").reduced()
    p = attn_init(jax.random.PRNGKey(3), cfg)
    p = jax.tree.map(lambda x: x.value if hasattr(x, "value") else x, p,
                     is_leaf=lambda x: hasattr(x, "value"))
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, cfg.d_model))
    y_dot = attention_block(p, x, cfg, mode="dot")
    y_chunk = attention_block(p, x, cfg, mode="chunked", chunk=8)
    np.testing.assert_allclose(np.asarray(y_dot), np.asarray(y_chunk),
                               rtol=1e-4, atol=1e-5)


def test_sliding_window_masks_past():
    cfg = dataclasses.replace(get_arch("gemma3-4b").reduced(), window=4)
    p = attn_init(jax.random.PRNGKey(3), cfg)
    p = jax.tree.map(lambda x: x.value if hasattr(x, "value") else x, p,
                     is_leaf=lambda x: hasattr(x, "value"))
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, cfg.d_model))
    y_local = attention_block(p, x, cfg, window=jnp.int32(4), mode="dot")
    # perturbing a token > window positions back must not change the output
    x2 = x.at[:, 0].add(10.0)
    y2 = attention_block(p, x2, cfg, window=jnp.int32(4), mode="dot")
    np.testing.assert_allclose(
        np.asarray(y_local[:, 8:]), np.asarray(y2[:, 8:]), rtol=1e-4, atol=1e-5
    )


def _naive_ssd(p, x, cfg):
    """Token-by-token recurrence oracle for the chunked SSD scan."""
    b, s, d = x.shape
    conv_shape, ssm_shape = ssm_state_shapes(cfg, b)
    conv = jnp.zeros(conv_shape)
    state = jnp.zeros(ssm_shape)
    outs = []
    for t in range(s):
        y, conv, state = ssm_decode(p, x[:, t : t + 1], cfg, conv, state)
        outs.append(y[:, 0])
    return jnp.stack(outs, axis=1)


def test_ssd_chunked_equals_recurrence():
    cfg = get_arch("mamba2-780m").reduced()
    p = ssm_init(jax.random.PRNGKey(7), cfg)
    p = jax.tree.map(lambda x: x.value if hasattr(x, "value") else x, p,
                     is_leaf=lambda x: hasattr(x, "value"))
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 16, cfg.d_model)) * 0.5
    y_chunked = ssm_apply(p, x, cfg)  # chunk = 8 -> 2 chunks
    y_naive = _naive_ssd(p, x, cfg)
    np.testing.assert_allclose(
        np.asarray(y_chunked), np.asarray(y_naive), rtol=2e-3, atol=2e-3
    )


def test_moe_balance_aux_positive():
    from repro.models.layers import split_params
    from repro.models.moe import moe_apply, moe_init

    cfg = get_arch("qwen2-moe-a2.7b").reduced()
    p, _ = split_params(moe_init(jax.random.PRNGKey(9), cfg))
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 16, cfg.d_model))
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz; == 1 if balanced


def test_exact_param_counts():
    expected = {
        "qwen1.5-110b": 111.2, "qwen1.5-32b": 35.2, "grok-1-314b": 316.5,
        "mamba2-780m": 0.8, "qwen2-0.5b": 0.5,
    }
    for name, want in expected.items():
        got = ARCHS[name].params_billions()
        assert abs(got - want) / want < 0.05, (name, got, want)


def test_sorted_moe_dispatch_equals_einsum():
    """§Perf optimization: gather/scatter dispatch == one-hot einsum."""
    cfg = get_arch("qwen2-moe-a2.7b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
    )
    from repro.models.layers import split_params
    from repro.models.moe import moe_apply, moe_init

    p, _ = split_params(moe_init(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y1, a1 = moe_apply(p, x, cfg)
    cfg2 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="sorted")
    )
    y2, a2 = moe_apply(p, x, cfg2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-5)
    assert float(a1) == float(a2)


def test_causal_blocked_attention_equals_dot():
    """§Perf optimization: triangular q-block schedule == dot attention."""
    from repro.models.layers import split_params

    cfg = get_arch("qwen2-0.5b").reduced()
    p, _ = split_params(attn_init(jax.random.PRNGKey(2), cfg))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model))
    yd = attention_block(p, x, cfg, mode="dot")
    yb = attention_block(p, x, cfg, mode="causal_blocked", chunk=8)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yb),
                               rtol=1e-4, atol=1e-5)
    # sliding-window variant agrees too
    yw = attention_block(p, x, cfg, window=jnp.int32(8), mode="dot")
    yw2 = attention_block(p, x, cfg, window=jnp.int32(8),
                          mode="causal_blocked", chunk=8)
    np.testing.assert_allclose(np.asarray(yw), np.asarray(yw2),
                               rtol=1e-4, atol=1e-5)
