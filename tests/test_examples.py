"""Subprocess smoke tests for the documented entry points.

The examples are the public face of the API; running them end-to-end (with
``PYTHONPATH=src`` exactly as the docstrings instruct) means a refactor
cannot silently break the quickstart while the unit suite stays green.
"""

import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_example(name: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    src = os.path.join(_REPO, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, os.path.join(_REPO, "examples", name)],
        capture_output=True,
        text=True,
        env=env,
        timeout=300,
    )


@pytest.mark.parametrize("name", ["quickstart.py", "lineage_debugging.py"])
def test_example_runs_clean(name):
    proc = _run_example(name)
    assert proc.returncode == 0, (
        f"{name} failed\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    assert proc.stdout.strip(), f"{name} printed nothing"


def test_quickstart_output_shape():
    proc = _run_example("quickstart.py")
    assert proc.returncode == 0, proc.stderr
    out = proc.stdout
    assert "planner route:" in out
    assert "reused=gen" in out  # index-reshaping reuse actually engaged
    assert "table blobs" in out  # lazy reload demo ran
